PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: check test lint examples

# tier-1 pytest + reduced lm/vlm dry-runs (no TPU needed) — the CI gate
check:
	bash scripts/check.sh

test:
	python -m pytest -x -q

# replint: AST concurrency + JAX-discipline analyzer (docs/LINTS.md);
# exits non-zero on any unsuppressed finding
lint:
	python scripts/repro_lint.py

examples:
	python examples/quickstart.py
	python examples/low_power_cascade.py
