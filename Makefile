PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: check test examples

# tier-1 pytest + reduced lm/vlm dry-runs (no TPU needed) — the CI gate
check:
	bash scripts/check.sh

test:
	python -m pytest -x -q

examples:
	python examples/quickstart.py
	python examples/low_power_cascade.py
