"""Serving engine: exactness vs lockstep decode, continuous batching,
TABM path, battery throttling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.power import BatteryAwareExecutor, PMU
from repro.launch.steps import init_params
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import bucket_length
from repro.serving.sampling import greedy, sample


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reference_decode(cfg, params, prompt, n):
    logits, cache = M.lm_prefill(params, cfg, jnp.asarray(prompt)[None], 256)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        lg, cache = M.lm_decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def test_engine_matches_reference(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, n_slots=4, max_len=256)
    prompts = [np.arange(5, 5 + n) % 200 + 3 for n in (9, 17, 33)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, tokens=p, max_new_tokens=8))
    done = eng.run()
    assert len(done) == 3
    for req in done:
        ref = _reference_decode(cfg, params, prompts[req.rid], 8)
        assert req.out_tokens[:8] == ref, req.rid


def test_slot_reuse_more_requests_than_slots(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, n_slots=2, max_len=256)
    for i in range(6):
        eng.submit(Request(rid=i, tokens=np.arange(3 + i) + 3,
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 6
    assert eng.stats.prefills == 6
    assert len(eng.slots.free) == 2            # all slots returned


def test_battery_critical_stops_admission(setup):
    cfg, params = setup
    ex = BatteryAwareExecutor(PMU())
    ex.pmu.level = 0.05                        # CRITICAL
    eng = ServingEngine(cfg, params, n_slots=2, max_len=256, executor=ex)
    eng.submit(Request(rid=0, tokens=np.arange(5) + 3, max_new_tokens=4))
    for _ in range(5):
        eng.step()
    assert len(eng.done) == 0                  # nothing admitted
    ex.pmu.level = 1.0
    done = eng.run()
    assert len(done) == 1                      # resumes when charged


def test_vlm_tabm_path(key):
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(key, cfg)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=128)
    feats = np.full((1, cfg.vision_tokens, cfg.vision_feat_dim), 0.01,
                    np.float32)
    eng.submit(Request(rid=0, tokens=np.arange(6) + 3, max_new_tokens=4,
                       vision_feats=feats))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) >= 4
    assert eng.tabm.stats["writes"] == 1 and eng.tabm.stats["reads"] == 1


def test_bucketing():
    assert bucket_length(1) == 128
    assert bucket_length(128) == 128
    assert bucket_length(129) == 256
    assert bucket_length(5000) == 4096


def test_sampling_functions(key):
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(greedy(logits)[0]) == 1
    t = sample(logits, key, temperature=1e-4)
    assert int(t[0]) == 1
    tk = sample(jnp.asarray([[0.0, 5.0, 4.9, -2.0]]), key,
                temperature=2.0, top_k=2)
    assert int(tk[0]) in (1, 2)
    tp = sample(logits, key, temperature=1.0, top_p=0.5)
    assert int(tp[0]) == 1


def test_e2e_latency_and_throughput_metrics(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, n_slots=2, max_len=256)
    eng.submit(Request(rid=0, tokens=np.arange(8) + 3, max_new_tokens=4))
    done = eng.run()
    assert done[0].e2e_latency is not None and done[0].e2e_latency > 0
    assert done[0].first_token_t is not None
    mem = eng.memory_bytes()
    assert mem["weights"] > 0 and mem["kv_pool"] > 0
