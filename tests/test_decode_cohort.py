"""Continuous-batching decode cohort over paged, class-aware KV (PR 6).

The decode-equivalence battery the issue asks for:

* **cohort == per-request oracle** — greedy tokens from the batched
  cohort decode (mixed slot classes, mid-flight admissions and
  retirements against a 2-slot pool) are identical to each request
  decoded alone in its own engine;
* **paged block allocator invariants** (hypothesis) — random
  take/grant/release schedules never double-grant a block, never orphan
  one, and conserve the free count; ``insert_many``'s strided writes
  land in the owner's granted blocks ONLY;
* **refcounted READY slots** — two requests with identical vision bytes
  stage ONCE (one ring write, one ``shares`` grant) and decode exactly
  like private copies; a shared slot frees only when the last holder
  releases;
* **battery-aware KV shed** — THROTTLED shrinks the hi-res classes'
  block budgets first (``kv_block_budgets`` + engine admission), and
  restores them when charge recovers;
* **free-list fix** — ``SlotCache.free`` is a deque (O(1) ``popleft``,
  not ``list.pop(0)``) and still hands slots out FIFO.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, strategies as hst

from repro.configs import get_config
from repro.core.power import BatteryAwareExecutor, PMU
from repro.core.scheduler import kv_block_budgets
from repro.core.slot_classes import shed_scales
from repro.core.tabm import CONSUMED, EMPTY, RingBuffer, SlotClassPool
from repro.launch.steps import init_params
from repro.models import decoder as dec
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import PagedKVCache, SlotCache


@pytest.fixture(scope="module")
def vlm():
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def lm_cfg():
    return get_config("stablelm-1.6b").reduced()


def _req(cfg, rid, n_tokens, n_images=1, n_new=4, seed=0, prompt_len=None):
    rng = np.random.default_rng(seed + rid)
    plen = prompt_len if prompt_len is not None else 6 + (rid % 3)
    return Request(
        rid=rid, tokens=(np.arange(plen) % 50 + 3).astype(np.int32),
        n_images=n_images, max_new_tokens=n_new,
        vision_feats=rng.standard_normal(
            (1, n_tokens, cfg.vision_feat_dim)).astype(np.float32) * 0.02)


# ---------------------------------------------------------------------------
# headline: cohort decode == per-request oracle, with mid-flight churn
# ---------------------------------------------------------------------------

def test_cohort_matches_per_request_oracle(vlm):
    """Five mixed-class requests through a 2-slot engine: the pool is
    oversubscribed, so requests retire and admit mid-flight while
    others keep decoding in the same cohort step.  Every request's
    greedy tokens must equal the request decoded alone."""
    cfg, params = vlm

    def reqs():
        return [
            _req(cfg, 0, 8, n_images=1, n_new=6, prompt_len=7),
            _req(cfg, 1, 2, n_images=1, n_new=3, prompt_len=6),
            _req(cfg, 2, 32, n_images=4, n_new=5, prompt_len=9),
            _req(cfg, 3, 2, n_images=1, n_new=4, prompt_len=8),
            _req(cfg, 4, 8, n_images=1, n_new=3, prompt_len=6),
        ]

    batch = reqs()
    with ServingEngine(cfg, params, n_slots=2, max_len=128,
                       block_size=32) as eng:
        for r in batch:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 5 and all(r.error is None for r in done)
        assert len({r.slot_class for r in batch}) >= 2, (
            f"battery needs >=2 slot classes, got "
            f"{[r.slot_class for r in batch]}")
        events = [(e, k) for e, k, _ in eng.trace]
        cohorts = [k for e, k in events if e == "decode_cohort"]
        assert max(cohorts) > 1, f"never decoded a cohort >1: {cohorts}"
        # mid-flight churn: some retirement precedes some admission
        first_finish = events.index(("finish", done[0].rid))
        later_prefills = [i for i, (e, _) in enumerate(events)
                          if e == "prefill" and i > first_finish]
        assert later_prefills, (
            "no admission after the first retirement — the pool never "
            f"churned mid-flight: {events}")
        cohort_tokens = {r.rid: r.out_tokens for r in done}

    for ref in reqs():
        with ServingEngine(cfg, params, n_slots=2, max_len=128,
                           block_size=32) as eng:
            eng.submit(ref)
            done = eng.run()
            assert done[0].error is None
            assert cohort_tokens[ref.rid] == ref.out_tokens, (
                f"request {ref.rid}: cohort decode changed greedy tokens\n"
                f"  cohort: {cohort_tokens[ref.rid]}\n"
                f"  alone:  {ref.out_tokens}")


def test_mid_flight_blocks_recycle(vlm):
    """A finishing request's KV blocks are free the same step — the next
    request's grant reuses them (block ids overlap)."""
    cfg, params = vlm
    with ServingEngine(cfg, params, n_slots=1, max_len=128,
                       block_size=32) as eng:
        a, b = _req(cfg, 0, 2, n_new=3), _req(cfg, 1, 2, n_new=3, seed=50)
        eng.submit(a)
        eng.submit(b)
        seen = {}
        for _ in range(60):
            for slot, req in eng.live.items():
                seen[req.rid] = list(eng.slots.block_tables[slot])
            if not (eng.queue or eng.live):
                break
            eng.step()
        assert a.error is None and b.error is None
        assert set(seen[0]) & set(seen[1]), (
            f"freed blocks were not recycled: {seen}")
        assert eng.slots.free_block_count == eng.slots.n_blocks
        eng.slots.check_block_invariants()


# ---------------------------------------------------------------------------
# paged block allocator: property tests (hypothesis)
# ---------------------------------------------------------------------------

def _tiny_pool(cfg, n_slots=4, max_len=64, block_size=16):
    return PagedKVCache(cfg, n_slots, max_len, block_size=block_size)


@given(ops=hst.lists(hst.tuples(hst.integers(0, 2), hst.integers(0, 7)),
                     max_size=40))
def test_block_allocator_invariants(ops):
    """Random take/grant/release schedules: no double grant, no orphan,
    free-count conservation, class charges match the tables — after
    EVERY op (``check_block_invariants``)."""
    cfg = get_config("stablelm-1.6b").reduced()
    kv = _tiny_pool(cfg)
    classes = ("thumb", "hi")
    live = []
    for op, v in ops:
        if op in (0, 2) and kv.free:           # admit: slot + lifetime grant
            need = 1 + v % kv.blocks_per_slot
            slot = kv.take_slot()
            if need <= kv.free_block_count:
                kv.grant_blocks(slot, need, slot_class=classes[v % 2])
                live.append(slot)
            else:                              # grant refused atomically
                with pytest.raises(RuntimeError):
                    kv.grant_blocks(slot, need, slot_class=classes[v % 2])
                kv.release(slot)
        elif op == 1 and live:                 # retire: blocks free NOW
            slot = live.pop(v % len(live))
            freed = len(kv.block_tables[slot])
            before = kv.free_block_count
            kv.release(slot)
            assert kv.free_block_count == before + freed
        kv.check_block_invariants()
    total_granted = sum(len(t) for t in kv.block_tables.values())
    assert total_granted + kv.free_block_count == kv.n_blocks


def test_double_grant_raises(lm_cfg):
    kv = _tiny_pool(lm_cfg)
    slot = kv.take_slot()
    kv.grant_blocks(slot, 2)
    with pytest.raises(RuntimeError):
        kv.grant_blocks(slot, 1)               # one grant per residency
    kv.release(slot)
    kv.check_block_invariants()


def test_insert_many_writes_only_owner_blocks(lm_cfg):
    """The strided block scatter lands each request's prefill in ITS
    granted blocks and nowhere else — ungranted blocks stay zero."""
    cfg = lm_cfg
    kv = _tiny_pool(cfg)                       # 16 blocks of 16 tokens
    bs = kv.block_size
    s0, s1 = kv.take_slot(), kv.take_slot()
    kv.grant_blocks(s0, 2, slot_class="a")
    kv.grant_blocks(s1, 2, slot_class="b")
    # fake block-aligned prefill (K=2, S=2 blocks): row b holds b+1
    layers = jax.tree.map(
        lambda l: jnp.broadcast_to(
            jnp.arange(1, 3, dtype=l.dtype).reshape(
                (1, 2) + (1,) * (l.ndim - 2)), l.shape),
        dec.init_cache(cfg, 2, 2 * bs))
    kv.insert_many([s0, s1], {"layers": layers}, [5, 9])
    assert int(kv.lengths[s0]) == 5 and int(kv.lengths[s1]) == 9
    owned = {s0: 1.0, s1: 2.0}
    for pos, is_paged in enumerate(kv.paged):
        if not is_paged:
            continue
        for leaf in jax.tree.leaves(kv.pool[pos]):
            got = np.asarray(leaf, np.float32)
            for slot, val in owned.items():
                for blk in kv.block_tables[slot]:
                    assert np.all(got[:, blk] == val), (
                        f"slot {slot}'s value missing from its block {blk}")
            granted = {b for t in kv.block_tables.values() for b in t}
            for blk in range(kv.n_blocks):
                if blk not in granted:
                    assert np.all(got[:, blk] == 0.0), (
                        f"write leaked into ungranted block {blk}")
    kv.check_block_invariants()


def test_insert_many_requires_block_aligned_and_granted(lm_cfg):
    cfg = lm_cfg
    kv = _tiny_pool(cfg)
    bs = kv.block_size
    slot = kv.take_slot()
    kv.grant_blocks(slot, 1)
    layers = dec.init_cache(cfg, 1, bs + 1)    # misaligned width
    with pytest.raises(RuntimeError):
        kv.insert_many([slot], {"layers": layers}, [3])
    layers = dec.init_cache(cfg, 1, 2 * bs)    # wider than the grant
    with pytest.raises(RuntimeError):
        kv.insert_many([slot], {"layers": layers}, [3])


# ---------------------------------------------------------------------------
# refcounted READY slots: stage once, feed many
# ---------------------------------------------------------------------------

def test_ring_refcount_frees_at_zero():
    rb = RingBuffer(n_slots=2, max_tokens=8, dim=16)
    s = rb.acquire_write()
    rb.commit_write(s, jnp.ones((3, 16)))
    slot, view, n = rb.acquire_read()
    gen = rb.slot_generation(slot)
    assert rb.addref(slot, gen)                # second holder
    assert rb.stats["shares"] == 1
    shared = rb.shared_view(slot, gen)
    assert shared is not None and shared[1] == 3
    rb.release(slot)                           # 2 -> 1: stays CONSUMED
    assert rb.states[slot] == CONSUMED
    assert rb.view_valid(slot, gen)            # survivors' views stay valid
    rb.release(slot)                           # 1 -> 0: now recycled
    assert rb.states[slot] == EMPTY
    assert not rb.addref(slot, gen)            # stale gen can't re-pin
    assert rb.shared_view(slot, gen) is None


def test_shared_staging_decodes_like_private(vlm):
    """Two requests with byte-identical vision stage ONCE (ring writes
    == 1, one ``shares`` grant) and produce exactly the tokens two
    private stagings produce."""
    cfg, params = vlm

    def reqs():
        rng = np.random.default_rng(3)
        feats = rng.standard_normal(
            (1, cfg.vision_tokens, cfg.vision_feat_dim)
        ).astype(np.float32) * 0.02
        return [Request(rid=i, tokens=np.arange(7) + 3, max_new_tokens=4,
                        vision_feats=feats.copy()) for i in range(2)]

    twins = reqs()
    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng:
        for r in twins:
            eng.submit(r)
        assert twins[1].share_of is twins[0]   # dedup keyed on bytes
        done = eng.run()
        assert all(r.error is None for r in done)
        ring = eng.tabm.ring(twins[0].slot_class)
        assert ring.stats["writes"] == 1, (
            f"identical vision staged twice: {ring.stats}")
        assert ring.stats["shares"] == 1, ring.stats
        assert ("stage_share", twins[1].rid) in [
            (e, k) for e, k, _ in eng.trace]
        shared_tokens = {r.rid: r.out_tokens for r in done}

    private = reqs()
    with ServingEngine(cfg, params, n_slots=2, max_len=128,
                       share_staged=False) as eng:
        for r in private:
            eng.submit(r)
        done = eng.run()
        assert all(r.error is None for r in done)
        ring = eng.tabm.ring(private[0].slot_class)
        assert ring.stats["writes"] == 2       # the un-deduped baseline
        private_tokens = {r.rid: r.out_tokens for r in done}
    assert shared_tokens == private_tokens, (
        f"refcounted reuse changed greedy tokens:\n"
        f"  shared:  {shared_tokens}\n  private: {private_tokens}")


def test_failed_owner_releases_sharers(vlm):
    """If the staging owner fails before binding, its sharers fall back
    to staging privately instead of waiting forever."""
    cfg, params = vlm
    rng = np.random.default_rng(9)
    feats = rng.standard_normal(
        (1, cfg.vision_tokens, cfg.vision_feat_dim)
    ).astype(np.float32) * 0.02
    reqs = [Request(rid=i, tokens=np.arange(6) + 3, max_new_tokens=3,
                    vision_feats=feats.copy()) for i in range(2)]
    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng:
        for r in reqs:
            eng.submit(r)
        assert reqs[1].share_of is reqs[0]
        eng._unshare(reqs[0])                  # what _fail does to an owner
        assert reqs[1].share_of is None        # twin stages privately now
        done = eng.run()
        assert all(r.error is None for r in done) and len(done) == 2


# ---------------------------------------------------------------------------
# battery-aware KV shed: hi-res block budgets shrink first, then restore
# ---------------------------------------------------------------------------

def test_kv_block_budgets_shed_hires_first(vlm):
    cfg, _ = vlm
    pool = SlotClassPool.from_config(cfg, slots_per_class=2)
    names = list(pool.classes)                 # ascending by slab size
    eff = shed_scales(pool.classes, 0.5)
    assert eff[names[0]] == 1.0 and eff[names[-1]] == 0.5
    assert all(eff[a] >= eff[b] for a, b in zip(names, names[1:])), (
        f"shed order must be hi-res first: {eff}")
    budgets = kv_block_budgets(pool, 100, {}, 0.5)
    assert budgets[names[0]] == 100 and budgets[names[-1]] == 50
    # used blocks are charged against the class's own cap
    budgets = kv_block_budgets(pool, 100, {names[-1]: 30}, 0.5)
    assert budgets[names[-1]] == 20
    assert kv_block_budgets(pool, 100, {}, 0.0)[names[-1]] == 0


def test_throttled_sheds_hires_kv_before_thumbnail(vlm):
    """At 40% charge (alpha 0.5) a 6-block pool: the largest class's
    budget is int(6*0.5)=3 < the 4-block lifetime need -> gated, while
    the thumbnail class (full scale) admits.  Recovered charge restores
    the hi-res grant."""
    cfg, params = vlm
    pmu = PMU(level=0.4)
    with ServingEngine(cfg, params, n_slots=2, max_len=128,
                       block_size=32, kv_blocks=6,
                       executor=BatteryAwareExecutor(pmu)) as eng:
        hi = _req(cfg, 0, 32, n_images=4, n_new=3)   # largest class
        thumb = _req(cfg, 1, 2, n_images=1, n_new=3)
        eng.submit(hi)
        eng.submit(thumb)
        for _ in range(40):
            if thumb.finish_t is not None:
                break
            eng.step()
        assert thumb.finish_t is not None and thumb.error is None, (
            "thumbnail must keep admitting under THROTTLED")
        assert hi.slot is None and hi.finish_t is None, (
            "hi-res class must be KV-gated at alpha 0.5")
        assert ("kv_gated", hi.rid) in [(e, k) for e, k, _ in eng.trace]
        assert hi.aging > 0
        pmu.level = 1.0                        # charge recovers
        done = eng.run()
        assert hi.error is None and hi.finish_t is not None, (
            f"hi-res request must admit once restored: {hi.error!r}")
        assert len(done) == 2
        eng.slots.check_block_invariants()


# ---------------------------------------------------------------------------
# free-list fix: deque semantics preserved
# ---------------------------------------------------------------------------

def test_slot_free_lists_are_fifo_deques(vlm, lm_cfg):
    from collections import deque
    cfg, _ = vlm
    flat = SlotCache(cfg, n_slots=4, max_len=32)
    paged = _tiny_pool(lm_cfg)
    for pool in (flat, paged):
        assert isinstance(pool.free, deque)
        took = [pool.take_slot() for _ in range(4)]
        assert took == [0, 1, 2, 3]            # FIFO, like list.pop(0)
        assert pool.take_slot() is None
        pool.release(2)
        pool.release(0)
        assert pool.take_slot() == 2           # reuse order = release order
        assert pool.take_slot() == 0
