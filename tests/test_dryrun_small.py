"""Dry-run machinery integration test on a small multi-device mesh.

Runs in a SUBPROCESS because --xla_force_host_platform_device_count must be
set before jax initializes (and the rest of the suite needs 1 device).
Exercises: sharding rules binding, lower+compile of train/prefill/decode on
a (2,4) mesh, roofline extraction — the same path the 512-device production
dry-run takes.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get_config, SHAPES
    from repro.configs.base import ShapeCell
    from repro.launch import dryrun as dr
    import repro.launch.dryrun  # noqa
    from repro.analysis import roofline as rl

    cfg = get_config("{arch}").reduced()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cell = ShapeCell("t", "{kind}", {seq}, {batch})
    with mesh:
        lowered, compiled = dr.lower_cell(cfg, cell, mesh)
    extra = {{}}
    roof = rl.build("{arch}", cell.name, "2x4", 8, compiled, cfg, cell,
                    extra=extra)
    rec = roof.to_dict()
    rec["n_collectives"] = sum(rec["collective_count"].values())
    print("RESULT " + json.dumps(rec))
""")


def _run(arch, kind, seq, batch):
    code = SCRIPT.format(arch=arch, kind=kind, seq=seq, batch=batch)
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600, env=env,
                          cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout
    return json.loads(line[0][len("RESULT "):])


@pytest.mark.slow
def test_train_cell_compiles_on_mesh():
    rec = _run("stablelm-1.6b", "train", 256, 8)
    assert rec["flops_per_device"] > 0
    assert rec["n_collectives"] > 0           # FSDP/TP really communicates
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < rec["useful_flops_ratio"] < 20


@pytest.mark.slow
def test_decode_cell_compiles_on_mesh():
    rec = _run("stablelm-1.6b", "decode", 512, 8)
    assert rec["flops_per_device"] > 0
    assert rec["model_flops"] > 0


@pytest.mark.slow
def test_moe_cell_compiles_on_mesh():
    rec = _run("deepseek-moe-16b", "train", 256, 8)
    # EP dispatch must show up as all-to-all or gather traffic
    assert rec["flops_per_device"] > 0
    assert sum(rec["collective_count"].values()) > 0


@pytest.mark.slow
def test_hybrid_decode_on_mesh():
    rec = _run("jamba-1.5-large-398b", "decode", 512, 8)
    assert rec["flops_per_device"] > 0
