"""TABM ring buffer: state-machine invariants (hypothesis) + data
integrity + producer/consumer smoothing signals + thread-safety
(blocking acquire, close/drain, per-slot events, seqlock generation)
+ the ExecutionPlan.produce abort-on-error regression."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as hst

from repro.core.tabm import (ALLOCATED_FOR_READ, ALLOCATED_FOR_WRITE,
                             CONSUMED, EMPTY, FREE, READY, READY_TO_READ,
                             RingBuffer, STAGING, TABMError)


def make(n=4, tokens=8, dim=16):
    return RingBuffer(n_slots=n, max_tokens=tokens, dim=dim)


def test_legacy_state_aliases():
    """Paper-wording names are the same states (importers keep working)."""
    assert FREE == EMPTY and ALLOCATED_FOR_WRITE == STAGING
    assert READY_TO_READ == READY and ALLOCATED_FOR_READ == CONSUMED


def test_lifecycle_roundtrip():
    rb = make()
    s = rb.acquire_write()
    assert rb.states[s] == STAGING
    data = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
    rb.commit_write(s, data)
    assert rb.states[s] == READY
    slot, view, n = rb.acquire_read()
    assert slot == s and n == 8
    np.testing.assert_allclose(np.asarray(view[:n], np.float32),
                               np.asarray(data), rtol=1e-2)
    rb.release(slot)
    assert rb.states[s] == EMPTY


def test_ring_full_stalls_producer():
    rb = make(n=2)
    a = rb.acquire_write(); rb.commit_write(a, jnp.ones((1, 16)))
    b = rb.acquire_write(); rb.commit_write(b, jnp.ones((1, 16)))
    assert rb.acquire_write() is None          # full -> backpressure signal
    assert rb.stats["stalls"] == 1
    slot, _, _ = rb.acquire_read()
    rb.release(slot)
    assert rb.acquire_write() is not None      # freed -> resumes


def test_fifo_ordering():
    rb = make(n=4)
    payloads = []
    for i in range(3):
        s = rb.acquire_write()
        data = jnp.full((4, 16), float(i))
        rb.commit_write(s, data)
        payloads.append(float(i))
    for expect in payloads:
        slot, view, n = rb.acquire_read()
        assert float(view[0, 0]) == pytest.approx(expect, abs=1e-2)
        rb.release(slot)


def test_abort_write_rewinds_pointer():
    """An aborted acquire must not desync the FIFO: the next producer gets
    the same slot back and reads still come out in commit order."""
    rb = make(n=2)
    s = rb.acquire_write()
    rb.abort_write(s)
    s2 = rb.acquire_write()
    assert s2 == s                             # pointer rewound, not skipped
    rb.commit_write(s2, jnp.full((1, 16), 7.0))
    slot, view, _ = rb.acquire_read()          # read pointer still aligned
    assert slot == s2 and float(view[0, 0]) == pytest.approx(7.0, abs=1e-2)
    rb.release(slot)
    # out-of-order abort is rejected (FIFO ring invariant)
    a = rb.acquire_write()
    b = rb.acquire_write()
    with pytest.raises(TABMError):
        rb.abort_write(a)
    rb.abort_write(b)                          # most recent: fine
    rb.abort_write(a)                          # now the most recent


def test_illegal_transitions_raise():
    rb = make()
    with pytest.raises(TABMError):
        rb.commit_write(0, jnp.ones((1, 16)))  # commit without acquire
    s = rb.acquire_write()
    with pytest.raises(TABMError):
        rb.release(s)                          # release mid-write
    with pytest.raises(TABMError):
        rb.commit_write(s, jnp.ones((100, 16)))  # overflow slot capacity


@given(ops=hst.lists(hst.sampled_from(["w", "r"]), min_size=1, max_size=60))
def test_state_machine_invariants_random_schedules(ops):
    """Any interleaving of producer/consumer ops keeps every slot in a
    legal state and preserves write->read data correspondence."""
    rb = make(n=3, tokens=4, dim=8)
    pending = []                                # (slot, value) committed
    counter = 0
    for op in ops:
        if op == "w":
            s = rb.acquire_write()
            if s is None:
                continue
            val = float(counter); counter += 1
            rb.commit_write(s, jnp.full((2, 8), val))
            pending.append(val)
        else:
            got = rb.acquire_read()
            if got is None:
                continue
            slot, view, n = got
            expect = pending.pop(0)             # FIFO
            assert float(view[0, 0]) == pytest.approx(expect, abs=1e-2)
            rb.release(slot)
        for st in rb.states:
            assert st in (EMPTY, STAGING, READY, CONSUMED)
    assert 0.0 <= rb.occupancy <= 1.0


# ---------------------------------------------------------------------------
# strided slab commits (acquire_write_many / commit_many / abort_many)
# ---------------------------------------------------------------------------

def test_slab_roundtrip_fifo_and_per_slot_lengths():
    """One strided commit covers K slots; reads come out in acquisition
    order with each slot's own true length."""
    rb = make(n=4, tokens=8, dim=16)
    slots = rb.acquire_write_many(3)
    assert slots == [0, 1, 2]
    slab = jnp.stack([jnp.full((8, 16), float(i)) for i in range(3)])
    rb.commit_many(slots, slab, lengths=[3, 8, 5])
    assert rb.stats["writes"] == 3 and rb.stats["slab_commits"] == 1
    for want_val, want_n in [(0.0, 3), (1.0, 8), (2.0, 5)]:
        slot, view, n = rb.acquire_read()
        assert n == want_n
        assert float(view[0, 0]) == pytest.approx(want_val, abs=1e-2)
        # the padded tail beyond the slot's length is zeroed
        if n < rb.max_tokens:
            assert float(jnp.abs(view[n:]).max()) == 0.0
        rb.release(slot)
    assert all(st == EMPTY for st in rb.states)


def test_slab_acquire_full_mid_batch_is_all_or_nothing():
    """FULL mid-batch: a K-slot acquire either gets the whole contiguous
    run or nothing — no partial acquisition ever leaks."""
    rb = make(n=3)
    s = rb.acquire_write()
    rb.commit_write(s, jnp.ones((1, 16)))
    assert rb.acquire_write_many(3) is None    # only 2 free -> all-or-nothing
    assert rb.stats["stalls"] == 1
    assert sum(st == STAGING for st in rb.states) == 0   # nothing half-taken
    got = rb.acquire_write_many(2)             # the free run fits
    assert got == [1, 2]
    rb.abort_many(got)
    with pytest.raises(TABMError):             # K > capacity is a caller bug
        rb.acquire_write_many(4)
    slot, _, _ = rb.acquire_read()
    rb.release(slot)
    assert rb.acquire_write_many(3) is not None  # wrap-around run works


def test_slab_blocking_acquire_waits_for_whole_run():
    """A producer parked for K slots resumes only once the whole run is
    free (consumer releases), and close() wakes it with None."""
    rb = make(n=2)
    a = rb.acquire_write(); rb.commit_write(a, jnp.ones((1, 16)))
    got = []
    t = threading.Thread(
        target=lambda: got.append(rb.acquire_write_many(
            2, block=True, timeout=30.0)))
    t.start(); time.sleep(0.05)
    assert not got                             # one slot busy: still parked
    slot, _, _ = rb.acquire_read()
    rb.release(slot)                           # whole ring free now
    t.join(30.0)
    assert got and got[0] is not None and len(got[0]) == 2


def test_slab_partial_abort_rejected_full_abort_rewinds():
    """abort-all-on-failure: the whole run rewinds (write pointer back to
    the first slot); aborting a strict subset out of order is rejected —
    the FIFO invariant commit order == read order survives failures."""
    rb = make(n=4)
    slots = rb.acquire_write_many(3)
    with pytest.raises(TABMError):
        rb.abort_many(slots[:2])               # not the most recent run
    with pytest.raises(TABMError):
        rb.abort_many([slots[0], slots[2]])    # not contiguous
    rb.abort_many(slots)
    assert rb.stats["aborts"] == 3
    assert all(st == EMPTY for st in rb.states)
    again = rb.acquire_write_many(2)
    assert again == slots[:2]                  # pointer rewound, not skipped
    rb.commit_many(again, jnp.ones((2, 4, 16)))
    s0, _, _ = rb.acquire_read()
    assert s0 == slots[0]                      # read pointer still aligned
    rb.release(s0)


def test_slab_commit_validates_run_and_capacity():
    rb = make(n=4, tokens=8)
    slots = rb.acquire_write_many(2)
    with pytest.raises(TABMError):             # oversized slab
        rb.commit_many(slots, jnp.ones((2, 9, 16)))
    with pytest.raises(TABMError):             # length beyond slab width
        rb.commit_many(slots, jnp.ones((2, 4, 16)), lengths=[4, 6])
    with pytest.raises(TABMError):             # slab/run size mismatch
        rb.commit_many(slots, jnp.ones((3, 4, 16)))
    with pytest.raises(TABMError):             # non-contiguous run
        rb.commit_many([slots[0], (slots[1] + 1) % 4],
                       jnp.ones((2, 4, 16)))
    rb.commit_many(slots, jnp.ones((2, 4, 16)))  # the valid commit works
    with pytest.raises(TABMError):             # double commit: not STAGING
        rb.commit_many(slots, jnp.ones((2, 4, 16)))


def test_slab_commit_fires_per_slot_ready_events_with_generation_check():
    """Each slot of a slab commit wakes its own wait_ready waiter — and a
    slot recycled after abort_many never satisfies the old lifecycle's
    wait (generation checks hold across strided ops)."""
    rb = make(n=4)
    slots = rb.acquire_write_many(2)
    results = {}
    threads = [threading.Thread(
        target=lambda s=s: results.__setitem__(
            s, rb.wait_ready(s, timeout=30.0))) for s in slots]
    for t in threads:
        t.start()
    time.sleep(0.05)
    rb.commit_many(slots, jnp.ones((2, 2, 16)))
    for t in threads:
        t.join(30.0)
    assert results == {slots[0]: True, slots[1]: True}
    # drain, then: an aborted slab ends waits with False
    for _ in slots:
        s, _, _ = rb.acquire_read()
        rb.release(s)
    slots2 = rb.acquire_write_many(2)
    out = []
    t = threading.Thread(
        target=lambda: out.append(rb.wait_ready(slots2[0], timeout=30.0)))
    t.start(); time.sleep(0.05)
    rb.abort_many(slots2)
    t.join(30.0)
    assert out == [False]
    # recycle: a later lifecycle's slab commit must not satisfy a wait
    # captured before the abort (generation arithmetic)
    g0 = rb.slot_generation(slots2[0])
    slots3 = rb.acquire_write_many(2)
    rb.commit_many(slots3, jnp.ones((2, 2, 16)))
    assert rb.slot_generation(slots3[0]) != g0


# ---------------------------------------------------------------------------
# thread-safety: the async producer/consumer contract
# ---------------------------------------------------------------------------

def test_blocking_acquire_write_unblocks_on_release():
    """A producer parked on a FULL ring resumes when the consumer frees a
    slot — backpressure stalls the producer thread, not a polling loop."""
    rb = make(n=1)
    s = rb.acquire_write(); rb.commit_write(s, jnp.ones((1, 16)))
    got = []

    def producer():
        got.append(rb.acquire_write(block=True, timeout=10.0))

    t = threading.Thread(target=producer)
    t.start()
    time.sleep(0.05)                           # producer is now parked
    assert not got
    slot, _, _ = rb.acquire_read()
    rb.release(slot)                           # frees the ring
    t.join(10.0)
    assert got and got[0] == s                 # same head slot, FIFO kept
    assert rb.stats["stalls"] >= 1


def test_close_wakes_blocked_producer_and_consumer():
    rb = make(n=1)
    s = rb.acquire_write(); rb.commit_write(s, jnp.ones((1, 16)))
    results = {}

    def producer():
        results["w"] = rb.acquire_write(block=True, timeout=10.0)

    t = threading.Thread(target=producer)
    t.start()
    time.sleep(0.05)
    rb.close()                                 # shutdown: wake everyone
    t.join(10.0)
    assert not t.is_alive() and results["w"] is None
    assert rb.acquire_read(block=True, timeout=0.1) is None   # closed


def test_per_slot_ready_event():
    rb = make(n=2)
    s = rb.acquire_write()
    assert not rb.wait_ready(s, timeout=0.02)  # not committed yet
    rb.commit_write(s, jnp.ones((1, 16)))
    assert rb.wait_ready(s, timeout=1.0)       # event fired at commit
    slot, _, _ = rb.acquire_read()
    assert rb.wait_ready(slot, timeout=0)      # CONSUMED still counts
    rb.release(slot)


def test_wait_ready_unblocks_on_abort_and_close():
    """A waiter must never hang on a slot that will no longer commit:
    abort_write (generation bump) and close() both end the wait, False."""
    rb = make(n=2)
    s = rb.acquire_write()
    out = []
    t = threading.Thread(
        target=lambda: out.append(rb.wait_ready(s, timeout=10.0)))
    t.start(); time.sleep(0.05)
    rb.abort_write(s)                          # producer gave up
    t.join(10.0)
    assert not t.is_alive() and out == [False]
    s2 = rb.acquire_write()
    out2 = []
    t2 = threading.Thread(
        target=lambda: out2.append(rb.wait_ready(s2, timeout=10.0)))
    t2.start(); time.sleep(0.05)
    rb.close()                                 # shutdown
    t2.join(10.0)
    assert not t2.is_alive() and out2 == [False]


def test_generation_seqlock_view_validity():
    """A consumer's zero-copy view is valid exactly while its slot stays
    CONSUMED at the captured generation — recycling invalidates it."""
    rb = make(n=2)
    s = rb.acquire_write(); rb.commit_write(s, jnp.full((2, 16), 3.0))
    slot, view, n = rb.acquire_read()
    gen = rb.slot_generation(slot)
    assert rb.view_valid(slot, gen)
    rb.release(slot)
    assert not rb.view_valid(slot, gen)        # recycled underneath
    # the slot's next lifecycle has a different generation
    s2 = rb.acquire_write()
    assert rb.slot_generation(s2) != gen


def test_drain_releases_ready_and_consumed():
    rb = make(n=4)
    for i in range(3):
        s = rb.acquire_write()
        rb.commit_write(s, jnp.full((1, 16), float(i)))
    rb.acquire_read()                          # one CONSUMED, two READY
    assert rb.drain() == 3
    assert all(st == EMPTY for st in rb.states)
    # a STAGING slot belongs to the producer: drain refuses
    rb2 = make(n=2)
    rb2.acquire_write()
    with pytest.raises(TABMError):
        rb2.drain()


def test_threaded_producer_consumer_fifo_integrity():
    """One producer thread + one consumer thread hammer a tiny ring; every
    payload arrives exactly once, in order, and the ring ends EMPTY."""
    rb = make(n=2, tokens=2, dim=8)
    N = 16
    seen = []

    def producer():
        for i in range(N):
            s = rb.acquire_write(block=True, timeout=30.0)
            assert s is not None
            rb.commit_write(s, jnp.full((1, 8), float(i)))

    def consumer():
        while len(seen) < N:
            got = rb.acquire_read(block=True, timeout=30.0)
            assert got is not None
            slot, view, _ = got
            seen.append(round(float(view[0, 0])))
            rb.release(slot)

    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start()
    tp.join(60.0); tc.join(60.0)
    assert not tp.is_alive() and not tc.is_alive()
    assert seen == list(range(N))
    assert all(st == EMPTY for st in rb.states)


# ---------------------------------------------------------------------------
# regression: a failing projector must not wedge the ring
# ---------------------------------------------------------------------------

def test_produce_error_aborts_slot_regression(key):
    """ExecutionPlan.produce used to be able to leave a slot in STAGING
    forever when an upstream brick raised; the write must be aborted (slot
    back to EMPTY) and the error surfaced to the caller, after which the
    ring still works."""
    from repro.configs import get_config
    from repro.core.bricks import decompose
    from repro.core.plan import compile_plan
    from repro.launch.steps import init_params

    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(key, cfg)
    ring = RingBuffer(n_slots=2, max_tokens=cfg.vision_tokens,
                      dim=cfg.d_model)
    plan = compile_plan(decompose(cfg), params, tabm=ring)

    boom = plan.steps[plan._tabm_producer].fn

    def raising_projector(p, ctx):
        raise RuntimeError("projector exploded")

    plan.steps[plan._tabm_producer].fn = raising_projector
    feats = jnp.ones((1, cfg.vision_tokens, cfg.vision_feat_dim),
                     jnp.float32)
    with pytest.raises(RuntimeError, match="projector exploded"):
        plan.produce({"vision_feats": feats})
    assert all(st == EMPTY for st in ring.states)      # aborted, not wedged
    assert ring.stats["aborts"] == 1

    plan.steps[plan._tabm_producer].fn = boom          # restore
    slot = plan.produce({"vision_feats": feats})       # ring still usable
    assert slot is not None
    got = plan.consume()
    assert got is not None and got[0] == slot
    plan.release(slot)
    assert all(st == EMPTY for st in ring.states)
