"""TABM ring buffer: state-machine invariants (hypothesis) + data
integrity + producer/consumer smoothing signals."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as hst

from repro.core.tabm import (ALLOCATED_FOR_READ, ALLOCATED_FOR_WRITE, FREE,
                             READY_TO_READ, RingBuffer, TABMError)


def make(n=4, tokens=8, dim=16):
    return RingBuffer(n_slots=n, max_tokens=tokens, dim=dim)


def test_lifecycle_roundtrip():
    rb = make()
    s = rb.acquire_write()
    assert rb.states[s] == ALLOCATED_FOR_WRITE
    data = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
    rb.commit_write(s, data)
    assert rb.states[s] == READY_TO_READ
    slot, view, n = rb.acquire_read()
    assert slot == s and n == 8
    np.testing.assert_allclose(np.asarray(view[:n], np.float32),
                               np.asarray(data), rtol=1e-2)
    rb.release(slot)
    assert rb.states[s] == FREE


def test_ring_full_stalls_producer():
    rb = make(n=2)
    a = rb.acquire_write(); rb.commit_write(a, jnp.ones((1, 16)))
    b = rb.acquire_write(); rb.commit_write(b, jnp.ones((1, 16)))
    assert rb.acquire_write() is None          # full -> backpressure signal
    assert rb.stats["stalls"] == 1
    slot, _, _ = rb.acquire_read()
    rb.release(slot)
    assert rb.acquire_write() is not None      # freed -> resumes


def test_fifo_ordering():
    rb = make(n=4)
    payloads = []
    for i in range(3):
        s = rb.acquire_write()
        data = jnp.full((4, 16), float(i))
        rb.commit_write(s, data)
        payloads.append(float(i))
    for expect in payloads:
        slot, view, n = rb.acquire_read()
        assert float(view[0, 0]) == pytest.approx(expect, abs=1e-2)
        rb.release(slot)


def test_abort_write_rewinds_pointer():
    """An aborted acquire must not desync the FIFO: the next producer gets
    the same slot back and reads still come out in commit order."""
    rb = make(n=2)
    s = rb.acquire_write()
    rb.abort_write(s)
    s2 = rb.acquire_write()
    assert s2 == s                             # pointer rewound, not skipped
    rb.commit_write(s2, jnp.full((1, 16), 7.0))
    slot, view, _ = rb.acquire_read()          # read pointer still aligned
    assert slot == s2 and float(view[0, 0]) == pytest.approx(7.0, abs=1e-2)
    rb.release(slot)
    # out-of-order abort is rejected (FIFO ring invariant)
    a = rb.acquire_write()
    b = rb.acquire_write()
    with pytest.raises(TABMError):
        rb.abort_write(a)
    rb.abort_write(b)                          # most recent: fine
    rb.abort_write(a)                          # now the most recent


def test_illegal_transitions_raise():
    rb = make()
    with pytest.raises(TABMError):
        rb.commit_write(0, jnp.ones((1, 16)))  # commit without acquire
    s = rb.acquire_write()
    with pytest.raises(TABMError):
        rb.release(s)                          # release mid-write
    with pytest.raises(TABMError):
        rb.commit_write(s, jnp.ones((100, 16)))  # overflow slot capacity


@given(ops=hst.lists(hst.sampled_from(["w", "r"]), min_size=1, max_size=60))
def test_state_machine_invariants_random_schedules(ops):
    """Any interleaving of producer/consumer ops keeps every slot in a
    legal state and preserves write->read data correspondence."""
    rb = make(n=3, tokens=4, dim=8)
    pending = []                                # (slot, value) committed
    counter = 0
    for op in ops:
        if op == "w":
            s = rb.acquire_write()
            if s is None:
                continue
            val = float(counter); counter += 1
            rb.commit_write(s, jnp.full((2, 8), val))
            pending.append(val)
        else:
            got = rb.acquire_read()
            if got is None:
                continue
            slot, view, n = got
            expect = pending.pop(0)             # FIFO
            assert float(view[0, 0]) == pytest.approx(expect, abs=1e-2)
            rb.release(slot)
        for st in rb.states:
            assert st in (FREE, ALLOCATED_FOR_WRITE, READY_TO_READ,
                          ALLOCATED_FOR_READ)
    assert 0.0 <= rb.occupancy <= 1.0
