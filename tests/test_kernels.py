"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle.

Every kernel is validated over a grid of shapes, dtypes, and its tiling
parameters, per the brief.  interpret=True executes the kernel body in
Python on CPU; the BlockSpecs/grids are the TPU-target ones.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import QuantSpec, quantize
from repro.kernels.dequant_gemm import dequant_gemm, ref_dequant_gemm
from repro.kernels.flash_attention import flash_attention, ref_attention
from repro.kernels.linear_attention import (linear_attention,
                                            ref_linear_attention)
from repro.kernels.ssd import ref_ssd, ssd


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))


# ---------------------------------------------------------------------------
# dequant-GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("mkn", [(64, 512, 128), (8, 1024, 256),
                                 (130, 512, 200)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_dequant_gemm_matches_ref(key, bits, mkn, dtype):
    M, K, N = mkn
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (M, K), jnp.float32).astype(dtype)
    w = (jax.random.normal(k2, (N, K), jnp.float32) * 0.05).astype(dtype)
    qt = quantize(w, QuantSpec(bits))
    out = dequant_gemm(x, qt, use_kernel=True, interpret=True)
    ref = ref_dequant_gemm(x, qt)
    assert out.shape == (M, N) and out.dtype == dtype
    assert _rel_err(out, ref) < 5e-3


@pytest.mark.parametrize("act", ["relu", "silu", "gelu", "squared_relu"])
def test_dequant_gemm_fused_epilogue(key, act):
    x = jax.random.normal(key, (32, 512), jnp.float32)
    w = jax.random.normal(key, (128, 512), jnp.float32) * 0.1
    qt = quantize(w, QuantSpec(4))
    bias = jnp.linspace(-0.5, 0.5, 128, dtype=jnp.float32)
    out = dequant_gemm(x, qt, bias, act, use_kernel=True, interpret=True)
    ref = ref_dequant_gemm(x, qt, bias, act)
    assert _rel_err(out, ref) < 5e-3


@pytest.mark.parametrize("group_size", [32, 64, 128])
def test_dequant_gemm_group_sizes(key, group_size):
    x = jax.random.normal(key, (16, 512), jnp.float32)
    w = jax.random.normal(key, (64, 512), jnp.float32) * 0.2
    qt = quantize(w, QuantSpec(4, group_size=group_size))
    out = dequant_gemm(x, qt, use_kernel=True, interpret=True, bk=256)
    assert _rel_err(out, ref_dequant_gemm(x, qt)) < 5e-3


def test_dequant_gemm_3d_input(key):
    x = jax.random.normal(key, (2, 16, 512), jnp.float32)
    w = jax.random.normal(key, (64, 512), jnp.float32) * 0.1
    qt = quantize(w, QuantSpec(4))
    out = dequant_gemm(x, qt, use_kernel=True, interpret=True)
    assert out.shape == (2, 16, 64)
    assert _rel_err(out, ref_dequant_gemm(x, qt)) < 5e-3


# ---------------------------------------------------------------------------
# linear attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 128, 4, 32), (1, 256, 2, 64),
                                   (3, 64, 5, 16)])
@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_attention_matches_ref(key, shape, chunk, dtype):
    B, S, H, hd = shape
    ks = jax.random.split(key, 3)
    q = (jax.random.normal(ks[0], shape, jnp.float32) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], shape, jnp.float32) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], shape, jnp.float32).astype(dtype)
    out, state, z = linear_attention(q, k, v, chunk=chunk, interpret=True)
    ref_o, ref_s, ref_z = ref_linear_attention(q, k, v)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    assert _rel_err(out, ref_o) < tol
    assert _rel_err(state, ref_s) < tol
    assert _rel_err(z, ref_z) < tol


def test_linear_attention_stream_continuation(key):
    """Kernel prefill state + paper's single-matvec decode == one long
    prefill: the stream is exact across the prefill/decode boundary."""
    from repro.models.linear_attention import linear_attn_decode
    B, S, H, hd = 1, 128, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S + 4, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S + 4, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S + 4, H, hd))
    _, state, z = linear_attention(q[:, :S], k[:, :S], v[:, :S],
                                   chunk=32, interpret=True)
    full, _, _ = ref_linear_attention(q, k, v)
    for t in range(S, S + 4):
        o, state, z = linear_attn_decode(q[:, t:t+1], k[:, t:t+1],
                                         v[:, t:t+1], state, z)
        assert _rel_err(o[:, 0], full[:, t]) < 1e-4


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 128, 4, 32, 1, 32),
                                   (1, 256, 8, 64, 2, 64),
                                   (2, 64, 4, 16, 4, 16)])
@pytest.mark.parametrize("chunk", [32, 64])
def test_ssd_matches_ref(key, shape, chunk):
    B, S, H, P, G, N = shape
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    ky, kh = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ry, rh = ref_ssd(x, dt, A, Bm, Cm)
    assert _rel_err(ky, ry) < 1e-4
    assert _rel_err(kh, rh) < 1e-4


def test_ssd_state_continuation(key):
    """Kernel final state continues exactly through the sequential
    decode-step recurrence (prefill -> decode boundary)."""
    from repro.models.mamba2 import ssd_decode_step
    B, S, H, P, G, N = 1, 64, 2, 16, 1, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S + 3, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 3, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S + 3, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S + 3, G, N)) * 0.3
    _, h = ssd(x[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S],
               chunk=32, interpret=True)
    ry, _ = ref_ssd(x, dt, A, Bm, Cm)
    rep = H // G
    for t in range(S, S + 3):
        y, h = ssd_decode_step(h, x[:, t], dt[:, t], A,
                               jnp.repeat(Bm[:, t], rep, 1)[:, :G],
                               Cm[:, t])
        assert _rel_err(y, ry[:, t]) < 1e-4


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 128, 4, 2, 32), (1, 256, 8, 8, 64),
                                   (2, 256, 6, 2, 32), (1, 128, 32, 4, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(key, shape, dtype):
    B, S, H, KV, hd = shape
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    ref = ref_attention(q, k, v)
    assert _rel_err(out, ref) < (2e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_flash_attention_noncausal(key):
    B, S, H, KV, hd = 1, 128, 4, 4, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64,
                          interpret=True)
    assert _rel_err(out, ref_attention(q, k, v, causal=False)) < 1e-4


@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(key, blocks):
    bq, bk = blocks
    B, S, H, KV, hd = 1, 128, 2, 1, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    assert _rel_err(out, ref_attention(q, k, v)) < 1e-4
