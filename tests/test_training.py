"""Training substrate: optimizer, loop, checkpoints, compression, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as hst

from repro.configs import get_config
from repro.data import PackedLMDataset, ShardedLoader, multimodal_batch_iter
from repro.distributed import checkpoint as ck
from repro.distributed.compression import (ErrorFeedback, compress,
                                           decompress)
from repro.launch.steps import init_params
from repro.training.optimizer import OptConfig, adamw_update, init_opt, \
    schedule_lr
from repro.training.train_loop import (TrainConfig, build_accum_train_step,
                                       fit)


def test_loss_decreases_and_resume_equivalence(key):
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    with tempfile.TemporaryDirectory() as d:
        it = multimodal_batch_iter(cfg, global_batch=4, seq_len=64)
        res = fit(cfg, oc,
                  TrainConfig(steps=10, ckpt_dir=d, ckpt_every=5,
                              log_every=100), it)
        assert res.metrics_history[-1]["loss"] < res.metrics_history[0]["loss"]
        # crash + restart: resumes from step 10
        it2 = multimodal_batch_iter(cfg, global_batch=4, seq_len=64)
        res2 = fit(cfg, oc,
                   TrainConfig(steps=12, ckpt_dir=d, ckpt_every=5,
                               log_every=100), it2)
        assert res2.recovery.events[0]["kind"] == "restore"
        assert res2.metrics_history[0]["step"] == 11


def test_grad_accum_matches_full_batch(key):
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    params = init_params(key, cfg)
    oc = OptConfig(lr=1e-3)
    batch = {"tokens": (jnp.arange(4 * 64).reshape(4, 64) % 60 + 3
                        ).astype(jnp.int32)}
    p1, _, m1 = jax.jit(build_accum_train_step(cfg, oc, 1))(
        params, init_opt(params, oc), batch)
    p2, _, m2 = jax.jit(build_accum_train_step(cfg, oc, 2))(
        params, init_opt(params, oc), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-2, atol=1e-4)


def test_lr_schedule_shapes():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                   schedule="cosine", min_lr_frac=0.1)
    lrs = [float(schedule_lr(oc, jnp.asarray(s))) for s in
           (1, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] >= 1e-4 * 0.99


def test_weight_decay_mask(key):
    """Norm scales / biases are exempt from decoupled weight decay."""
    cfg = get_config("stablelm-1.6b").reduced(n_layers=1)
    params = init_params(key, cfg)
    # large effective decay so the bf16 weights move visibly in one step
    oc = OptConfig(lr=0.1, weight_decay=1.0, warmup_steps=0,
                   schedule="constant")
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(params, zeros, init_opt(params, oc), oc)
    # with zero grads, decayed leaves shrink; exempt leaves unchanged
    scale_before = params["final_norm"]["scale"]
    scale_after = p2["final_norm"]["scale"]
    np.testing.assert_array_equal(np.asarray(scale_after),
                                  np.asarray(scale_before))
    w_before = params["lm_head"]
    w_after = p2["lm_head"]
    assert float(jnp.mean(jnp.abs(w_after))) < float(
        jnp.mean(jnp.abs(w_before)))


def test_bf16_optimizer_states(key):
    cfg = get_config("stablelm-1.6b").reduced(n_layers=1)
    params = init_params(key, cfg)
    oc = OptConfig(state_dtype="bfloat16")
    opt = init_opt(params, oc)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(opt["m"]))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    step = jax.jit(build_accum_train_step(cfg, oc, 1))
    p2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(opt2["m"]))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

@given(seed=hst.integers(0, 1000))
def test_checkpoint_roundtrip_mixed_dtypes(seed):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((3, 4)), jnp.bfloat16),
            "b": (jnp.arange(5, dtype=jnp.int32),
                  {"c": jnp.asarray(rng.standard_normal(7), jnp.float32)}),
            "step": jnp.asarray(seed, jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 7, tree)
        got, step, _ = ck.restore(d, tree)
        assert step == 7
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ck.save(d, s, {"x": jnp.ones(3)}, keep=2)
        assert ck.latest_step(d) == 5
        steps = sorted(int(p.split("_")[1]) for p in os.listdir(d))
        assert steps == [4, 5]


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        acp = ck.AsyncCheckpointer(d)
        acp.save_async(3, {"x": jnp.arange(10)})
        acp.wait()
        got, step, _ = ck.restore(d, {"x": jnp.arange(10)})
        assert step == 3


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@given(seed=hst.integers(0, 500), scale=hst.floats(1e-4, 1e3))
def test_compress_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((3, 130)) * scale, jnp.float32)
    q, s = compress(g)
    dq = decompress(q, s, g.shape, g.dtype)
    blocks, _ = np.asarray(g).reshape(-1), None
    # per-block bound: amax/127/2 (round-to-nearest)
    gb = np.pad(np.asarray(g).reshape(-1), (0, (-g.size) % 256))
    gb = gb.reshape(-1, 256)
    bound = np.abs(gb).max(1) / 127 / 2 + 1e-7
    err = np.abs(np.asarray(dq) - np.asarray(g)).reshape(-1)
    err = np.pad(err, (0, (-g.size) % 256)).reshape(-1, 256).max(1)
    assert np.all(err <= bound + 1e-6)


def test_error_feedback_preserves_signal(key):
    """Sum of compressed grads with error feedback tracks the true sum."""
    ef = ErrorFeedback()
    rng = np.random.default_rng(0)
    true_sum = None
    fed_sum = None
    for _ in range(20):
        g = {"w": jnp.asarray(rng.standard_normal((64,)) * 0.1, jnp.float32)}
        dq = ef.apply(g)
        true_sum = g["w"] if true_sum is None else true_sum + g["w"]
        fed_sum = dq["w"] if fed_sum is None else fed_sum + dq["w"]
    resid = float(jnp.max(jnp.abs(true_sum - fed_sum)))
    # residual memory keeps the drift bounded by ~one quantization step
    assert resid < 0.1 * float(jnp.max(jnp.abs(true_sum)))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_seek():
    ds1 = PackedLMDataset(1000, 64, seed=5)
    l1 = ShardedLoader(ds1, global_batch=4)
    first = [next(l1) for _ in range(3)]
    ds2 = PackedLMDataset(1000, 64, seed=5)
    l2 = ShardedLoader(ds2, global_batch=4)
    l2.seek(2)
    replay = next(l2)
    np.testing.assert_array_equal(first[2]["tokens"], replay["tokens"])


def test_host_sharding_partitions_batch():
    full = ShardedLoader(PackedLMDataset(1000, 32, seed=1), global_batch=4)
    b_full = next(full)
    h0 = ShardedLoader(PackedLMDataset(1000, 32, seed=1), global_batch=4,
                       host_id=0, n_hosts=2)
    h1 = ShardedLoader(PackedLMDataset(1000, 32, seed=1), global_batch=4,
                       host_id=1, n_hosts=2)
    b0, b1 = next(h0), next(h1)
    merged = np.empty_like(b_full["tokens"])
    merged[0::2] = b0["tokens"]
    merged[1::2] = b1["tokens"]
    np.testing.assert_array_equal(merged, b_full["tokens"])
