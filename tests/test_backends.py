"""Backend lowering API: one Placement, many substrates (core/backends).

Covers the api_redesign acceptance criteria:
* the same BrickGraph + Placement lowered via SubmeshBackend,
  DeviceBackend, and HostBackend produces identical greedy tokens through
  ServingEngine (and identical plan.run logits);
* cascade max-not-sum residency holds on the HostBackend lowering;
* the module-level jit cache is shared across compile_plan calls — the
  engine/cascade/scheduler paths reuse compiled executables (the old
  per-plan ``_make_fn`` lambda bug);
* kernels/dispatch: one TPU check, REPRO_FORCE_REF override, force_ref
  scope, and HostBackend executables pinned to the reference path;
* Accelerator.backend -> schedule() -> Placement.backends carry-through;
* plan.relower + PowerPolicy.knobs.backend_demotion (the THROTTLED
  re-lowering hook) change the substrate without changing the numbers.
"""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.energy import TPU_V5E
from repro.configs import get_config
from repro.core import backends as B
from repro.core.backends import (BACKENDS, BackendError, HostBackend,
                                 jit_cache_len, resolve_backend)
from repro.core.bricks import decompose
from repro.core.plan import compile_plan
from repro.core.power import PowerPolicy
from repro.core.scheduler import (Accelerator, edge_accelerators,
                                  populate_brick_bytes, schedule)
from repro.kernels import dispatch
from repro.launch.steps import init_params
from repro.models.model import lm_forward
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def vlm():
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _submesh_accels():
    """Two submesh accelerators over the test container's single device —
    enough to drive the SubmeshBackend lowering (NamedSharding binds +
    SubmeshPipe edges); the 8-device split runs in scripts/check.sh."""
    mesh = jax.make_mesh((1,), ("model",))
    return [
        Accelerator("enc", TPU_V5E, static_only=True, dynamic_ok=False,
                    mesh=mesh, backend="submesh"),
        Accelerator("dec", TPU_V5E, mesh=mesh, backend="submesh"),
    ]


def _static_assignment(cfg):
    return {b.name: ("enc" if b.static_shape else "dec")
            for b in decompose(cfg).bricks}


def _reqs(cfg, n=3, n_new=5):
    rng = np.random.default_rng(0)
    return [Request(
        rid=i, tokens=(np.arange(6 + i) % 50 + 3).astype(np.int32),
        max_new_tokens=n_new,
        vision_feats=rng.standard_normal(
            (1, cfg.vision_tokens, cfg.vision_feat_dim)
        ).astype(np.float32) * 0.02) for i in range(n)]


# ---------------------------------------------------------------------------
# the tentpole: same graph, swappable substrate
# ---------------------------------------------------------------------------

def test_plan_logits_identical_across_backends(vlm):
    """One BrickGraph lowered through all three backends returns the
    monolithic forward's logits."""
    cfg, params = vlm
    rng = np.random.default_rng(0)
    inputs = {"tokens": jnp.asarray(rng.integers(3, 200, (1, 24)),
                                    jnp.int32),
              "vision_feats": jnp.asarray(
                  rng.standard_normal(
                      (1, cfg.vision_tokens, cfg.vision_feat_dim)) * 0.02,
                  jnp.float32)}
    mono, _ = lm_forward(params, cfg, inputs["tokens"],
                         vision_feats=inputs["vision_feats"])
    mono = np.asarray(mono, np.float32)

    lowerings = {
        "device": dict(backend="device"),
        "host": dict(backend="host"),
        "submesh": dict(placement=_static_assignment(cfg),
                        accels=_submesh_accels()),
    }
    for name, kw in lowerings.items():
        plan = compile_plan(decompose(cfg), params, **kw)
        assert all(s.backend.name == name for s in plan.steps), name
        out, _ = plan.run(inputs)
        np.testing.assert_allclose(np.asarray(out, np.float32), mono,
                                   rtol=2e-2, atol=2e-2, err_msg=name)


def test_engine_greedy_tokens_identical_across_backends(vlm):
    """The issue's equivalence criterion: identical greedy tokens through
    ServingEngine whichever substrate the plan lowered to."""
    cfg, params = vlm
    results = {}
    for name, kw in [("device", dict(backend="device")),
                     ("host", dict(backend="host")),
                     ("submesh", dict(placement=_static_assignment(cfg),
                                      accels=_submesh_accels()))]:
        with ServingEngine(cfg, params, n_slots=2, max_len=128,
                           **kw) as eng:
            for r in _reqs(cfg):
                eng.submit(r)
            done = eng.run()
            assert all(r.error is None for r in done), name
            results[name] = {r.rid: tuple(r.out_tokens) for r in done}
    assert results["device"] == results["host"] == results["submesh"]
    assert all(results["device"][i] for i in range(3))


def test_cascade_max_not_sum_on_host_backend(vlm):
    """HostBackend is the cascade policy: load -> execute -> release per
    brick on the pinned host thread; peak residency stays max-not-sum and
    returns to zero."""
    cfg, params = vlm
    plan = compile_plan(decompose(cfg), params, backend="host")
    assert all(not s.backend.resident for s in plan.steps)
    rng = np.random.default_rng(0)
    _, trace = plan.run({
        "tokens": jnp.asarray(rng.integers(3, 200, (1, 16)), jnp.int32),
        "vision_feats": jnp.asarray(
            rng.standard_normal(
                (1, cfg.vision_tokens, cfg.vision_feat_dim)) * 0.02,
            jnp.float32)})
    for b in plan.graph.names():
        phases = [(e.brick, e.phase) for e in trace.events]
        assert (b, "load") in phases and (b, "release") in phases
    assert trace.events[-1].resident_bytes == 0
    assert 0 < trace.peak_bytes < trace.sum_bytes
    # execution really went through the backend's pinned thread
    host = BACKENDS["host"]
    assert host._pool is not None and host._pool_tids
    assert any(t.name.startswith("host-backend")
               for t in threading.enumerate())


# ---------------------------------------------------------------------------
# satellite: shared jit cache (the old per-plan _make_fn lambda bug)
# ---------------------------------------------------------------------------

def test_jit_cache_shared_across_compile_plan_calls(vlm):
    """Two compile_plan calls over equal (brick, cfg) keys must reuse the
    cached executables — no fresh jax.jit per plan, so engine, cascade,
    and scheduler plans share compiled functions."""
    cfg, params = vlm
    plan_a = compile_plan(decompose(cfg), params, backend="device")
    n_after_first = jit_cache_len()
    plan_b = compile_plan(decompose(cfg), params, backend="device")
    assert jit_cache_len() == n_after_first          # pure cache hits
    for sa, sb in zip(plan_a.steps, plan_b.steps):
        assert sa.fn is sb.fn, sa.brick.name         # the same executable
    # a different kernel mode is a different executable (host = ref path),
    # but re-lowering to host twice is again pure cache hits
    plan_h = compile_plan(decompose(cfg), params, backend="host")
    n_after_host = jit_cache_len()
    plan_h2 = compile_plan(decompose(cfg), params, backend="host")
    assert jit_cache_len() == n_after_host
    assert B.brick_executable(plan_h.steps[0].brick, cfg, "ref") \
        is B.brick_executable(plan_h2.steps[0].brick, cfg, "ref")


# ---------------------------------------------------------------------------
# satellite: one kernel dispatch helper
# ---------------------------------------------------------------------------

def test_kernel_dispatch_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
    on_tpu = dispatch.on_tpu()
    # explicit caller choice always wins
    assert dispatch.resolve_interpret(True) is True
    assert dispatch.resolve_interpret(False) is False
    # default: interpret off-TPU, compiled on TPU
    assert dispatch.resolve_interpret(None) is (not on_tpu)
    # env var forces the reference path everywhere
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    assert dispatch.resolve_interpret(None) is True
    monkeypatch.setenv("REPRO_FORCE_REF", "0")
    assert dispatch.resolve_interpret(None) is (not on_tpu)
    # the scoped (thread-local, re-entrant) override HostBackend uses
    with dispatch.force_ref():
        assert dispatch.resolve_interpret(None) is True
        with dispatch.force_ref():
            assert dispatch.resolve_interpret(None) is True
        assert dispatch.resolve_interpret(None) is True
    assert dispatch.resolve_interpret(None) is (not on_tpu)


def test_ops_share_the_dispatch_helper():
    """No kernel wrapper keeps a private jax.default_backend() check."""
    import inspect
    import repro.kernels.cache_update.ops as c
    import repro.kernels.dequant_gemm.ops as d
    import repro.kernels.flash_attention.ops as f
    import repro.kernels.linear_attention.ops as l
    import repro.kernels.ssd.ops as s
    for mod in (c, d, f, l, s):
        src = inspect.getsource(mod)
        assert "default_backend" not in src, mod.__name__
        assert "resolve_interpret" in src, mod.__name__


# ---------------------------------------------------------------------------
# carry-through: Accelerator.backend -> schedule() -> Placement.backends
# ---------------------------------------------------------------------------

def test_accelerator_backend_profile_carries_into_placement(vlm):
    cfg, params = vlm
    accels = edge_accelerators()
    assert {a.name: a.backend_name() for a in accels} == {
        "npu": "host", "gpu": "device", "cpu": "host"}
    graph = decompose(cfg)
    populate_brick_bytes(graph, params)
    pl = schedule(graph, accels, n_tokens=24)
    assert set(pl.backends) == set(pl.assignment) == set(graph.names())
    by_name = {a.name: a for a in accels}
    for brick, acc in pl.assignment.items():
        assert pl.backends[brick] == by_name[acc].backend_name()
    # and compile_plan lowers each brick through the carried backend
    plan = compile_plan(graph, params, placement=pl, accels=accels)
    for s in plan.steps:
        assert s.backend.name == pl.backends[s.brick.name]


def test_one_brick_rejects_resident_override(vlm):
    """residency='one-brick' promises max-not-sum memory; a resident
    backend override would silently break that, so it must be an error."""
    from repro.core.plan import PlanError
    cfg, params = vlm
    with pytest.raises(PlanError):
        compile_plan(decompose(cfg), params, backend="device",
                     residency="one-brick")
    # a transient override is the same lowering the alias picks
    plan = compile_plan(decompose(cfg), params, backend="host",
                        residency="one-brick")
    assert all(not s.backend.resident for s in plan.steps)


def test_resolve_backend_priorities():
    assert resolve_backend("host") is BACKENDS["host"]
    assert resolve_backend(BACKENDS["device"]) is BACKENDS["device"]
    with pytest.raises(BackendError):
        resolve_backend("no-such-substrate")
    # accelerator profile field beats inference
    acc = Accelerator("x", TPU_V5E, backend="device")
    assert resolve_backend(None, acc) is BACKENDS["device"]
    # mesh-less accelerator with no profile -> host emulation
    assert resolve_backend(None, Accelerator("y", TPU_V5E)) \
        is BACKENDS["host"]
    # nothing at all -> default-device placement
    assert resolve_backend(None) is BACKENDS["device"]


# ---------------------------------------------------------------------------
# the THROTTLED re-lowering hook
# ---------------------------------------------------------------------------

def test_power_policy_backend_demotion_knob():
    pol = PowerPolicy(t_high=0.6, t_low=0.2)
    assert pol.knobs(0.9).backend_demotion is None       # UNCONSTRAINED
    assert pol.knobs(0.55).backend_demotion is None      # mild THROTTLED
    assert pol.knobs(0.25).backend_demotion == "host"    # deep THROTTLED
    assert pol.knobs(0.1).backend_demotion == "host"     # CRITICAL


def test_relower_changes_substrate_not_numbers(vlm):
    cfg, params = vlm
    rng = np.random.default_rng(0)
    inputs = {"tokens": jnp.asarray(rng.integers(3, 200, (1, 16)),
                                    jnp.int32),
              "vision_feats": jnp.asarray(
                  rng.standard_normal(
                      (1, cfg.vision_tokens, cfg.vision_feat_dim)) * 0.02,
                  jnp.float32)}
    plan = compile_plan(decompose(cfg), params)          # default: device
    out_dev, _ = plan.run(inputs)
    step = plan.relower("projector", "host")
    assert step.backend.name == "host"
    assert plan.backend_of("projector").name == "host"
    assert plan.backend_of("decoder").name == "device"   # others untouched
    out_mixed, _ = plan.run(inputs)
    np.testing.assert_allclose(np.asarray(out_mixed, np.float32),
                               np.asarray(out_dev, np.float32),
                               rtol=2e-2, atol=2e-2)
    plan.relower("projector", "device")                  # restore
    assert plan.backend_of("projector").name == "device"


def test_engine_applies_demotion_and_restores(vlm):
    """The battery hook end to end: a deep-THROTTLED PMU makes the engine
    relower its static (encoder-side) bricks to the host backend; a
    recovered battery restores the compiled substrate."""
    from repro.core.power import BatteryAwareExecutor, PMU
    cfg, params = vlm
    ex = BatteryAwareExecutor(PMU())
    ex.pmu.level = 0.25                                  # deep THROTTLED
    with ServingEngine(cfg, params, n_slots=2, max_len=128,
                       executor=ex) as eng:
        assert eng.plan.backend_of("projector").name == "device"
        eng.step()                                       # applies knobs
        assert eng.plan.backend_of("projector").name == "host"
        assert eng.plan.backend_of("decoder").name == "device"
        # demoted lowering still serves correctly
        eng.submit(_reqs(cfg, n=1, n_new=3)[0])
        done = eng.run()
        assert done[0].error is None and len(done[0].out_tokens) >= 3
        ex.pmu.level = 1.0                               # charge recovers
        eng.step()
        assert eng.plan.backend_of("projector").name == "device"
