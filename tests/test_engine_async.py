"""Async TABM producer/consumer pipeline (serving/engine.StagingWorker).

Covers the issue's acceptance criteria:
* **overlap** — request k+1's vision encode begins (and commits) before
  request k's last decode step, asserted on the engine's interleaving
  trace with the requests pinned mid-decode so the evidence is
  deterministic, not timing luck;
* **equivalence** — greedy tokens from the async pipeline are identical to
  the synchronous single-threaded path (same plan, same ring, one thread);
* **drain protocol** — shutdown with staged-but-unconsumed slots releases
  the whole ring back to EMPTY, joins the worker (no daemon thread left),
  and fails still-queued requests with EngineClosed;
* **error propagation** — a staging failure surfaces on the originating
  request's ``error`` and the pipeline keeps serving later requests;
* **admission depth** — core/scheduler.staging_budget counts STAGING+READY
  (+ in-flight hand-offs), not raw occupancy.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.scheduler import staged_ahead_depth, staging_budget
from repro.core.tabm import CONSUMED, EMPTY, RingBuffer
from repro.launch.steps import init_params
from repro.serving.engine import EngineClosed, Request, ServingEngine


@pytest.fixture(scope="module")
def vlm():
    import jax
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _vreq(cfg, rid, n_new=8, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(
        rid=rid, tokens=(np.arange(6 + rid) % 50 + 3).astype(np.int32),
        max_new_tokens=n_new,
        vision_feats=rng.standard_normal(
            (1, cfg.vision_tokens, cfg.vision_feat_dim)
        ).astype(np.float32) * 0.02)


def _idx(trace, event, rid):
    for i, (ev, r, _) in enumerate(trace):
        if ev == event and r == rid:
            return i
    raise AssertionError(f"{(event, rid)} not in trace: "
                         f"{[(e, r) for e, r, _ in trace]}")


def test_overlap_vision_encode_with_decode(vlm):
    """The tentpole's proof: while request 0 sits mid-decode (we stop
    stepping, so it cannot finish), the producer thread stages request 1's
    vision encode to commit — then the trace shows stage_start/commit of
    rid 1 strictly before rid 0's last decode step and finish."""
    cfg, params = vlm
    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng:
        assert eng.async_staging
        r0, r1 = _vreq(cfg, 0), _vreq(cfg, 1)
        eng.submit(r0)
        eng.submit(r1)
        # step until r0 is admitted and has decoded at least one token
        deadline = time.monotonic() + 120
        while r0.slot is None or len(r0.out_tokens) < 2:
            assert time.monotonic() < deadline, "r0 never started decoding"
            eng.step()
        assert r0.finish_t is None             # r0 is mid-decode, pinned
        # the producer thread stages r1 concurrently — no step() calls run
        assert r1._staged_ev.wait(60), "producer thread never staged r1"
        assert r1.error is None and r1.tabm_slot is not None
        assert r0.finish_t is None             # still mid-decode: overlap
        done = eng.run()
        assert {r.rid for r in done} == {0, 1}
        tr = eng.trace
        # k+1's vision encode began — and committed — before k's last
        # decode step (the finish event directly follows that step)
        assert _idx(tr, "stage_start", 1) < _idx(tr, "finish", 0)
        assert _idx(tr, "stage_commit", 1) < _idx(tr, "finish", 0)


def test_async_tokens_identical_to_sync(vlm):
    """Greedy decode through the two-thread pipeline produces exactly the
    synchronous path's tokens (same ring, same plan, zero numerics drift)."""
    cfg, params = vlm
    reqs = lambda: [_vreq(cfg, i, n_new=6) for i in range(3)]
    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng_a:
        done_a = {r.rid: r.out_tokens for r in _run_all(eng_a, reqs())}
    eng_s = ServingEngine(cfg, params, n_slots=2, max_len=128,
                          async_staging=False)
    done_s = {r.rid: r.out_tokens for r in _run_all(eng_s, reqs())}
    assert done_a == done_s
    assert all(done_a[i] for i in range(3))


def _run_all(eng, reqs):
    for r in reqs:
        eng.submit(r)
    return eng.run()


def test_shutdown_drains_staged_slots_no_thread_left(vlm):
    """Drain protocol: staged-but-unconsumed slots (and a producer parked
    on its class's FULL ring) must not survive shutdown — every class ring
    fully EMPTY, all class workers joined, queued requests failed with
    EngineClosed."""
    cfg, params = vlm
    eng = ServingEngine(cfg, params, n_slots=2, max_len=128)
    # every _vreq is the same class (1 full-res image): its class ring is
    # the resource being overfilled, not the pool total
    ring = eng.tabm.ring_for_tokens(cfg.vision_tokens)
    n_ring = ring.n_slots
    for i in range(n_ring + 2):                # overfill: forces starvation
        eng.submit(_vreq(cfg, i))
    eng._feed_staging()                        # hand over without admitting
    # wait until the class ring is staged full (worker committed n_ring)
    deadline = time.monotonic() + 120
    while ring.ready_count() < n_ring:
        assert time.monotonic() < deadline, "worker never filled the ring"
        time.sleep(0.005)
    assert staged_ahead_depth(ring) == n_ring
    worker_threads = list(eng._worker._threads.values())
    assert worker_threads and all(t.is_alive() for t in worker_threads)
    assert eng.shutdown()                      # True = all workers joined
    assert all(st == EMPTY for st in eng.tabm.states)  # pool released
    # THIS engine's producer threads are dead — no daemon left behind
    # (other tests' engines may still park workers, so scope to our own)
    for t in worker_threads:
        assert not t.is_alive()
        assert t not in threading.enumerate()
    assert not eng.queue                       # everything resolved
    failed = [r for r in eng.done if r.error is not None]
    assert len(failed) == n_ring + 2           # none decoded, all cancelled
    assert all(isinstance(r.error, EngineClosed) for r in failed)
    assert eng.shutdown()                      # idempotent
    with pytest.raises(EngineClosed):
        eng.submit(_vreq(cfg, 99))


def test_shutdown_resolves_live_mid_decode_requests(vlm):
    """shutdown() must account for every submitted request: one admitted
    and pinned mid-decode ends up in done, failed with EngineClosed,
    keeping its partial tokens, and its KV slot is returned."""
    cfg, params = vlm
    eng = ServingEngine(cfg, params, n_slots=2, max_len=128)
    r0 = _vreq(cfg, 0, n_new=32)
    eng.submit(r0)
    deadline = time.monotonic() + 120
    while r0.slot is None or len(r0.out_tokens) < 2:
        assert time.monotonic() < deadline, "r0 never started decoding"
        eng.step()
    assert eng.shutdown()
    assert r0 in eng.done and isinstance(r0.error, EngineClosed)
    assert r0.finish_t is not None and len(r0.out_tokens) >= 2
    assert len(eng.slots.free) == eng.slots.n_slots    # KV slot returned
    assert eng.stats.failed == 1 and not eng.live


def test_dropped_engine_reaps_worker_thread(vlm):
    """An engine discarded without shutdown() must not leak its producer
    threads: the worker holds the engine only weakly, so collection fires
    the finalizer, which closes the pool and joins every class thread."""
    import gc
    cfg, params = vlm
    eng = ServingEngine(cfg, params, n_slots=2, max_len=128)
    r = _vreq(cfg, 0)
    eng.submit(r)
    eng._feed_staging()
    assert r._staged_ev.wait(60)               # worker is up and parked
    t = eng._worker._threads[r.slot_class]     # this request's class thread
    assert t is not None and t.is_alive()
    del eng
    gc.collect()                               # finalizer -> worker.shutdown
    t.join(10.0)
    assert not t.is_alive()


def test_staging_error_surfaces_on_owning_request(vlm):
    """A projector blow-up mid-staging fails exactly the owning request
    (error attached, finished failed) and the ring/pipeline keep serving."""
    cfg, params = vlm
    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng:
        bad = _vreq(cfg, 0)
        # wrong feature dim: the projector matmul cannot contract
        bad.vision_feats = np.ones(
            (1, cfg.vision_tokens, cfg.vision_feat_dim + 3), np.float32)
        good = _vreq(cfg, 1, n_new=4)
        eng.submit(bad)
        eng.submit(good)
        done = eng.run()
        by_rid = {r.rid: r for r in done}
        assert by_rid[0].error is not None and not by_rid[0].out_tokens
        assert by_rid[1].error is None and len(by_rid[1].out_tokens) >= 4
        assert eng.stats.failed == 1 and eng.stats.finished == 1
        assert all(st == EMPTY for st in eng.tabm.states)  # nothing wedged
        assert ("stage_error", 0) in [(e, r) for e, r, _ in eng.trace]


def test_admission_failure_releases_kv_and_ring_slot(vlm):
    """A prefill blow-up after the ring slot was consumed must release
    both the KV slot and the ring slot and fail the request — repeated
    failures must not shrink the ring or wedge the producer."""
    cfg, params = vlm
    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng:
        def raising_prefill(bucket):
            def fn(*a, **k):
                raise RuntimeError("prefill exploded")
            return fn
        eng._prefill_fn = raising_prefill
        for i in range(3):                     # more failures than ring slots
            eng.submit(_vreq(cfg, i, n_new=4))
        done = eng.run()
        assert len(done) == 3
        assert all(isinstance(r.error, RuntimeError) for r in done)
        assert eng.stats.failed == 3
        assert all(st == EMPTY for st in eng.tabm.states)  # ring recycled
        assert len(eng.slots.free) == eng.slots.n_slots    # KV recycled


def test_staging_budget_counts_depth_not_occupancy():
    """The admission hook: a CONSUMED slot occupies the ring but is behind
    the consumer — it must not count against staged-ahead depth."""
    rb = RingBuffer(n_slots=4, max_tokens=2, dim=8)
    assert staged_ahead_depth(rb) == 0
    assert staging_budget(rb, in_flight=0) == 4
    s = rb.acquire_write()                     # STAGING counts
    assert staged_ahead_depth(rb) == 1
    rb.commit_write(s, jnp.ones((1, 8)))       # READY counts
    assert staged_ahead_depth(rb) == 1
    assert staging_budget(rb, in_flight=2) == 1
    slot, _, _ = rb.acquire_read()             # CONSUMED: behind consumer
    assert rb.states[slot] == CONSUMED and rb.occupancy > 0
    assert staged_ahead_depth(rb) == 0
    assert staging_budget(rb, in_flight=0, max_ahead=2) == 2
