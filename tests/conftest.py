# NOTE: no XLA_FLAGS here — smoke tests must see the real single CPU device.
# The multi-device dry-run integration test spawns a subprocess that sets
# --xla_force_host_platform_device_count itself (see test_dryrun_small.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    # the target container has no hypothesis and nothing may be installed;
    # _hypothesis_stub registers a deterministic sampling shim in its place
    import _hypothesis_stub  # noqa: F401  (self-installs into sys.modules)
    from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci", max_examples=25, deadline=None,
    suppress_health_check=[HealthCheck.too_slow,
                           HealthCheck.data_too_large])
settings.load_profile("ci")


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
