"""Continuous-batching engine across every decoder-only cache family:
dense KV, GQA, MoE routing, SSD state, hybrid interleave, VLM+TABM."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import init_params
from repro.serving.engine import Request, ServingEngine

ARCHS = ["stablelm-1.6b", "nemotron-4-15b", "deepseek-moe-16b",
         "mamba2-1.3b", "jamba-1.5-large-398b", "qwen2-vl-7b",
         "llava-onevision-0.5b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_serves_arch(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=160)
    rng = np.random.default_rng(1)
    for i in range(3):
        req = Request(rid=i,
                      tokens=rng.integers(3, 200, 8 + 5 * i).astype(np.int32),
                      max_new_tokens=5)
        if cfg.vlm:
            req.vision_feats = rng.standard_normal(
                (1, cfg.vision_tokens, cfg.vision_feat_dim)
            ).astype(np.float32) * 0.02
        eng.submit(req)
    done = eng.run()
    assert len(done) == 3
    for r in done:
        assert len(r.out_tokens) >= 5 or 1 in r.out_tokens
        assert all(isinstance(t, int) for t in r.out_tokens)
    assert len(eng.slots.free) == 2          # all slots recycled


def test_engine_interleaves_prefill_and_decode():
    """Continuous batching: a request admitted mid-flight decodes alongside
    the existing one (slot lengths differ)."""
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=160)
    eng.submit(Request(rid=0, tokens=np.arange(10) + 3, max_new_tokens=12))
    for _ in range(4):
        eng.step()
    eng.submit(Request(rid=1, tokens=np.arange(30) + 3, max_new_tokens=4))
    done = eng.run()
    assert {r.rid for r in done} == {0, 1}
    assert not eng.live and not eng.queue
    assert sorted(eng.slots.free) == [0, 1]  # everything released
    # outputs differ: the two requests decoded from different lengths
    assert done[0].out_tokens != done[1].out_tokens
