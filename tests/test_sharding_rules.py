"""Sharding rule table: divisibility guarantees and per-leaf rules.

Uses a stub 16x16 "mesh" (the rules only read axis_names / device-grid
shape), so the production-mesh decisions are unit-testable on 1 CPU device.
"""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed import sharding as sh
from repro.launch.steps import abstract_cache, abstract_params, input_specs
from repro.configs.base import SHAPES


class StubMesh:
    def __init__(self, shape=(16, 16), axes=("data", "model")):
        self.devices = np.empty(shape, dtype=object)
        self.axis_names = axes


MESH = StubMesh()
MESH3 = StubMesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, name):
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _check_divisible(spec_tree, shapes_tree, mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ok = []

    def visit(spec, sds):
        for dim, names in zip(sds.shape, tuple(spec) + (None,) * 10):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            total = int(np.prod([sizes[n] for n in names]))
            assert dim % total == 0, (spec, sds.shape)
        ok.append(1)

    jax.tree.map(visit, spec_tree, shapes_tree,
                 is_leaf=lambda x: isinstance(x, P))
    assert ok


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_divisible_all_archs(arch):
    cfg = get_config(arch)
    shapes = abstract_params(cfg)
    specs = sh.tree_param_specs(MESH, shapes)
    _check_divisible(specs, shapes, MESH)


@pytest.mark.parametrize("arch", ["deepseek-67b", "jamba-1.5-large-398b",
                                  "qwen2-vl-7b"])
def test_param_specs_divisible_multipod(arch):
    cfg = get_config(arch)
    shapes = abstract_params(cfg)
    specs = sh.tree_param_specs(MESH3, shapes)
    _check_divisible(specs, shapes, MESH3)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape):
    cfg = get_config(arch)
    from repro.configs.base import cell_applicable
    cell = SHAPES[shape]
    if not cell_applicable(cfg, cell)[0]:
        pytest.skip("cell not applicable")
    shapes = abstract_cache(cfg, cell.global_batch, cell.seq_len)
    specs = sh.tree_cache_specs(MESH, shapes)
    _check_divisible(specs, shapes, MESH)


def test_rule_table_expectations():
    cfg = get_config("deepseek-67b")
    shapes = abstract_params(cfg)
    specs = sh.tree_param_specs(MESH, shapes)
    layer0 = specs["layers"][0]
    # attention: H=64 divisible by 16 -> heads on model; no hd fallback
    assert layer0["mixer"]["wq"] == P(None, "data", "model", None)
    # GQA kv=8 indivisible -> replicated over model, FSDP kept
    assert layer0["mixer"]["wk"] == P(None, "data", None, None)
    assert layer0["mixer"]["wo"] == P(None, "model", None, "data")
    assert layer0["ffn"]["w_up"] == P(None, "data", "model")
    assert layer0["ffn"]["w_down"] == P(None, "model", "data")
    assert specs["embed"] == P("model", "data")
    assert specs["lm_head"] == P("data", "model")
    assert specs["final_norm"]["scale"] == P()


def test_moe_expert_parallel_rule():
    cfg = get_config("dbrx-132b")
    specs = sh.tree_param_specs(MESH, abstract_params(cfg))
    moe = specs["layers"][0]["ffn"]
    assert moe["w_up"] == P(None, "model", "data", None)     # EP on experts
    assert moe["w_down"] == P(None, "model", None, "data")
    assert moe["router"] == P(None, "data", None)


def test_qwen2_indivisible_heads_fall_back():
    cfg = get_config("qwen2-vl-7b")                          # 28 heads
    specs = sh.tree_param_specs(MESH, abstract_params(cfg))
    wq = specs["layers"][0]["mixer"]["wq"]
    assert wq == P(None, "data", None, None)                 # replicated TP


def test_batch_specs():
    cell = SHAPES["train_4k"]
    cfg = get_config("deepseek-67b")
    specs = sh.tree_batch_specs(MESH, input_specs(cfg, cell))
    assert specs["tokens"][0] in (("data",), "data")
    # long_500k batch=1: replicated
    cfg2 = get_config("mamba2-1.3b")
    specs2 = sh.tree_batch_specs(MESH, input_specs(cfg2, SHAPES["long_500k"]))
    assert all(x is None for x in specs2["tokens"])


def test_cache_seq_sharding_flash_decode():
    """decode_32k: batch over data, cache sequence over model (SP)."""
    cfg = get_config("deepseek-67b")
    cell = SHAPES["decode_32k"]
    shapes = abstract_cache(cfg, cell.global_batch, cell.seq_len)
    specs = sh.tree_cache_specs(MESH, shapes)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    kv = [(p, s) for p, s in flat
          if "layers" in "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                                  for q in p)]
    assert kv
    for _, spec in kv:
        entries = tuple(spec)
        # sequence dim (3rd-from-last) on "model"; batch dim on "data"
        assert entries[-3] == "model"
        assert entries[-4] in ("data", ("data",))
