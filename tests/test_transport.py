"""Transport wire format, failure taxonomy, KV block export/import, and
the scheduler's transport-aware split pricing (core/transport.py)."""
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.backends import BackendError, resolve_backend
from repro.core.bricks import decompose
from repro.core.scheduler import fleet_accelerators, schedule_split
from repro.core.transport import (InProcTransport, MAGIC, PipeTransport,
                                  RemotePrefill, SocketTransport,
                                  TRANSPORTS, TransportError, _BytesReader,
                                  decode_frame, encode_frame,
                                  resolve_transport)
from repro.serving.kv_cache import PagedKVCache

_PREFIX_SIZE = struct.calcsize("<4sqI")


def _decode(frame: bytes):
    return decode_frame(_BytesReader(frame).read)


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

def test_codec_roundtrip_bit_exact():
    import ml_dtypes
    rng = np.random.default_rng(0)
    arrays = [
        rng.standard_normal((3, 5)).astype(np.float32),
        rng.integers(-9, 9, (7,)).astype(np.int32),
        rng.integers(0, 255, (2, 2, 2)).astype(np.uint8),
        np.array([], np.float32),
        # bfloat16: dtype.str is an opaque "<V2", so frames must carry
        # the NAME — the exact bug class this test pins
        rng.standard_normal((4, 3)).astype(ml_dtypes.bfloat16),
    ]
    meta = {"rid": 3, "nested": {"k": [1, 2]}, "s": "x"}
    kind, got_meta, got, rid = _decode(
        encode_frame("prefill", meta, arrays, rid=3))
    assert (kind, rid, got_meta) == ("prefill", 3, meta)
    assert len(got) == len(arrays)
    for a, b in zip(arrays, got):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()


def test_codec_bad_magic_is_fatal():
    frame = bytearray(encode_frame("x", {}, rid=1))
    frame[:4] = b"NOPE"
    with pytest.raises(TransportError) as ei:
        _decode(bytes(frame))
    assert not ei.value.recoverable


def test_codec_truncation_is_fatal():
    frame = encode_frame("x", {}, [np.arange(8, dtype=np.int64)], rid=1)
    with pytest.raises(TransportError) as ei:
        _decode(frame[:-10])
    assert not ei.value.recoverable


def test_codec_corrupt_header_is_fatal():
    frame = bytearray(encode_frame("x", {"a": 1}, rid=5))
    frame[_PREFIX_SIZE] ^= 0xFF           # first header byte
    with pytest.raises(TransportError) as ei:
        _decode(bytes(frame))
    assert not ei.value.recoverable and ei.value.rid == 5


def test_codec_corrupt_payload_fails_only_owner():
    """Payload corruption is recoverable: the frame was consumed whole
    (header lengths were good), the rid survived in the prefix, and the
    NEXT frame on the stream still decodes."""
    bad = bytearray(encode_frame(
        "prefill", {}, [np.arange(32, dtype=np.float64)], rid=7))
    header_len = struct.unpack_from("<4sqI", bytes(bad))[2]
    bad[_PREFIX_SIZE + header_len + 4 + 3] ^= 0xFF    # a payload byte
    ok = encode_frame("prefill", {"fine": True}, rid=8)
    reader = _BytesReader(bytes(bad) + ok)
    with pytest.raises(TransportError) as ei:
        decode_frame(reader.read)
    assert ei.value.recoverable and ei.value.rid == 7
    kind, meta, _, rid = decode_frame(reader.read)
    assert (kind, rid, meta) == ("prefill", 8, {"fine": True})


# ---------------------------------------------------------------------------
# transports + registry
# ---------------------------------------------------------------------------

def test_inproc_pair_duplex_and_close():
    a, b = InProcTransport.pair()
    a.send("ping", {"n": 1}, [np.arange(3, dtype=np.int32)], rid=1)
    kind, meta, arrays, rid = b.recv()
    assert (kind, meta, rid) == ("ping", {"n": 1}, 1)
    np.testing.assert_array_equal(arrays[0], np.arange(3, dtype=np.int32))
    b.send("pong", {}, rid=1)
    assert a.recv()[0] == "pong"
    assert a.sent_frames == 1 and a.sent_bytes > 0
    a.close()
    with pytest.raises(TransportError) as ei:
        b.recv()
    assert not ei.value.recoverable


def test_pipe_pair_roundtrip_and_close():
    a, b = PipeTransport.pair()
    a.send("msg", {"x": 2}, [np.ones((2, 2), np.float32)], rid=4)
    kind, meta, arrays, rid = b.recv()
    assert (kind, meta["x"], rid) == ("msg", 2, 4)
    a.close()
    with pytest.raises(TransportError) as ei:
        b.recv()
    assert not ei.value.recoverable
    a.close()                              # idempotent
    b.close()


def test_serializing_edge_roundtrips_codec():
    class _DirectBackend:
        def make_edge(self, src, dst):
            return None                    # direct: no transfer needed
    x = np.arange(6, dtype=np.float32).reshape(2, 3)
    # inproc plan edges stay direct device hand-offs
    assert InProcTransport().make_edge(None, None, _DirectBackend()) is None
    edge = PipeTransport(None, None).make_edge(None, None, _DirectBackend())
    np.testing.assert_array_equal(edge(x), x)


def test_registry_mirrors_backends():
    assert set(TRANSPORTS) >= {"inproc", "pipe", "socket"}
    assert resolve_transport("socket") is SocketTransport
    assert resolve_transport(InProcTransport) is InProcTransport
    with pytest.raises(TransportError):
        resolve_transport("carrier-pigeon")


def test_resolve_backend_device_ordinals():
    be = resolve_backend("device:0")
    assert be.name == "device:0"
    assert resolve_backend("device:0") is be      # cached per ordinal
    with pytest.raises(BackendError):
        resolve_backend("device:abc")
    with pytest.raises(BackendError):
        resolve_backend(f"device:{len(jax.devices()) + 7}")


# ---------------------------------------------------------------------------
# the wire unit + KV block export/import
# ---------------------------------------------------------------------------

def test_remote_prefill_wire_roundtrip():
    rng = np.random.default_rng(1)
    rp = RemotePrefill(
        rid=11, prompt=np.arange(6, dtype=np.int32), first_token=42,
        max_new_tokens=5, blocks_granted=4, paged=(True, False),
        kv=[[rng.standard_normal((2, 3, 8)).astype(np.float32)] * 2,
            [rng.standard_normal((2, 1, 4)).astype(np.float32)]],
        slot_class="full", slab=rng.standard_normal((9,)).astype(np.float32))
    kind, meta, arrays = rp.to_wire()
    k2, m2, a2, rid = _decode(encode_frame(kind, meta, arrays, rid=rp.rid))
    back = RemotePrefill.from_wire(m2, a2)
    assert (back.rid, back.first_token, back.max_new_tokens,
            back.blocks_granted, back.slot_class, back.prompt_len) == \
        (11, 42, 5, 4, "full", 6)
    assert back.paged == (True, False)
    np.testing.assert_array_equal(back.prompt, rp.prompt)
    np.testing.assert_array_equal(back.slab, rp.slab)
    for l1, l2 in zip([x for ls in rp.kv for x in ls],
                      [x for ls in back.kv for x in ls]):
        assert l1.tobytes() == l2.tobytes()
    # only paged positions count toward the wire-savings assertion
    assert rp.kv_wire_bytes() == 2 * rp.kv[0][0].nbytes
    # a frame missing its arrays is a malformed-but-recoverable prefill
    with pytest.raises(TransportError) as ei:
        RemotePrefill.from_wire(m2, a2[:1])
    assert ei.value.recoverable and ei.value.rid == 11


def test_kv_export_import_bit_exact():
    """export -> wire codec -> import into a DIFFERENT pool (different
    block ids) -> re-export preserves every leaf byte-for-byte."""
    cfg = get_config("llava-onevision-0.5b").reduced()
    kw = dict(n_slots=2, max_len=256, block_size=32)
    src = PagedKVCache(cfg, **kw)
    dst = PagedKVCache(cfg, **kw)
    rng = np.random.default_rng(2)
    src.pool = tuple(
        jax.tree.map(lambda l: jnp.asarray(
            rng.standard_normal(l.shape), l.dtype), p)
        for p in src.pool)

    s_src = src.take_slot()
    src.grant_blocks(s_src, 4)
    payload = src.export_blocks(s_src, 3)     # written blocks < grant

    layout = [len(leaves) for leaves in payload]
    flat = [leaf for leaves in payload for leaf in leaves]
    _, meta, back, _ = _decode(encode_frame(
        "kv", {"layout": layout}, flat, rid=0))
    it = iter(back)
    wired = [[next(it) for _ in range(n)] for n in meta["layout"]]

    dst.grant_blocks(dst.take_slot(), 2)      # shift dst's free block ids
    s_dst = dst.take_slot()
    dst.grant_blocks(s_dst, 4)
    dst.import_blocks(s_dst, wired)
    out = dst.export_blocks(s_dst, 3)
    for p1, p2 in zip(payload, out):
        for l1, l2 in zip(p1, p2):
            assert np.asarray(l1).tobytes() == np.asarray(l2).tobytes()

    with pytest.raises(RuntimeError):
        src.export_blocks(s_src, 5)           # over the grant


# ---------------------------------------------------------------------------
# split pricing
# ---------------------------------------------------------------------------

def test_fleet_rows_priced_at_transport_bw():
    for accel in fleet_accelerators(SocketTransport):
        assert accel.profile.link_bw == SocketTransport.link_bw
    prefill, decode = fleet_accelerators(InProcTransport)
    assert prefill.static_only and not prefill.dynamic_ok
    assert (prefill.backend, decode.backend) == ("device:0", "device:1")


def test_schedule_split_responds_to_transport():
    """A fast in-process wire lets the DP cut at the vision/decode
    boundary; a slow socket makes the crossing too expensive and
    co-locates everything on the decode fleet."""
    graph = decompose(get_config("llava-onevision-0.5b"))
    fast = schedule_split(graph, "inproc", n_tokens=729)
    slow = schedule_split(graph, SocketTransport, n_tokens=729)
    assert fast.assignment["vision_frontend"] == "prefill-fleet"
    assert fast.assignment["projector"] == "prefill-fleet"
    assert fast.assignment["decoder"] == "decode-fleet"
    assert set(slow.assignment.values()) == {"decode-fleet"}


def test_schedule_split_measured_link_flips_placement():
    """Measured-not-modeled wire pricing: the in-process transport's
    static class row prices fast enough to cut at the vision/decode
    boundary, but when the frames actually clocked ~1 MB/s
    (``Transport.measured_link_bw`` folded through
    ``CostCalibration.observe_link``) the repriced split co-locates
    everything on the decode fleet — the placement follows the
    observation, not the class constant."""
    from repro.telemetry.calibration import CostCalibration

    graph = decompose(get_config("llava-onevision-0.5b"))
    static = schedule_split(graph, "inproc", n_tokens=729)
    assert static.assignment["vision_frontend"] == "prefill-fleet"

    cal = CostCalibration(prior=1)
    cal.observe_link("inproc", bytes_moved=1e6, seconds=1.0, n=64)
    measured = schedule_split(graph, "inproc", n_tokens=729,
                              calibration=cal)
    assert set(measured.assignment.values()) == {"decode-fleet"}, (
        f"measured-slow link did not flip the split: "
        f"{measured.assignment}")
    # the blend is sample-weighted: a single observation against a
    # large prior barely moves the row and must NOT flip the split
    light = CostCalibration(prior=1 << 20)
    light.observe_link("inproc", bytes_moved=1e6, seconds=1.0, n=1)
    barely = schedule_split(graph, "inproc", n_tokens=729,
                            calibration=light)
    assert barely.assignment == static.assignment


def test_transport_measures_its_own_wire():
    """Every send accrues ``send_seconds``; ``measured_link_bw`` needs a
    floor of evidence before it reports."""
    a, b = InProcTransport.pair()
    assert a.measured_link_bw() is None          # no bytes yet
    payload = [np.zeros((1 << 16,), np.uint8)]
    a.send("kv", {"x": 1}, payload)
    b.recv()
    assert a.sent_bytes >= 1 << 16 and a.send_seconds > 0.0
    bw = a.measured_link_bw()
    assert bw is not None and bw > 0.0
    assert bw == pytest.approx(a.sent_bytes / a.send_seconds)
