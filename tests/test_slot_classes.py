"""Class-partitioned TABM slot pools (core/slot_classes +
core/tabm.SlotClassPool) and battery-scaled per-class admission.

Covers the issue's acceptance criteria:
* **class table** — image-count × resolution buckets derived from the
  arch config; classify() picks the smallest fitting slab; unservable
  specs fail fast;
* **class-sized slabs** — a thumbnail-class ring rejects a commit larger
  than its own max_tokens (no more padding 1-image requests into 4-image
  slabs, and no oversized payload sneaking into a small slab);
* **per-class FULL isolation** — with the high-resolution class ring
  FULL (and a further hi-res request starved at hand-off by its own
  class budget), a thumbnail request is still staged AND admitted — the
  engine trace proves it;
* **battery-scaled admission** — Knobs.class_depth_scale shrinks the
  high-resolution classes' depth first (largest slab gates to zero under
  deep THROTTLED) while the thumbnail class keeps full depth, and
  restores when charge recovers — end-to-end through the engine;
* **equivalence** — async and sync pipelines produce identical greedy
  tokens with ≥2 classes in flight.
"""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.power import BatteryAwareExecutor, PMU, PowerPolicy
from repro.core.scheduler import class_staging_budgets
from repro.core.slot_classes import (SlotClassError, build_slot_classes,
                                     classify, classify_total,
                                     image_buckets, resolution_buckets)
from repro.core.tabm import EMPTY, SlotClassPool, TABMError
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def vlm():
    import jax
    from repro.launch.steps import init_params
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _req(cfg, rid, n_tokens, n_images=1, n_new=4, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(
        rid=rid, tokens=(np.arange(6 + rid) % 50 + 3).astype(np.int32),
        n_images=n_images, max_new_tokens=n_new,
        vision_feats=rng.standard_normal(
            (1, n_tokens, cfg.vision_feat_dim)).astype(np.float32) * 0.02)


# ---------------------------------------------------------------------------
# class derivation (configs -> slot classes)
# ---------------------------------------------------------------------------

def test_class_table_from_config():
    cfg = get_config("llava-onevision-0.5b")
    assert resolution_buckets(cfg) == (196, 729)
    assert image_buckets(cfg) == (1, 4)
    classes = build_slot_classes(cfg.reduced(), slots_per_class=2)
    # reduced: buckets (2, 8) x images (1, 4), smallest slab first
    assert list(classes) == ["1img-2tok", "1img-8tok", "4img-2tok",
                             "4img-8tok"]
    assert classes["1img-2tok"].max_tokens == 2
    assert classes["4img-8tok"].max_tokens == 32
    # classify picks the smallest fitting slab
    assert classify(classes, 8, 1).name == "1img-8tok"
    assert classify(classes, 2, 1).name == "1img-2tok"
    assert classify(classes, 8, 4).name == "4img-2tok"   # 4 thumbnails
    assert classify(classes, 32, 4).name == "4img-8tok"
    assert classify_total(classes, 5).name == "1img-8tok"
    with pytest.raises(SlotClassError):
        classify(classes, 64, 1)               # beyond every bucket
    with pytest.raises(SlotClassError):
        classify(classes, 8, 8)                # more images than the config
    with pytest.raises(SlotClassError):
        build_slot_classes(get_config("stablelm-1.6b"))   # not a vlm


def test_single_bucket_arch_falls_back_to_one_class_per_image_bucket():
    cfg = get_config("llava-onevision-0.5b").reduced(
        vision_token_buckets=(), vision_max_images=1)
    classes = build_slot_classes(cfg)
    assert list(classes) == ["1img-8tok"]      # vision_tokens fallback


def test_class_sized_max_tokens_rejects_oversized_commit(vlm):
    """The slab win and its guard: each class ring holds exactly its own
    slab, and a payload bigger than the class slab is rejected at commit
    (per class), like single-ring overflow."""
    cfg, _ = vlm
    pool = SlotClassPool.from_config(cfg, slots_per_class=2)
    thumb, full = pool.ring("1img-2tok"), pool.ring("1img-8tok")
    assert thumb.max_tokens == 2 and full.max_tokens == 8
    assert thumb.nbytes < full.nbytes          # no padding into big slabs
    s = thumb.acquire_write()
    with pytest.raises(TABMError):             # full-res payload, thumb slab
        thumb.commit_write(s, jnp.ones((8, cfg.d_model)))
    thumb.abort_write(s)
    s = full.acquire_write()                   # same payload, right class
    full.commit_write(s, jnp.ones((8, cfg.d_model)))
    slot, _, n = full.acquire_read()
    assert slot == s and n == 8
    full.release(slot)
    assert all(st == EMPTY for st in pool.states)


def test_submit_oversized_vision_spec_fails_fast(vlm):
    cfg, params = vlm
    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng:
        with pytest.raises(SlotClassError):
            eng.submit(_req(cfg, 0, n_tokens=64, n_images=1))


# ---------------------------------------------------------------------------
# battery-scaled per-class admission depth
# ---------------------------------------------------------------------------

def test_admission_table_shrinks_high_res_first_and_restores(vlm):
    cfg, _ = vlm
    pool = SlotClassPool.from_config(cfg, slots_per_class=2)
    full_depth = {n: cap for n, (_, cap) in pool.admission_table(1.0).items()}
    assert full_depth == {"1img-2tok": 2, "1img-8tok": 2,
                          "4img-2tok": 2, "4img-8tok": 2}
    half = {n: cap for n, (_, cap) in pool.admission_table(0.5).items()}
    assert half["1img-2tok"] == 2              # thumbnail keeps full depth
    assert half["4img-8tok"] == 1              # largest slab shrinks most
    gated = {n: cap for n, (_, cap) in pool.admission_table(0.0).items()}
    assert gated["1img-2tok"] == 2             # still admitting thumbnails
    assert gated["4img-8tok"] == 0             # hi-res fully gated
    assert gated["4img-2tok"] == 0
    # monotone: deeper throttle never grows any class's depth
    for name in full_depth:
        assert gated[name] <= half[name] <= full_depth[name]
    # restore == the 1.0 table (no hysteresis)
    again = {n: cap for n, (_, cap) in pool.admission_table(1.0).items()}
    assert again == full_depth
    # the scheduler's per-class budget table charges against these caps
    budgets = class_staging_budgets(pool, in_flight={"1img-2tok": 1},
                                    depth_scale=0.0)
    assert budgets["1img-2tok"] == 1 and budgets["4img-8tok"] == 0


def test_power_knobs_expose_class_depth_scale():
    pol = PowerPolicy()
    assert pol.knobs(0.9).class_depth_scale == 1.0       # UNCONSTRAINED
    a = pol.alpha(0.4)
    assert pol.knobs(0.4).class_depth_scale == pytest.approx(a)
    assert pol.knobs(0.05).class_depth_scale == 0.0      # CRITICAL


@pytest.mark.parametrize("async_staging", [True, False],
                         ids=["async", "sync"])
def test_throttled_engine_sheds_high_res_staging_first_then_restores(
        vlm, async_staging):
    """End-to-end battery-aware admission, in BOTH pipelines: under
    THROTTLED (alpha=0.25) the 4-image full-resolution class's depth is 0
    — its request is never staged — while the thumbnail flows; restoring
    charge restores the class depth and the hi-res request completes."""
    cfg, params = vlm
    ex = BatteryAwareExecutor(PMU())
    ex.pmu.level = 0.30                        # alpha = 0.25, THROTTLED
    with ServingEngine(cfg, params, n_slots=2, max_len=128, executor=ex,
                       async_staging=async_staging) as eng:
        hi = _req(cfg, 0, n_tokens=32, n_images=4)     # largest class
        thumb = _req(cfg, 1, n_tokens=2)
        eng.submit(hi)
        eng.submit(thumb)
        assert hi.slot_class == "4img-8tok"
        assert thumb.slot_class == "1img-2tok"
        eng.run(max_steps=eng.stats.steps + 40)
        assert thumb in eng.done and thumb.error is None   # kept flowing
        assert hi in eng.queue                 # shed: never staged
        assert not hi.staged and hi.tabm_slot is None
        # the gated class never even allocated its ring (lazy pool)
        assert "4img-8tok" not in eng.tabm.rings
        ex.pmu.level = 1.0                     # charge recovers
        done = eng.run()
        assert hi in done and hi.error is None
        assert len(hi.out_tokens) >= 4


def test_rings_materialize_lazily(vlm):
    """Only classes traffic actually touches allocate a device pool —
    the memory win over one maximal eagerly-sized ring."""
    cfg, _ = vlm
    pool = SlotClassPool.from_config(cfg, slots_per_class=2)
    assert pool.rings == {} and pool.nbytes == 0
    assert pool.n_slots == 8                   # capacity is still static
    pool.classify(8, 1)                        # classification is free
    assert pool.rings == {}
    # budgets are computable before any ring exists (all-EMPTY semantics)
    budgets = class_staging_budgets(pool, in_flight={})
    assert budgets == {n: 2 for n in pool.names()}
    r = pool.ring("1img-2tok")                 # first use materializes
    assert list(pool.rings) == ["1img-2tok"]
    assert pool.nbytes == r.nbytes == pool.class_nbytes("1img-2tok")
    # the unmaterialized hi-res slab is the expensive one we didn't pay
    assert pool.class_nbytes("4img-8tok") == 16 * pool.class_nbytes(
        "1img-2tok")
    pool.close()                               # close() covers later birth
    late = pool.ring("1img-8tok")
    assert late.closed and pool.closed


# ---------------------------------------------------------------------------
# per-class FULL isolation (the acceptance trace)
# ---------------------------------------------------------------------------

def test_thumbnail_admitted_and_staged_while_high_res_ring_full(vlm):
    """The tentpole's proof: the high-resolution class ring is FULL (and a
    further hi-res request is starved at hand-off by its own class
    budget), yet a thumbnail request is staged by its own class thread
    AND admitted (prefilled) — both while the hi-res ring stays FULL."""
    cfg, params = vlm
    # max_batch=1 pins admission to one request per step, so the hi-res
    # slots provably stay staged (FULL) across the thumbnail's admission
    ex = BatteryAwareExecutor(PMU(), PowerPolicy(full_batch=1))
    with ServingEngine(cfg, params, n_slots=2, max_len=128,
                       executor=ex) as eng:
        hi_ring = eng.tabm.ring("1img-8tok")
        n_hi = hi_ring.n_slots
        thumb = _req(cfg, 0, n_tokens=2)
        eng.submit(thumb)                      # FIFO head: admitted first
        his = [_req(cfg, 1 + i, n_tokens=8) for i in range(n_hi + 1)]
        for r in his:
            eng.submit(r)
        eng._feed_staging()                    # hand over, nothing admitted
        deadline = time.monotonic() + 120
        while hi_ring.ready_count() < n_hi or not thumb.staged:
            assert time.monotonic() < deadline, "staging never completed"
            time.sleep(0.005)
        # hi-res class: ring FULL, and the (n_hi+1)-th request starved at
        # hand-off by ITS OWN class budget...
        assert hi_ring.staged_ahead() == n_hi
        extra = his[-1]
        assert not extra.stage_submitted and not extra.staged
        # ...while the thumbnail was handed over and staged concurrently
        assert thumb.staged and thumb.error is None
        assert thumb.tabm_slot is not None
        events = [(e, r) for e, r, _ in eng.trace]
        assert ("stage_commit", thumb.rid) in events
        # one step (admission budget 1): the thumbnail prefills...
        eng.step()
        assert thumb.slot is not None          # admitted: holds a KV slot
        assert ("prefill", thumb.rid) in [(e, r) for e, r, _ in eng.trace]
        # ...and the hi-res class ring is STILL full behind it
        assert hi_ring.staged_ahead() == n_hi
        assert all(r.slot is None for r in his)
        # everything still completes once stepping resumes
        done = eng.run()
        assert {r.rid for r in done} == {r.rid for r in [thumb] + his}
        assert all(r.error is None for r in done)
        assert all(st == EMPTY for st in eng.tabm.states)


def test_async_tokens_identical_to_sync_mixed_classes(vlm):
    """Greedy decode through the per-class producer threads produces
    exactly the synchronous path's tokens with ≥2 classes in flight."""
    cfg, params = vlm
    specs = [(2, 1), (8, 1), (8, 4), (32, 4), (2, 1), (8, 1)]
    mk = lambda: [_req(cfg, i, n_tokens=t, n_images=n, n_new=5)
                  for i, (t, n) in enumerate(specs)]

    def run(async_staging):
        eng = ServingEngine(cfg, params, n_slots=4, max_len=128,
                            async_staging=async_staging)
        with eng:
            reqs = mk()
            for r in reqs:
                eng.submit(r)
            done = eng.run()
            classes = {r.slot_class for r in reqs}
            assert len(classes) >= 2           # really mixed-class traffic
            return {r.rid: r.out_tokens for r in done}

    done_async, done_sync = run(True), run(False)
    assert done_async == done_sync
    assert all(done_async[i] for i in range(len(specs)))
