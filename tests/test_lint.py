"""replint: each rule family fires on seeded violations, stays quiet on
conforming code, pragmas/baseline suppress, the RingBuffer.commit_many
mutation is caught, and the real tree lints clean (see docs/LINTS.md)."""
import ast
import json
from pathlib import Path

import pytest

from repro.analysis.lint import (Finding, LintConfig, lint_source, run_lint,
                                 write_baseline)
from repro.analysis.lint.driver import load_modules, run_rules
from repro.analysis.lint.rules import (DispatchHygieneRule,
                                       DonationAliasingRule, HostSyncRule,
                                       KernelTripleRule, LockDisciplineRule)

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"


def rules_of(findings, name):
    return [f for f in findings if f.rule == name]


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------

LOCKED_CLASS = '''
import threading

class Box:
    def __init__(self):
        self._cond = threading.Condition()
        self.items = []
        self.n = 0

    def put(self, x):
        with self._cond:
            self.items.append(x)
            self.n = self.n + 1
            self._cond.notify_all()
'''


def test_lock_discipline_quiet_on_clean_class():
    assert lint_source(LOCKED_CLASS, rules=[LockDisciplineRule()]) == []


def test_lock_discipline_flags_unlocked_write():
    src = LOCKED_CLASS + '''
    def reset(self):
        self.n = 0
'''
    fs = lint_source(src, rules=[LockDisciplineRule()])
    assert len(fs) == 1 and "self.n" in fs[0].message
    assert fs[0].symbol == "Box.reset"


def test_lock_discipline_flags_unlocked_notify():
    src = LOCKED_CLASS + '''
    def poke(self):
        self._cond.notify_all()
'''
    fs = lint_source(src, rules=[LockDisciplineRule()])
    assert len(fs) == 1 and "notify_all" in fs[0].message


def test_lock_discipline_lock_required_method_call_graph():
    src = '''
import threading

class Box:
    def __init__(self):
        self._cond = threading.Condition()
        self.state = 0

    def _advance(self):
        """Caller must hold ``self._cond``."""
        self.state += 1

    def ok(self):
        with self._cond:
            self._advance()

    def bad(self):
        self._advance()
'''
    fs = lint_source(src, rules=[LockDisciplineRule()])
    assert len(fs) == 1 and fs[0].symbol == "Box.bad"
    assert "called-with-lock-held" in fs[0].message


def test_lock_discipline_wait_for_predicate_lambda_is_locked():
    src = '''
import threading

class Box:
    def __init__(self):
        self._cond = threading.Condition()
        self.done = False

    def finish(self):
        with self._cond:
            self.done = True
            self._cond.notify_all()

    def join(self):
        with self._cond:
            self._cond.wait_for(lambda: self.done)
'''
    assert lint_source(src, rules=[LockDisciplineRule()]) == []


def test_lock_discipline_mutation_commit_many_caught():
    """Seed a lock bypass into a copy of RingBuffer.commit_many: replace
    its 'with self._cond:' with 'if True:' and the rule must fire."""
    source = (SRC / "core" / "tabm.py").read_text()
    assert lint_source(source, path="repro/core/tabm.py",
                       rules=[LockDisciplineRule()]) == []

    lines = source.splitlines(keepends=True)
    start = next(i for i, l in enumerate(lines)
                 if "def commit_many" in l)
    with_i = next(i for i in range(start, len(lines))
                  if "with self._cond:" in lines[i])
    lines[with_i] = lines[with_i].replace("with self._cond:", "if True:")
    mutated = "".join(lines)
    assert mutated != source

    fs = lint_source(mutated, path="repro/core/tabm.py",
                     rules=[LockDisciplineRule()])
    assert any(f.symbol == "RingBuffer.commit_many" for f in fs), \
        [f.render() for f in fs]


# ---------------------------------------------------------------------------
# donation-aliasing
# ---------------------------------------------------------------------------

DONATING = '''
import functools
import jax

@functools.partial(jax.jit, donate_argnums=(0,))
def write(pool, v):
    return pool.at[0].set(v)
'''


def test_donation_rebind_in_statement_is_safe():
    src = DONATING + '''
def caller(pool, v):
    pool = write(pool, v)
    return pool.sum()
'''
    assert lint_source(src, rules=[DonationAliasingRule()]) == []


def test_donation_read_after_donate_flagged():
    src = DONATING + '''
def caller(pool, v):
    new = write(pool, v)
    return pool.sum() + new.sum()
'''
    fs = lint_source(src, rules=[DonationAliasingRule()])
    assert len(fs) == 1 and "'pool'" in fs[0].message
    assert fs[0].symbol == "caller"


def test_donation_attribute_target_and_self_field():
    src = '''
import jax

class Engine:
    def __init__(self, cache):
        self.cache = cache
        self._decode = jax.jit(lambda p, t, c: (p, c),
                               donate_argnums=(2,))

    def ok(self, p, t):
        logits, self.cache = self._decode(p, t, self.cache)
        return logits

    def bad(self, p, t):
        logits, fresh = self._decode(p, t, self.cache)
        return logits, self.cache
'''
    fs = lint_source(src, rules=[DonationAliasingRule()])
    assert len(fs) == 1 and fs[0].symbol == "Engine.bad"


def test_donation_aliased_argument_positions_flagged():
    src = DONATING + '''
def caller(pool):
    return write(pool, pool)
'''
    fs = lint_source(src, rules=[DonationAliasingRule()])
    assert len(fs) == 1 and "aliased donation" in fs[0].message


def test_donation_lower_is_not_a_call():
    src = '''
import jax

def probe(fn, pool):
    jitted = jax.jit(fn, donate_argnums=(0,))
    print(jitted.lower(pool).as_text())
    return pool.sum()
'''
    assert lint_source(src, rules=[DonationAliasingRule()]) == []


def test_donation_sites_in_tree_are_clean():
    """The audit of the tree's donate_argnums call sites (docs/LINTS.md):
    every one rebinds in the calling statement or returns."""
    mods = load_modules(SRC)
    donating = [m.path for m in mods if "donate_argnums" in m.source]
    assert len(donating) >= 6, donating       # the audited modules exist
    fs = run_rules(mods, [DonationAliasingRule()])
    assert fs == [], [f.render() for f in fs]


# ---------------------------------------------------------------------------
# dispatch-hygiene
# ---------------------------------------------------------------------------

def test_dispatch_probe_flagged_outside_dispatch_layer():
    src = '''
import jax

def pick():
    if jax.default_backend() == "tpu":
        return "kernel"
    return "ref"
'''
    fs = lint_source(src, path="repro/models/attention.py",
                     rules=[DispatchHygieneRule()])
    assert len(fs) == 1 and "jax.default_backend" in fs[0].message


def test_dispatch_env_var_read_flagged():
    src = '''
import os

def forced():
    return os.environ.get("REPRO_FORCE_REF", "") == "1"
'''
    fs = lint_source(src, path="repro/models/x.py",
                     rules=[DispatchHygieneRule()])
    assert len(fs) == 1 and "REPRO_FORCE_REF" in fs[0].message


def test_dispatch_allowed_in_dispatch_and_launch():
    src = 'import jax\nBACKEND = jax.default_backend()\n'
    for path in ("repro/kernels/dispatch.py", "repro/launch/dryrun.py"):
        assert lint_source(src, path=path,
                           rules=[DispatchHygieneRule()]) == []


def test_attention_train_fix_regression():
    """The pre-fix attention.py pattern fires; the checked-in fix routes
    through kernels/dispatch and is quiet."""
    old = '''
import jax

def attn_train(q, k, v):
    if jax.default_backend() == "tpu":
        return "flash"
    return "dense"
'''
    assert lint_source(old, path="repro/models/attention.py",
                       rules=[DispatchHygieneRule()]) != []
    current = (SRC / "models" / "attention.py").read_text()
    assert "resolve_interpret" in current
    assert lint_source(current, path="repro/models/attention.py",
                       rules=[DispatchHygieneRule()]) == []


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_item_in_jit_region():
    src = '''
import functools
import jax

@functools.partial(jax.jit)
def step(x):
    return x * x.sum().item()
'''
    fs = lint_source(src, rules=[HostSyncRule()])
    assert len(fs) == 1 and ".item()" in fs[0].message


def test_host_sync_hot_path_method_and_static_exemption():
    src = '''
class ServingEngine:
    def step(self, logits, tok):
        n = int(logits.shape[0])          # static: exempt
        t = int(tok[0])                   # device read: flagged
        return n + t
'''
    fs = lint_source(src, rules=[HostSyncRule()])
    assert len(fs) == 1 and fs[0].line == 5


def test_host_sync_quiet_outside_hot_contexts():
    src = '''
import numpy as np

def offline_report(x):
    return float(np.asarray(x).mean())
'''
    assert lint_source(src, rules=[HostSyncRule()]) == []


def test_host_sync_lambda_passed_to_jit():
    src = '''
import jax

decode = jax.jit(lambda p, c: jax.device_get(c), donate_argnums=(1,))
'''
    fs = lint_source(src, rules=[HostSyncRule()])
    assert len(fs) == 1 and "device_get" in fs[0].message


def test_host_sync_pragma_suppresses():
    src = '''
class ServingEngine:
    def step(self, tok):
        t = int(tok[0])  # replint: disable=host-sync
        return t
'''
    assert lint_source(src, rules=[HostSyncRule()]) == []


# ---------------------------------------------------------------------------
# kernel-triple
# ---------------------------------------------------------------------------

GOOD_OPS = '''
from repro.kernels.dispatch import resolve_interpret

def addone(x, y, *, interpret=None):
    interpret = resolve_interpret(interpret)
    return x + y
'''
GOOD_REF = '''
def ref_addone(x, y, scale=1.0):
    return (x + y) * scale
'''
GOOD_KERNEL = '''
import jax.experimental.pallas as pl

def addone_pallas(x, y, *, interpret=False):
    grid = None
    return pl.pallas_call(
        lambda xr, yr, o: None,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                  pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        interpret=interpret,
    )(x, y)
'''


def _fake_pkg(tmp_path, ops=GOOD_OPS, ref=GOOD_REF, kernel=GOOD_KERNEL,
              name="addone"):
    pkg = tmp_path / "kernels" / name
    pkg.mkdir(parents=True)
    files = {"ops.py": ops, "ref.py": ref, "kernel.py": kernel}
    for fname, text in files.items():
        if text is not None:
            (pkg / fname).write_text(text)
    return tmp_path                 # lint from above so paths keep kernels/


def _lint_tree(root):
    mods = load_modules(root)
    return run_rules(mods, [KernelTripleRule()])


def test_kernel_triple_good_package(tmp_path):
    assert _lint_tree(_fake_pkg(tmp_path)) == []


def test_kernel_triple_missing_ref(tmp_path):
    fs = _lint_tree(_fake_pkg(tmp_path, ref=None))
    assert len(fs) == 1 and "missing" in fs[0].message


def test_kernel_triple_signature_mismatch(tmp_path):
    fs = _lint_tree(_fake_pkg(
        tmp_path, ref="def ref_addone(a, b):\n    return a + b\n"))
    assert len(fs) == 1 and "oracle" in fs[0].message


def test_kernel_triple_interpret_not_plumbed(tmp_path):
    bad = GOOD_KERNEL.replace("        interpret=interpret,\n", "")
    fs = _lint_tree(_fake_pkg(tmp_path, kernel=bad))
    assert len(fs) == 1 and "pallas_call" in fs[0].message


def test_kernel_triple_blockspec_arity(tmp_path):
    bad = GOOD_KERNEL.replace("lambda i, j: (i, j)),\n", "lambda i: (i,)),\n",
                              1)
    fs = _lint_tree(_fake_pkg(tmp_path, kernel=bad))
    assert len(fs) == 1 and "index map" in fs[0].message


def test_kernel_triple_interpret_default_must_be_none(tmp_path):
    bad = GOOD_OPS.replace("interpret=None", "interpret=False")
    fs = _lint_tree(_fake_pkg(tmp_path, ops=bad))
    assert len(fs) == 1 and "interpret=None" in fs[0].message


def test_kernel_triple_real_tree_is_clean():
    fs = run_rules(load_modules(SRC), [KernelTripleRule()])
    assert fs == [], [f.render() for f in fs]


# ---------------------------------------------------------------------------
# driver: pragmas, baseline, whole-tree gate
# ---------------------------------------------------------------------------

def test_pragma_line_above():
    src = '''
class ServingEngine:
    def step(self, tok):
        # replint: disable=host-sync
        t = int(tok[0])
        return t
'''
    assert lint_source(src, rules=[HostSyncRule()]) == []


def test_pragma_wrong_rule_does_not_suppress():
    src = '''
class ServingEngine:
    def step(self, tok):
        t = int(tok[0])  # replint: disable=lock-discipline
        return t
'''
    assert len(lint_source(src, rules=[HostSyncRule()])) == 1


def test_baseline_matches_independent_of_line(tmp_path):
    f = Finding("host-sync", "repro/x.py", 10, 4, "msg", "C.m")
    base = tmp_path / "base.json"
    write_baseline(base, [f])
    entries = json.loads(base.read_text())
    assert entries == [{"rule": "host-sync", "path": "repro/x.py",
                        "symbol": "C.m", "message": "msg"}]
    shifted = Finding("host-sync", "repro/x.py", 99, 0, "msg", "C.m")
    assert shifted.key() == f.key()


def test_repo_tree_zero_unsuppressed():
    """The gate itself: the shipped tree has zero unsuppressed findings
    against the shipped (empty) baseline."""
    result = run_lint(SRC, baseline=REPO / "scripts" /
                      "replint_baseline.json")
    assert result.files_checked > 80
    assert result.findings == [], [f.render() for f in result.findings]
    # the deliberate syncs are suppressed in-line, not baselined (the
    # cohort-decode engine keeps exactly one per-token sync; plan.py
    # carries the other three)
    assert result.baseline_matched == []
    assert len(result.suppressed) >= 4
    report = result.to_json()
    assert report["ok"] and report["tool"] == "replint"
