"""Disaggregated two-fleet serving: prefill engine -> Transport ->
decode engine, end to end through the real launcher.  The launcher
itself asserts the acceptance bar (greedy tokens bit-identical to a
fresh single-process oracle across >= 2 slot classes, paged KV wire
bytes < whole-lane baseline) and prints "OK: disaggregated" only when
every assertion held, so the test just runs it per transport.
Subprocess: needs 8 placeholder devices for the device:N fleet
backends."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
@pytest.mark.parametrize("transport", ["inproc", "pipe", "socket"])
def test_serve_disagg_fleets(transport):
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_disagg",
         "--transport", transport, "--requests", "4"],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK: disaggregated" in proc.stdout
    assert f"over {transport}" in proc.stdout
