"""Disaggregated submesh serving (the paper's NPU/GPU split at pod scale):
encoder submesh -> SubmeshPipe (ICI) -> TABM -> decoder submesh.
Subprocess: needs 8 placeholder devices."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_serve_disagg_pipeline():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_disagg"],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK: disaggregated" in proc.stdout
