"""W4A16 serving path (the paper's core technique at pod scale) + the
cache-update kernel: correctness of the quantized decode end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quantize import PROFILES, quantize_tree
from repro.kernels.cache_update import cache_row_update, ref_cache_row_update
from repro.launch.steps import abstract_params, init_params
from repro.models import model as M


def _top1(logits):
    return int(jnp.argmax(logits.reshape(logits.shape[0], -1)[0], -1))


def test_quantized_decode_runs_and_tracks_fp(key):
    """QTensor params flow through prefill + decode, and q4 tracks fp.

    The seed version of this test free-ran BOTH models on their own argmax
    and demanded the trajectories match — but a random-init model has
    near-uniform logits, so one flipped top-1 forks the sequences and the
    comparison measures trajectory chaos, not quantization error (the test
    was deselected for exactly that).  The sound properties:

    * self-consistency — the q-model's free-running decode reproduces its
      own full-forward argmax exactly (prefill+decode path correctness
      with QTensor params, the thing the seed test actually exercised);
    * teacher-forced tracking — the SAME tokens through both models keep
      the q4 logits within a calibrated relative error of fp, with top-1
      agreement far above chance (measured margins: rel <= 0.40, agree
      4-6/8 across seeds with the MSE-searched scales).
    """
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    params = init_params(key, cfg)
    qparams = quantize_tree(params, PROFILES["nanomind-serve"])
    tokens = (jnp.arange(24)[None] % 60 + 3).astype(jnp.int32)
    steps = 8

    # --- self-consistency: free-running q decode == q full forward -------
    lg_q, cache_q = M.lm_prefill(qparams, cfg, tokens, 40)
    seq = [_top1(lg_q)]
    for _ in range(steps - 1):
        lg_q, cache_q = M.lm_decode_step(
            qparams, cfg, jnp.full((1, 1), seq[-1], jnp.int32), cache_q)
        seq.append(_top1(lg_q))
    assert np.isfinite(np.asarray(lg_q, np.float32)).all()
    full = jnp.concatenate(
        [tokens, jnp.asarray(seq[:-1], jnp.int32)[None]], axis=1)
    out_q, _ = M.lm_forward(qparams, cfg, full)
    S = tokens.shape[1]
    replay = [int(jnp.argmax(out_q[0, S - 1 + i])) for i in range(steps)]
    assert replay == seq                 # decode path == forward path

    # --- teacher-forced tracking: same inputs, compare outputs ----------
    lg_f, cache_f = M.lm_prefill(params, cfg, tokens, 40)
    lg_q, cache_q = M.lm_prefill(qparams, cfg, tokens, 40)
    agree = int(_top1(lg_f) == _top1(lg_q))
    t = jnp.full((1, 1), _top1(lg_f), jnp.int32)   # fp drives both
    for _ in range(steps - 1):
        lg_f, cache_f = M.lm_decode_step(params, cfg, t, cache_f)
        lg_q, cache_q = M.lm_decode_step(qparams, cfg, t, cache_q)
        agree += int(_top1(lg_f) == _top1(lg_q))
        t = jnp.full((1, 1), _top1(lg_f), jnp.int32)
    # chance is steps/vocab ~ 0.016 expected hits; require >= 3
    assert agree >= 3, agree
    ref, _ = M.lm_forward(params, cfg, full)
    rel = float(jnp.max(jnp.abs(out_q - ref))
                / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.5, rel                # measured 0.33-0.40 across seeds


def test_abstract_quant_params_shapes():
    """eval_shape of the quantized tree (what the dry-run lowers against)."""
    from repro.core.quantize import QTensor
    cfg = get_config("deepseek-67b")
    p = abstract_params(cfg, quant_policy="nanomind-serve")
    w = p["layers"][0]["ffn"]["w_up"]
    assert isinstance(w, QTensor)
    assert w.codes.shape == (95, 8192, 22016 // 8)
    assert w.scales.shape == (95, 8192, 22016 // 32)
    # group 32 divides every 16-way shard of the last dim (EXPERIMENTS §Perf)
    assert (22016 // 16) % 32 == 0


def test_quant_leaf_sharding_rules():
    """QTensor codes/scales inherit the parent weight's rule (the
    FlattenedIndexKey regression from §Perf decode it2)."""
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as sh

    class StubMesh:
        devices = np.empty((16, 16), object)
        axis_names = ("data", "model")

    cfg = get_config("deepseek-67b")
    p = abstract_params(cfg, quant_policy="nanomind-serve")
    sh.set_mode("serve")
    try:
        specs = sh.tree_param_specs(StubMesh(), p)
        w_up = specs["layers"][0]["ffn"]["w_up"]
        leaves = jax.tree.leaves(w_up, is_leaf=lambda x: isinstance(x, P))
        codes_spec = leaves[0]
        assert "model" in tuple(codes_spec), codes_spec   # TP preserved
    finally:
        sh.set_mode("tp")


@pytest.mark.parametrize("shape", [(4, 64, 2, 16), (2, 128, 8, 32),
                                   (1, 256, 4, 64)])
def test_cache_update_kernel(key, shape):
    B, S, KV, hd = shape
    ks = jax.random.split(key, 2)
    cache = jax.random.normal(ks[0], shape, jnp.float32)
    row = jax.random.normal(ks[1], (B, KV, hd), jnp.float32)
    idx = jnp.asarray([(i * 7 + 3) % S for i in range(B)], jnp.int32)
    ref = ref_cache_row_update(cache, row, idx)
    out = cache_row_update(cache.copy(), row, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_cache_update_scalar_index(key):
    cache = jnp.zeros((2, 16, 2, 8))
    row = jnp.ones((2, 2, 8))
    out = cache_row_update(cache, row, jnp.asarray(5), interpret=True)
    assert float(out[:, 5].sum()) == 2 * 2 * 8
    assert float(out.sum()) == 2 * 2 * 8


def test_sharding_modes_roundtrip():
    from repro.distributed import sharding as sh
    assert sh.get_mode() == "tp"
    sh.set_mode("fsdp")
    assert sh.get_mode() == "fsdp"
    sh.set_mode("tp")
    with pytest.raises(AssertionError):
        sh.set_mode("bogus")
