"""W4A16 serving path (the paper's core technique at pod scale) + the
cache-update kernel: correctness of the quantized decode end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quantize import PROFILES, quantize_tree
from repro.kernels.cache_update import cache_row_update, ref_cache_row_update
from repro.launch.steps import abstract_params, init_params
from repro.models import model as M


def test_quantized_decode_runs_and_tracks_fp(key):
    """QTensor params flow through prefill + decode; outputs stay close to
    the bf16 model (top-1 mostly agrees at q4)."""
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    params = init_params(key, cfg)
    qparams = quantize_tree(params, PROFILES["nanomind-serve"])
    tokens = (jnp.arange(24)[None] % 60 + 3).astype(jnp.int32)

    lg_f, cache_f = M.lm_prefill(params, cfg, tokens, 32)
    lg_q, cache_q = M.lm_prefill(qparams, cfg, tokens, 32)
    agree = 0
    for _ in range(4):
        t_f = jnp.argmax(lg_f, -1)[:, None].astype(jnp.int32)
        t_q = jnp.argmax(lg_q, -1)[:, None].astype(jnp.int32)
        agree += int(t_f[0, 0] == t_q[0, 0])
        lg_f, cache_f = M.lm_decode_step(params, cfg, t_f, cache_f)
        lg_q, cache_q = M.lm_decode_step(qparams, cfg, t_q, cache_q)
    assert agree >= 3                    # q4 tracks fp on most steps
    assert np.isfinite(np.asarray(lg_q, np.float32)).all()


def test_abstract_quant_params_shapes():
    """eval_shape of the quantized tree (what the dry-run lowers against)."""
    from repro.core.quantize import QTensor
    cfg = get_config("deepseek-67b")
    p = abstract_params(cfg, quant_policy="nanomind-serve")
    w = p["layers"][0]["ffn"]["w_up"]
    assert isinstance(w, QTensor)
    assert w.codes.shape == (95, 8192, 22016 // 8)
    assert w.scales.shape == (95, 8192, 22016 // 32)
    # group 32 divides every 16-way shard of the last dim (EXPERIMENTS §Perf)
    assert (22016 // 16) % 32 == 0


def test_quant_leaf_sharding_rules():
    """QTensor codes/scales inherit the parent weight's rule (the
    FlattenedIndexKey regression from §Perf decode it2)."""
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as sh

    class StubMesh:
        devices = np.empty((16, 16), object)
        axis_names = ("data", "model")

    cfg = get_config("deepseek-67b")
    p = abstract_params(cfg, quant_policy="nanomind-serve")
    sh.set_mode("serve")
    try:
        specs = sh.tree_param_specs(StubMesh(), p)
        w_up = specs["layers"][0]["ffn"]["w_up"]
        leaves = jax.tree.leaves(w_up, is_leaf=lambda x: isinstance(x, P))
        codes_spec = leaves[0]
        assert "model" in tuple(codes_spec), codes_spec   # TP preserved
    finally:
        sh.set_mode("tp")


@pytest.mark.parametrize("shape", [(4, 64, 2, 16), (2, 128, 8, 32),
                                   (1, 256, 4, 64)])
def test_cache_update_kernel(key, shape):
    B, S, KV, hd = shape
    ks = jax.random.split(key, 2)
    cache = jax.random.normal(ks[0], shape, jnp.float32)
    row = jax.random.normal(ks[1], (B, KV, hd), jnp.float32)
    idx = jnp.asarray([(i * 7 + 3) % S for i in range(B)], jnp.int32)
    ref = ref_cache_row_update(cache, row, idx)
    out = cache_row_update(cache.copy(), row, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_cache_update_scalar_index(key):
    cache = jnp.zeros((2, 16, 2, 8))
    row = jnp.ones((2, 2, 8))
    out = cache_row_update(cache, row, jnp.asarray(5), interpret=True)
    assert float(out[:, 5].sum()) == 2 * 2 * 8
    assert float(out.sum()) == 2 * 2 * 8


def test_sharding_modes_roundtrip():
    from repro.distributed import sharding as sh
    assert sh.get_mode() == "tp"
    sh.set_mode("fsdp")
    assert sh.get_mode() == "fsdp"
    sh.set_mode("tp")
    with pytest.raises(AssertionError):
        sh.set_mode("bogus")
