"""Cascade inference: equivalence with the monolithic forward + the
max-not-sum peak-memory claim (paper Fig. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.bricks import decompose
from repro.core.cascade import CascadeRunner, CascadeTrace
from repro.launch.steps import init_params
from repro.models.model import lm_forward


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "llava-onevision-0.5b",
                                  "mamba2-1.3b", "deepseek-moe-16b"])
def test_cascade_equals_monolithic(key, arch):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    tokens = jnp.arange(32)[None] % 64 + 3
    batch = {"tokens": tokens}
    if cfg.vlm:
        batch["vision_feats"] = jnp.full(
            (1, cfg.vision_tokens, cfg.vision_feat_dim), 0.01)
    mono, _ = lm_forward(params, cfg, tokens,
                         vision_feats=batch.get("vision_feats"))
    runner = CascadeRunner(decompose(cfg), params)
    out, trace = runner.run_once(batch)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(mono, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert trace.peak_bytes < trace.sum_bytes


def test_cascade_peak_is_max_not_sum(key):
    """load->execute->release: resident bytes never exceed the largest
    brick (+ the hand-off activations), far below sum(bricks)."""
    cfg = get_config("stablelm-12b").reduced(n_layers=4)
    params = init_params(key, cfg)
    g = decompose(cfg)
    runner = CascadeRunner(g, params)
    _, trace = runner.run_once({"tokens": jnp.ones((1, 16), jnp.int32)})
    from repro.core.bricks import brick_param_bytes
    sizes = brick_param_bytes(g, params)
    biggest = max(sizes.values())
    # peak within 1.5x of the biggest single brick, << sum
    assert trace.peak_bytes <= 1.5 * biggest
    assert trace.peak_bytes < 0.9 * trace.sum_bytes
    # release events really drop residency
    loads = [e.resident_bytes for e in trace.events if e.phase == "load"]
    releases = [e.resident_bytes for e in trace.events
                if e.phase == "release"]
    assert min(releases) < max(loads)


def test_cascade_encdec(key):
    cfg = get_config("seamless-m4t-large-v2").reduced()
    params = init_params(key, cfg)
    runner = CascadeRunner(decompose(cfg), params)
    out, trace = runner.run_once({
        "src_embeds": jnp.full((1, 16, cfg.d_model), 0.01),
        "tgt_tokens": jnp.ones((1, 8), jnp.int32)})
    assert out.shape[0] == 1 and np.isfinite(np.asarray(out)).all()
    assert trace.peak_bytes < trace.sum_bytes
