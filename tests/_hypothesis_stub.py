"""Minimal stand-in for `hypothesis` when it isn't installed.

The container this repo targets does not ship hypothesis and nothing may be
pip-installed, so conftest.py falls back to this shim: it implements just
the surface the test-suite uses — ``given`` over ``integers / floats /
sampled_from / lists / tuples`` strategies plus the ``settings`` /
``HealthCheck`` profile plumbing — as deterministic seeded random sampling
(default 25 examples per test, matching the "ci" profile).  It does NOT
shrink failures or remember a database; with the real hypothesis installed
this module is never imported.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    large_base_example = "large_base_example"


class settings:
    _profiles: dict = {}
    _current: dict = {"max_examples": 25}

    def __init__(self, **kwargs):
        self.kwargs = kwargs

    def __call__(self, fn):                    # used as @settings(...)
        fn._stub_settings = self.kwargs
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        cls._current = {"max_examples": 25, **cls._profiles.get(name, {})}


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, fn):
        return SearchStrategy(lambda rnd: fn(self._draw(rnd)))


def integers(min_value=0, max_value=2 ** 31 - 1):
    return SearchStrategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_):
    return SearchStrategy(lambda rnd: rnd.uniform(min_value, max_value))


def booleans():
    return SearchStrategy(lambda rnd: rnd.random() < 0.5)


def sampled_from(seq):
    seq = list(seq)
    return SearchStrategy(lambda rnd: rnd.choice(seq))


def lists(elements, min_size=0, max_size=10):
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.draw(rnd) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*strategies):
    return SearchStrategy(lambda rnd: tuple(s.draw(rnd) for s in strategies))


def given(*_args, **strategy_kwargs):
    if _args:
        raise TypeError("stub @given supports keyword strategies only")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = int(settings._current.get("max_examples", 25))
            n = int(getattr(fn, "_stub_settings", {}).get("max_examples", n))
            # deterministic per-test seed so failures reproduce
            rnd = random.Random(f"stub:{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = {k: s.draw(rnd) for k, s in strategy_kwargs.items()}
                fn(*args, **drawn, **kwargs)
        # hide the drawn params from pytest's fixture resolution: the
        # wrapper's visible signature keeps only real fixtures (like `key`)
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=keep)
        del wrapper.__wrapped__                # pytest must not unwrap to fn
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return decorate


def assume(condition):
    return bool(condition)


def _install():
    mod = types.ModuleType("hypothesis")
    mod.HealthCheck = HealthCheck
    mod.settings = settings
    mod.given = given
    mod.assume = assume
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples"):
        setattr(st, name, globals()[name])
    st.SearchStrategy = SearchStrategy
    mod.strategies = st
    mod.__is_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install()
