"""Telemetry subsystem (PR 8): ledger arithmetic + persistence, the
measured->scheduler calibration feedback, wall-time probes on the
plan/engine hot paths, the shared BENCH writer + regression gate, and
the fleet-scale battery simulator (incl. PMU/PowerPolicy replay from a
recorded fleet trace)."""
import dataclasses
import importlib.util
import json
import os

import numpy as np
import pytest

import jax
from hypothesis import given, strategies as hst

from repro.configs import get_config
from repro.core.bricks import decompose
from repro.core.power import PowerPolicy, PowerState
from repro.core.scheduler import (brick_cost, edge_accelerators,
                                  kv_block_budgets, schedule)
from repro.core.tabm import SlotClassPool
from repro.launch.steps import init_params
from repro.serving.engine import Request, ServingEngine, TraceEvent
from repro.telemetry import CostCalibration, Ledger, PhaseRecord, WallProbe
from repro.telemetry import writer
from repro.telemetry.fleet import (FleetSimulator, ModalityProfile,
                                   replay_trace)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _graph(arch="llava-onevision-0.5b"):
    g = decompose(get_config(arch))
    g.bricks = [dataclasses.replace(
        b, param_bytes=max(1, int(b.flops_per_token)))
        for b in g.bricks]
    return g


# ---------------------------------------------------------------------------
# ledger arithmetic + persistence
# ---------------------------------------------------------------------------

def test_phase_record_algebra():
    a = PhaseRecord(flops=10, bytes=4, tokens=2, joules=1.0, seconds=0.5,
                    samples=1)
    b = PhaseRecord(flops=30, bytes=6, tokens=2, joules=3.0, seconds=0.5,
                    samples=2)
    s = a + b
    assert (s.flops, s.bytes, s.tokens, s.samples) == (40, 10, 4, 3)
    assert s.j_per_token == pytest.approx(1.0)
    assert s.tokens_per_s == pytest.approx(4.0)
    d = a * 3
    assert d.flops == 30 and d.tokens == 6
    assert d.samples == 1, "samples is a count, not an extensive quantity"
    assert PhaseRecord().j_per_token == 0.0   # no division by zero


def test_ledger_accumulate_merge_scale_roundtrip(tmp_path):
    led = Ledger()
    led.accumulate("decoder", "decode", seconds=1.0, tokens=10, joules=2.0,
                   samples=1)
    led.accumulate("decoder", "decode", seconds=1.0, tokens=10, samples=1)
    assert led.record("decoder", "decode").tokens == 20
    assert led.record("decoder", "decode").samples == 2

    other = Ledger(meta={"bench": "x"})
    other.accumulate("projector", "stage", seconds=0.5, tokens=100,
                     samples=3)
    merged = led + other
    assert len(merged) == 2 and len(led) == 1   # __add__ does not mutate
    led.merge(other)
    assert len(led) == 2 and led.meta["bench"] == "x"

    half = led.scale(0.5)
    assert half.record("decoder", "decode").tokens == 10
    assert half.record("decoder", "decode").samples == 2

    path = tmp_path / "ledger.json"
    led.save(str(path))
    back = Ledger.load(str(path))
    assert back.to_dict() == led.to_dict()
    with pytest.raises(ValueError):
        led.accumulate("decoder", "warmup", tokens=1)


def test_ledger_total_uses_phase_token_max_rule():
    """Bricks chain: embed/decoder/head all see the SAME decode stream,
    so phase tokens aggregate by max, while seconds/joules add."""
    led = Ledger()
    for brick in ("embed", "decoder", "head"):
        led.accumulate(brick, "decode", seconds=1.0, tokens=50, joules=1.0)
    tot = led.total("decode")
    assert tot.tokens == 50
    assert tot.seconds == pytest.approx(3.0)
    assert tot.joules == pytest.approx(3.0)
    assert led.j_per_token("decode") == pytest.approx(3.0 / 50)


@given(recs=hst.lists(
    hst.tuples(hst.integers(1, 100), hst.integers(1, 100),
               hst.integers(0, 5)), min_size=1, max_size=8))
def test_ledger_merge_linear_property(recs):
    """Property (hypothesis): folding records one-by-one equals one
    bulk-merged ledger, and JSON round-trip preserves it exactly."""
    one = Ledger()
    parts = []
    for tok, sec, n in recs:
        part = Ledger()
        part.accumulate("b", "decode", tokens=tok, seconds=sec, samples=n)
        parts.append(part)
        one.accumulate("b", "decode", tokens=tok, seconds=sec, samples=n)
    bulk = Ledger()
    for p in parts:
        bulk.merge(p)
    assert bulk.to_dict() == one.to_dict()
    assert Ledger.from_dict(one.to_dict()).to_dict() == one.to_dict()


def test_ledger_modeled_from_cost_model():
    """Static population: compile-time roofline+energy rows, samples==0."""
    g = _graph()
    accels = edge_accelerators()
    pl = schedule(g, accels, n_tokens=64, objective="energy")
    by_name = {a.name: a for a in accels}
    accel_for = {b: by_name[a] for b, a in pl.assignment.items()}
    led = Ledger.modeled(g, accel_for, phase_tokens={
        "stage": 729, "prefill": 64, "decode": 1})
    assert len(led) > 0 and led.meta["source"] == "modeled"
    for _brick, _phase, rec in led.items():
        assert rec.samples == 0, "modeled rows must not look measured"
        assert rec.seconds > 0 and rec.joules > 0
    # decoder-side bricks never appear in the stage phase and vice versa
    phases_of = {}
    for brick, phase, _ in led.items():
        phases_of.setdefault(brick, set()).add(phase)
    assert "decode" not in phases_of.get("projector", set())
    assert "stage" not in phases_of.get("decoder", set())
    # and a profile built from it prices every phase
    prof = ModalityProfile.from_ledger(led)
    assert all(prof.j_per_token[p] > 0 for p in ("stage", "prefill",
                                                 "decode"))


# ---------------------------------------------------------------------------
# calibration: measured overrides modeled
# ---------------------------------------------------------------------------

def test_calibration_observe_lookup_fallback_roundtrip(tmp_path):
    cal = CostCalibration(prior=4)
    assert not cal and cal.sample("decoder", "rk-gpu") is None
    cal.observe("decoder", None, seconds=2.0, tokens=100, n=2)
    # profile-agnostic fallback: exact key misses, (brick, None) hits
    s = cal.sample("decoder", "rk-gpu")
    assert s is not None and s.seconds_per_token == pytest.approx(0.02)
    cal.observe("decoder", "rk-gpu", seconds=1.0, tokens=100, joules=5.0)
    exact = cal.sample("decoder", "rk-gpu")
    assert exact.seconds_per_token == pytest.approx(0.01)
    assert cal.weight(0) == 0.0 and cal.weight(4) == pytest.approx(0.5)
    assert cal.weight(4000) > 0.99
    # energy pressure: measured/modeled J per token; 1.0 with no joules
    assert cal.energy_pressure("decoder", None, 1.0) == 1.0
    assert cal.energy_pressure("decoder", "rk-gpu", 0.025) == pytest.approx(
        2.0)
    path = tmp_path / "cal.json"
    cal.save(str(path))
    back = CostCalibration.load(str(path))
    assert back.to_dict() == cal.to_dict()


def test_calibration_from_ledger_skips_modeled_rows():
    led = Ledger()
    led.accumulate("decoder", "decode", seconds=1.0, tokens=10, samples=2)
    led.accumulate("embed", "decode", seconds=9.0, tokens=10, samples=0)
    cal = CostCalibration.from_ledger(led)
    assert cal.sample("decoder") is not None
    assert cal.sample("embed") is None, "samples==0 rows are predictions"


def test_brick_cost_calibrated_vs_modeled():
    g = _graph()
    acc = next(a for a in edge_accelerators() if a.name == "gpu")
    brick = g.brick("decoder")
    base = brick_cost(brick, acc, 64)
    # empty table: calibration is a no-op
    assert brick_cost(brick, acc, 64,
                      calibration=CostCalibration()).latency_s == \
        base.latency_s
    # a disagreeing measurement changes the cost...
    cal = CostCalibration(prior=4)
    slow = base.latency_s / 64 * 10            # 10x slower per token
    cal.observe("decoder", acc.profile.name, seconds=slow * 640,
                tokens=640, n=4)
    mixed = brick_cost(brick, acc, 64, calibration=cal)
    assert mixed.latency_s > base.latency_s
    # ...blended at n==prior exactly halfway...
    assert mixed.latency_s == pytest.approx(
        0.5 * base.latency_s + 0.5 * slow * 64, rel=1e-9)
    # ...and measurement dominates at large n
    cal2 = CostCalibration(prior=4)
    cal2.observe("decoder", acc.profile.name, seconds=slow * 640,
                 tokens=640, n=4000)
    assert brick_cost(brick, acc, 64,
                      calibration=cal2).latency_s == pytest.approx(
        slow * 64, rel=1e-2)
    # energy stays modeled when the sample carries no joules
    assert mixed.energy_j == pytest.approx(base.energy_j)
    # infeasible stays infeasible regardless of observations
    npu = next(a for a in edge_accelerators() if a.name == "npu")
    dyn = dataclasses.replace(brick, static_shape=False)
    cal3 = CostCalibration()
    cal3.observe(dyn.name, npu.profile.name, seconds=1e-9, tokens=1e6,
                 n=10_000)
    assert not brick_cost(dyn, npu, 64, calibration=cal3).feasible


def test_schedule_placement_flips_under_calibration():
    """The DP prices from observation: a brick measured pathologically
    slow on its modeled-best unit migrates off it."""
    g = _graph()
    accels = edge_accelerators()
    base = schedule(g, accels, 256, "latency")
    victim = "decoder"
    home = base.assignment[victim]
    prof = next(a for a in accels if a.name == home).profile.name
    cal = CostCalibration(prior=1)
    cal.observe(victim, prof, seconds=1e4, tokens=1.0, n=10_000)
    moved = schedule(g, accels, 256, "latency", calibration=cal)
    assert moved.assignment[victim] != home, (
        f"{victim} stayed on {home} despite measured 1e4 s/token")
    # untouched table reproduces the modeled placement
    assert schedule(g, accels, 256, "latency",
                    calibration=CostCalibration()).assignment == \
        base.assignment


def test_kv_budgets_tighten_under_energy_pressure():
    cfg = get_config("llava-onevision-0.5b").reduced()
    pool = SlotClassPool.from_config(cfg, slots_per_class=2)
    names = list(pool.classes)                 # ascending by slab size
    calm = kv_block_budgets(pool, 100, {}, kv_scale=1.0)
    hot = kv_block_budgets(pool, 100, {}, kv_scale=1.0,
                           energy_pressure=2.0)
    assert hot[names[-1]] < calm[names[-1]], (
        "hotter-than-modeled decode must shed hi-res KV grants earlier")
    assert hot[names[0]] == calm[names[0]] == 100, (
        "the thumbnail class keeps the pool (hi-res sheds first)")
    # better-than-modeled energy never RELAXES beyond the battery knob
    cool = kv_block_budgets(pool, 100, {}, kv_scale=0.5,
                            energy_pressure=0.25)
    assert cool == kv_block_budgets(pool, 100, {}, kv_scale=0.5)


# ---------------------------------------------------------------------------
# probes + engine/plan integration
# ---------------------------------------------------------------------------

def test_wall_probe_record_and_to_ledger():
    probe = WallProbe()
    probe.record("decoder", "decode", 0.25, tokens=4)
    with probe.span("projector", "stage", tokens=8):
        pass
    assert len(probe) == 2
    ts = [s.t for s in probe.samples()]
    assert ts == sorted(ts), "monotonic stamps order samples"
    led = probe.to_ledger(meta={"collector": "test"})
    rec = led.record("decoder", "decode")
    assert rec.seconds == pytest.approx(0.25) and rec.tokens == 4
    assert rec.samples == 1 and rec.joules == 0.0
    assert led.record("projector", "stage").samples == 1
    probe.clear()
    assert len(probe) == 0


def test_engine_probes_and_monotonic_trace():
    """One synchronous engine run populates measured prefill/decode (and
    vision staging) ledger rows, the trace is TraceEvent-typed with
    nondecreasing monotonic stamps, and the measured calibration is
    consumable by the scheduler."""
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=np.arange(6 + i) + 3, max_new_tokens=3,
                    vision_feats=rng.standard_normal(
                        (1, cfg.vision_tokens, cfg.vision_feat_dim)
                    ).astype(np.float32) * 0.02)
            for i in range(2)]
    with ServingEngine(cfg, params, n_slots=2, max_len=128,
                       async_staging=False) as eng:
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 2 and all(r.error is None for r in done)
        led = eng.measured_ledger()
        assert led.record("decoder", "decode").samples > 0
        assert led.record("decoder", "decode").tokens > 0
        assert led.record("decoder", "prefill").samples > 0
        # the plan probe contributed vision-side staging spans too
        assert any(phase == "stage" for _b, phase, _r in led.items())
        events = list(eng.trace)
        assert events and all(isinstance(e, TraceEvent) for e in events)
        # satellite: timestamps are time.monotonic(), nondecreasing in
        # append order on this single-threaded run
        stamps = [e.t for e in events]
        assert stamps == sorted(stamps)
        # legacy tuple-unpacking consumers keep working
        assert all(isinstance(e.rid, int) for e in events)
        for ev, _rid, _t in events:
            assert isinstance(ev, str)
        cal = eng.measured_calibration()
        assert cal and cal.sample("decoder") is not None
        # measured-latency feedback prices differently from the pure model
        g = _graph()
        acc = next(a for a in edge_accelerators() if a.name == "gpu")
        assert brick_cost(g.brick("decoder"), acc, 8,
                          calibration=cal).latency_s != \
            brick_cost(g.brick("decoder"), acc, 8).latency_s


# ---------------------------------------------------------------------------
# fleet simulator + trace replay
# ---------------------------------------------------------------------------

def _small_fleet(**kw):
    kw.setdefault("battery_mah", 150.0)
    kw.setdefault("dt_s", 10.0)
    return FleetSimulator(120, ModalityProfile.default_edge(), seed=7, **kw)


def test_fleet_deterministic_and_traverses_all_states():
    rep1 = _small_fleet().run(2.0)
    rep2 = _small_fleet().run(2.0)
    assert rep1.tokens_per_s == rep2.tokens_per_s
    assert rep1.j_per_token == rep2.j_per_token
    assert np.array_equal(rep1.survival_hours, rep2.survival_hours)
    assert rep1.n_devices == 120 and rep1.j_per_token > 0
    assert rep1.states_seen == {s.value for s in PowerState}
    assert all(rep1.state_ticks[s] > 0 for s in rep1.states_seen)
    assert rep1.dead > 0 and rep1.survival_hours_p50 <= rep1.hours
    assert rep1.shed_tokens > 0, "throttling/cascade must shed load"
    counts, _edges = rep1.histogram()
    assert counts.sum() == rep1.n_devices
    assert "tokens/s" in rep1.summary()
    with pytest.raises(ValueError):
        FleetSimulator(0, ModalityProfile.default_edge())


def test_fleet_seed_changes_fleet():
    a = FleetSimulator(50, ModalityProfile.default_edge(), seed=1,
                       battery_mah=150.0, dt_s=10.0).run(1.0)
    b = FleetSimulator(50, ModalityProfile.default_edge(), seed=2,
                       battery_mah=150.0, dt_s=10.0).run(1.0)
    assert a.tokens_per_s != b.tokens_per_s


def test_fleet_trace_replays_through_fresh_pmu_policy():
    """PMU/PowerPolicy transitions are a pure function of the drain
    history: re-driving the recorded per-tick joules through FRESH
    instances reproduces every recorded state and charge level."""
    sim = _small_fleet(record_trace=True)
    sim.run(1.5)
    events = list(sim.trace)
    assert events, "trace recording produced nothing"
    assert {e.state for e in events} == {s.value for s in PowerState}
    replayed = replay_trace(events, battery_mah=150.0,
                            policy=PowerPolicy())
    per_dev = {}
    for e in events:
        per_dev.setdefault(e.device, []).append(e)
    for dev, evs in per_dev.items():
        got = replayed[dev]
        assert len(got) == len(evs)
        for (state, level), ev in zip(got, evs):
            assert state == ev.state, (dev, ev)
            assert level == pytest.approx(ev.level, abs=1e-12)


# ---------------------------------------------------------------------------
# shared writer + regression gate
# ---------------------------------------------------------------------------

def test_writer_merge_sections_and_ledger(tmp_path):
    path = str(tmp_path / "BENCH_8.json")
    led_a = Ledger()
    led_a.accumulate("decoder", "decode", seconds=1.0, tokens=10, samples=1)
    writer.merge_section(path, "alpha", rows=[("a/x", 1.0, "d=1")],
                         metrics={"m": writer.metric(2.0, gate=False)},
                         ledger=led_a)
    led_b = Ledger()
    led_b.accumulate("decoder", "decode", seconds=1.0, tokens=10, samples=1)
    data = writer.merge_section(
        path, "beta", rows=[("b/y", 2.0, "d=2")],
        metrics={"g": writer.metric(5.0, better="lower")}, ledger=led_b)
    # separate processes accumulate into ONE file
    assert set(data["sections"]) == {"alpha", "beta"}
    assert data["sections"]["alpha"]["rows"] == [["a/x", 1.0, "d=1"]]
    merged = Ledger.from_dict(data["ledger"])
    assert merged.record("decoder", "decode").tokens == 20
    assert merged.record("decoder", "decode").samples == 2
    # only gate:true metrics are gateable
    assert list(writer.gated_metrics(data)) == ["beta/g"]
    # a foreign-PR file is restarted, not merged into
    data2 = writer.merge_section(path, "gamma", rows=[], pr=99)
    assert set(data2["sections"]) == {"gamma"} and data2["pr"] == 99
    # csv side-emit and round-trip
    csv = tmp_path / "rows.csv"
    writer.write_csv(str(csv), [("a/x", 1.0, "d=1")])
    assert csv.read_text().splitlines()[0] == writer.CSV_HEADER
    assert writer.read_bench(path)["sections"]["gamma"] == {"rows": []}


def test_latest_baseline_picks_highest_and_excludes_candidate(tmp_path):
    for n in (3, 8, 12):
        (tmp_path / f"BENCH_{n}.json").write_text("{}")
    (tmp_path / "BENCH_x.json").write_text("{}")
    assert writer.latest_baseline(str(tmp_path)).endswith("BENCH_12.json")
    assert writer.latest_baseline(
        str(tmp_path),
        exclude=str(tmp_path / "BENCH_12.json")).endswith("BENCH_8.json")
    assert writer.latest_baseline(str(tmp_path / "empty")) is None


def _bench_gate():
    spec = importlib.util.spec_from_file_location(
        "bench_gate", os.path.join(REPO, "scripts", "bench_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench_data(**metrics):
    return {"schema": 1, "pr": 8,
            "sections": {"s": {"metrics": metrics}}, "ledger": None}


def test_bench_gate_compare():
    gate = _bench_gate()
    base = _bench_data(tps=writer.metric(100.0, better="higher"),
                       jpt=writer.metric(0.04, better="lower"),
                       wall=writer.metric(123.0, gate=False))
    # within tolerance both directions -> pass (ungated ignored entirely)
    ok, _ = gate.compare(base, _bench_data(
        tps=writer.metric(95.0), jpt=writer.metric(0.043),
        wall=writer.metric(9999.0, gate=False)))
    assert ok
    # >10% tokens/s drop -> fail
    ok, lines = gate.compare(base, _bench_data(
        tps=writer.metric(80.0), jpt=writer.metric(0.04)))
    assert not ok and any(line.startswith("FAIL s/tps") for line in lines)
    # >10% J/token rise -> fail
    ok, _ = gate.compare(base, _bench_data(
        tps=writer.metric(100.0), jpt=writer.metric(0.05, better="lower")))
    assert not ok
    # a dropped gated metric fails unless explicitly allowed
    ok, _ = gate.compare(base, _bench_data(tps=writer.metric(100.0)))
    assert not ok
    ok, _ = gate.compare(base, _bench_data(tps=writer.metric(100.0)),
                         allow_missing=True)
    assert ok
    # empty baseline gates nothing
    ok, lines = gate.compare(_bench_data(), _bench_data())
    assert ok and "no gated metrics" in lines[-1]


def test_committed_bench_parses_and_self_gates():
    """The committed BENCH_<CURRENT_PR>.json was produced through the
    shared writer: it parses, carries gated metrics + a ledger, and
    gates cleanly against itself."""
    path = os.path.join(REPO, f"BENCH_{writer.CURRENT_PR}.json")
    assert os.path.exists(path), \
        f"BENCH_{writer.CURRENT_PR}.json must be committed"
    data = writer.read_bench(path)
    assert data["schema"] == writer.SCHEMA
    assert data["pr"] == writer.CURRENT_PR
    gated = writer.gated_metrics(data)
    assert gated, "the committed ledger must carry gateable metrics"
    assert any(k.startswith("fleet/") for k in gated)
    led = Ledger.from_dict(data["ledger"])
    assert len(led) > 0
    json.dumps(data)                            # fully JSON-serializable
    ok, _ = _bench_gate().compare(data, data)
    assert ok, "a ledger must never regress against itself"
