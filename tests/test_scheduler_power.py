"""Scheduler DP optimality (vs brute force, hypothesis), the paper's
NPU-wins-encoders observation, and the battery policy."""
import dataclasses
import itertools

import jax
import pytest
from hypothesis import given, strategies as hst

from repro.analysis.energy import EDGE_GPU, EDGE_NPU
from repro.core.bricks import decompose
from repro.core.power import (BatteryAwareExecutor, Knobs, PMU, PowerPolicy,
                              PowerState)
from repro.core.scheduler import (Accelerator, Placement, brick_cost,
                                  edge_accelerators, edge_bytes,
                                  populate_brick_bytes, schedule,
                                  transfer_cost)
from repro.configs import get_config
from repro.launch.steps import init_params


def _graph(arch="llava-onevision-0.5b"):
    cfg = get_config(arch)                     # FULL config: real ratios
    g = decompose(cfg)
    # analytic param bytes (no allocation of the full model)
    g.bricks = [dataclasses.replace(
        b, param_bytes=max(1, int(b.flops_per_token)))
        for b in g.bricks]
    return g


def _brute_force(graph, accels, n_tokens, objective):
    best, best_cost = None, float("inf")
    bricks = graph.bricks
    xfer = edge_bytes(graph, n_tokens)
    for combo in itertools.product(range(len(accels)), repeat=len(bricks)):
        total = 0.0
        ok = True
        prev = None
        for b, a in zip(bricks, combo):
            c = brick_cost(b, accels[a], n_tokens)
            if not c.feasible:
                ok = False
                break
            total += c.energy_j if objective == "energy" else c.latency_s
            if prev is not None and prev != a:
                tt, te = transfer_cost(xfer, accels[prev], accels[a])
                total += te if objective == "energy" else tt
            prev = a
        if ok and total < best_cost:
            best, best_cost = combo, total
    return best_cost


@given(seed=hst.integers(0, 10_000),
       objective=hst.sampled_from(["latency", "energy"]))
def test_dp_matches_brute_force(seed, objective):
    import random
    rnd = random.Random(seed)
    g = _graph()
    # randomize brick weights so the DP search space is non-trivial
    g.bricks = [dataclasses.replace(
        b, param_bytes=rnd.randint(1, 10**9),
        flops_per_token=rnd.uniform(0, 1e9),
        static_shape=rnd.random() < 0.5) for b in g.bricks]
    accels = edge_accelerators()
    bf = _brute_force(g, accels, 256, objective)
    pl = schedule(g, accels, 256, objective)
    got = pl.energy_j if objective == "energy" else pl.latency_s
    assert got == pytest.approx(bf, rel=1e-6)


def test_static_only_constraint_respected():
    g = _graph()
    pl = schedule(g, edge_accelerators(), 256, "latency")
    npu_bricks = [n for n, a in pl.assignment.items() if a == "npu"]
    for name in npu_bricks:
        assert g.brick(name).static_shape


def test_paper_observation_npu_wins_encoder():
    """Sec. 4: 'NPUs consistently outperform other units for encoder
    inference' — must emerge from the cost model on the paper's own model
    (SigLip-class encoder + 0.5B decoder)."""
    g = _graph("qwen2-vl-7b")
    pl = schedule(g, edge_accelerators(), n_tokens=1024, objective="latency")
    assert pl.assignment["projector"] == "npu"
    assert pl.assignment["decoder"] in ("gpu", "cpu")


def test_energy_objective_prefers_lower_power():
    g = _graph()
    lat = schedule(g, edge_accelerators(), 256, "latency")
    en = schedule(g, edge_accelerators(), 256, "energy")
    assert en.energy_j <= lat.energy_j + 1e-12


# ---------------------------------------------------------------------------
# power policy
# ---------------------------------------------------------------------------

def test_three_states_and_alpha():
    pol = PowerPolicy(t_high=0.6, t_low=0.2)
    assert pol.state(0.9) is PowerState.UNCONSTRAINED
    assert pol.state(0.5) is PowerState.THROTTLED
    assert pol.state(0.1) is PowerState.CRITICAL
    # alpha linear in (t_low, t_high)
    assert pol.alpha(0.6) == pytest.approx(1.0)
    assert pol.alpha(0.4) == pytest.approx(0.5)
    assert pol.alpha(0.2) == pytest.approx(0.0)


@given(b=hst.floats(0.0, 1.0))
def test_knobs_monotone_in_battery(b):
    pol = PowerPolicy()
    k_lo = pol.knobs(max(0.0, b - 0.1))
    k_hi = pol.knobs(min(1.0, b + 0.1))
    assert k_lo.max_batch <= k_hi.max_batch
    assert k_lo.frame_rate_hz <= k_hi.frame_rate_hz + 1e-9


def test_pmu_drain_and_critical_switches_to_cascade():
    ex = BatteryAwareExecutor(PMU(battery_mah=100))
    ex.pmu.level = 0.21
    st, knobs, obj = ex.current()
    assert st is PowerState.THROTTLED and obj == "energy"
    ex.pmu.drain(ex.pmu.capacity_j * 0.05)
    st, knobs, obj = ex.current()
    assert st is PowerState.CRITICAL and knobs.cascade


def test_brick_decomposition_covers_params(key):
    """Every top-level param entry is owned by >= 1 brick; applying the
    chain reproduces the monolithic forward (see test_cascade)."""
    for arch in ("stablelm-1.6b", "qwen2-vl-7b", "seamless-m4t-large-v2"):
        cfg = get_config(arch).reduced()
        params = init_params(key, cfg)
        g = decompose(cfg)
        owned = set()
        for b in g.bricks:
            owned |= set(b.param_keys)
        assert owned == set(params.keys()), (arch, owned, set(params.keys()))
