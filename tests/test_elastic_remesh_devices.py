"""Elastic re-mesh with REAL (placeholder) devices: train on a (2,4) mesh,
'lose a host', restore the topology-free checkpoint onto a (1,4) mesh and
keep training.  Runs in a subprocess (device count is locked at jax init).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.data import multimodal_batch_iter
    from repro.distributed import checkpoint as ck
    from repro.distributed import sharding as sh
    from repro.distributed.fault_tolerance import plan_remesh
    from repro.launch.steps import init_params
    from repro.training.optimizer import OptConfig, init_opt
    from repro.training.train_loop import build_accum_train_step

    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    oc = OptConfig(lr=1e-3, warmup_steps=1)
    step_fn = jax.jit(build_accum_train_step(cfg, oc, 1),
                      donate_argnums=(0, 1))
    data = multimodal_batch_iter(cfg, global_batch=8, seq_len=64)

    # phase 1: 8 devices as (2 data, 4 model)
    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    pspecs = sh.tree_param_specs(mesh1, params)
    params = jax.device_put(params, sh.tree_shardings(mesh1, pspecs))
    opt = init_opt(params, oc)
    losses = []
    with mesh1:
        for _ in range(3):
            batch = jax.tree.map(jnp.asarray, next(data))
            params, opt, m = step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
    d = tempfile.mkdtemp()
    ck.save(d, 3, {"params": params, "opt": opt})

    # phase 2: a host dies -> survivors host only 4 devices; the plan
    # preserves the model axis and shrinks DP
    plan = plan_remesh(alive_workers=[0], devices_per_worker=4, model_axis=4)
    assert plan.shape == (1, 4), plan.shape
    mesh2 = jax.make_mesh(plan.shape, plan.axes)
    like = {"params": params, "opt": opt}
    shards = {"params": sh.tree_shardings(
                  mesh2, sh.tree_param_specs(mesh2, params)),
              "opt": sh.tree_shardings(
                  mesh2, sh.tree_param_specs(mesh2, opt))}
    state, step, _ = ck.restore(d, like, shardings=shards)
    params2, opt2 = state["params"], state["opt"]
    data.seek if hasattr(data, "seek") else None
    with mesh2:
        for _ in range(2):
            batch = jax.tree.map(jnp.asarray, next(data))
            params2, opt2, m = step_fn(params2, opt2, batch)
            losses.append(float(m["loss"]))
    assert all(l == l for l in losses)          # finite
    assert losses[-1] < losses[0] + 1.0         # no blow-up across re-mesh
    print("REMESH_OK", losses)
""")


@pytest.mark.slow
def test_elastic_remesh_across_topologies():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env,
                          cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "REMESH_OK" in proc.stdout
