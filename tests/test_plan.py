"""ExecutionPlan: one runtime behind engine, cascade, and scheduler.

Covers the api_redesign acceptance criteria:
* engine-path, cascade-path, and monolithic forward produce identical
  logits for the same params/inputs (lm and vlm archs);
* a Placement from schedule() on edge_accelerators() compiles to an
  ExecutionPlan that really executes (vlm logits match monolithic);
* CascadeRunner contains no per-kind dispatch;
* the TABM lifecycle FULL -> stall -> drain drives through the engine path.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import cascade as cascade_mod
from repro.core.bricks import decompose
from repro.core.cascade import CascadeRunner
from repro.core.plan import PlanError, PlanTrace, compile_plan
from repro.core.scheduler import (edge_accelerators, populate_brick_bytes,
                                  schedule)
from repro.core.tabm import RingBuffer
from repro.launch.steps import init_params
from repro.models.model import lm_forward
from repro.serving.engine import Request, ServingEngine


def _setup(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(3, 200, (1, 24)), jnp.int32)
    inputs = {"tokens": tokens}
    if cfg.vlm:
        inputs["vision_feats"] = jnp.asarray(
            rng.standard_normal((1, cfg.vision_tokens, cfg.vision_feat_dim))
            * 0.02, jnp.float32)
    return cfg, params, inputs


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "llava-onevision-0.5b"])
def test_plan_cascade_monolithic_identical_logits(key, arch):
    """The three execution paths are the same function."""
    cfg, params, inputs = _setup(arch, key)
    mono, _ = lm_forward(params, cfg, inputs["tokens"],
                         vision_feats=inputs.get("vision_feats"))
    mono = np.asarray(mono, np.float32)

    plan = compile_plan(decompose(cfg), params)          # engine runtime
    out_plan, _ = plan.run(inputs)
    np.testing.assert_allclose(np.asarray(out_plan, np.float32), mono,
                               rtol=2e-2, atol=2e-2)

    out_casc, trace = CascadeRunner(decompose(cfg), params).run_once(inputs)
    np.testing.assert_allclose(np.asarray(out_casc, np.float32), mono,
                               rtol=2e-2, atol=2e-2)
    assert trace.peak_bytes < trace.sum_bytes            # one-brick residency


def test_schedule_output_is_executable(key):
    """Placement on edge_accelerators() -> compile_plan -> one vlm
    inference; logits match the monolithic forward and the TABM edge's
    full slot lifecycle ran."""
    cfg, params, inputs = _setup("llava-onevision-0.5b", key)
    graph = decompose(cfg)
    populate_brick_bytes(graph, params)
    accels = edge_accelerators()
    placement = schedule(graph, accels, n_tokens=24, objective="latency")
    assert set(placement.assignment) == set(graph.names())

    ring = RingBuffer(n_slots=2, max_tokens=cfg.vision_tokens,
                      dim=cfg.d_model)
    plan = compile_plan(graph, params, placement=placement, accels=accels,
                        tabm=ring)
    out, _ = plan.run(inputs)
    mono, _ = lm_forward(params, cfg, inputs["tokens"],
                         vision_feats=inputs["vision_feats"])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(mono, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert ring.stats["writes"] == ring.stats["reads"] == 1
    assert all(s == 0 for s in ring.states)              # slot released


def test_cascade_has_no_kind_dispatch():
    src = inspect.getsource(cascade_mod)
    assert ".kind" not in src
    assert "elif" not in inspect.getsource(CascadeRunner)


def test_engine_first_token_matches_monolithic(key):
    """Engine path (plan vision staging + TABM bind + bucketed prefill)
    agrees with the monolithic forward at the first sampled position."""
    for arch in ("stablelm-1.6b", "llava-onevision-0.5b"):
        cfg, params, inputs = _setup(arch, key)
        mono, _ = lm_forward(params, cfg, inputs["tokens"],
                             vision_feats=inputs.get("vision_feats"))
        want = int(jnp.argmax(mono[0, -1]))
        eng = ServingEngine(cfg, params, n_slots=2, max_len=128)
        eng.submit(Request(rid=0,
                           tokens=np.asarray(inputs["tokens"][0]),
                           vision_feats=(np.asarray(inputs["vision_feats"])
                                         if cfg.vlm else None),
                           max_new_tokens=2))
        done = eng.run()
        assert done[0].out_tokens[0] == want, arch


def test_engine_tabm_full_stall_drain(key):
    """FULL -> stall -> drain through the engine: more vlm requests than
    ring slots; the producer stalls on the full ring (stats count it), no
    request ever bypasses the ring, and everything drains.  Runs the
    synchronous pipeline so the stall is observable after exactly one
    step; the async producer-thread variant is covered in
    tests/test_engine_async.py."""
    cfg, params, _ = _setup("llava-onevision-0.5b", key)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=128,
                        async_staging=False)
    # every request below is one full-res image -> one class ring of 2
    assert eng.tabm.ring_for_tokens(cfg.vision_tokens).n_slots == 2
    rng = np.random.default_rng(0)
    n_req = 5
    for i in range(n_req):
        eng.submit(Request(
            rid=i, tokens=np.arange(6) + 3, max_new_tokens=4,
            vision_feats=rng.standard_normal(
                (1, cfg.vision_tokens, cfg.vision_feat_dim)
            ).astype(np.float32) * 0.02))
    eng.step()
    # after one step: ring filled (2 commits) and the 3rd request stalled
    assert eng.tabm.stats["writes"] >= 2
    assert eng.tabm.stats["stalls"] >= 1
    done = eng.run()
    assert len(done) == n_req
    # zero-copy accounting: every request's embeds went through the ring
    assert eng.tabm.stats["writes"] == n_req
    assert eng.tabm.stats["reads"] == n_req
    assert all(s == 0 for s in eng.tabm.states)          # fully drained


def test_plan_port_validation(key):
    cfg, params, inputs = _setup("llava-onevision-0.5b", key)
    plan = compile_plan(decompose(cfg), params)
    assert [p.name for p in plan.input_ports] == ["vision_feats", "tokens"]
    with pytest.raises(PlanError):               # missing required port
        plan.run({"tokens": inputs["tokens"]})
    with pytest.raises(PlanError):               # int port fed floats
        plan.run({"tokens": inputs["tokens"].astype(jnp.float32),
                  "vision_feats": inputs["vision_feats"]})


def test_plan_one_brick_residency_trace(key):
    """one-brick residency: load/execute/release per brick, residency
    returns to zero, peak is max-not-sum (same contract the old cascade
    interpreter proved)."""
    cfg, params, inputs = _setup("stablelm-1.6b", key)
    plan = compile_plan(decompose(cfg), params, residency="one-brick")
    _, trace = plan.run(inputs, trace=PlanTrace())
    phases = [(e.brick, e.phase) for e in trace.events]
    for b in plan.graph.names():
        assert (b, "load") in phases and (b, "release") in phases
    assert trace.events[-1].resident_bytes == 0
    assert 0 < trace.peak_bytes < trace.sum_bytes
