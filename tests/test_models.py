"""Per-arch smoke tests (reduced configs) + decode-path consistency.

Smoke: one train step + prefill + decode per assigned arch, asserting
output shapes and finiteness (the brief's required reduced-config tests).

Consistency: prefill+decode must reproduce the teacher-forced forward's
next-token logits for every cache family (KV, SSM state, hybrid, linear).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cell_applicable, get_config, list_archs
from repro.launch.steps import (build_serve_step, build_train_step,
                                init_params)
from repro.models import encdec as ED
from repro.models import model as M
from repro.training.optimizer import OptConfig, init_opt

ARCHS = list_archs()


def _batch(cfg, B=2, S=64):
    batch = {}
    if cfg.encdec:
        batch["src_embeds"] = jnp.full((B, 32, cfg.d_model), 0.01)
        batch["tgt_tokens"] = (jnp.arange(B * S).reshape(B, S) % 60 + 3
                               ).astype(jnp.int32)
    else:
        batch["tokens"] = (jnp.arange(B * S).reshape(B, S) % 60 + 3
                           ).astype(jnp.int32)
        if cfg.vlm:
            batch["vision_feats"] = jnp.full(
                (B, cfg.vision_tokens, cfg.vision_feat_dim), 0.01)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(key, arch):
    """One forward/train step on CPU: shapes + no NaNs (assignment rule)."""
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    opt_cfg = OptConfig(lr=1e-3)
    opt = init_opt(params, opt_cfg)
    step = jax.jit(build_train_step(cfg, opt_cfg))
    params2, opt2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params2), jax.tree.leaves(params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_prefill_decode(key, arch):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    B, S, max_len = 2, 16, 32
    if cfg.encdec:
        logits, cache = ED.encdec_prefill(
            params, cfg, jnp.full((B, 8, cfg.d_model), 0.01),
            jnp.ones((B, S), jnp.int32), max_len)
    else:
        logits, cache = M.lm_prefill(
            params, cfg, jnp.ones((B, S), jnp.int32), max_len,
            vision_feats=(jnp.full((B, cfg.vision_tokens,
                                    cfg.vision_feat_dim), 0.01)
                          if cfg.vlm else None))
    assert logits.shape == (B, cfg.padded_vocab)
    serve = jax.jit(build_serve_step(cfg))
    for _ in range(3):
        logits, cache = serve(params, jnp.ones((B, 1), jnp.int32), cache)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mamba2-1.3b",
                                  "jamba-1.5-large-398b",
                                  "deepseek-moe-16b", "qwen2-vl-7b"])
def test_decode_matches_teacher_forcing(key, arch):
    """Greedy decode logits == forward logits on the same prefix, for every
    cache family (attention KV, SSD state, hybrid interleave, MoE)."""
    cfg = get_config(arch).reduced()
    # MoE routing under capacity pressure differs between a (B,S) forward
    # and a (B,1) decode; widen capacity so routing is identical.
    if cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(key, cfg)
    B, S, extra = 1, 24, 4
    tokens = (jnp.arange(B * (S + extra)).reshape(B, -1) % 50 + 3
              ).astype(jnp.int32)
    vision = (jnp.full((B, cfg.vision_tokens, cfg.vision_feat_dim), 0.01)
              if cfg.vlm else None)
    full_logits, _ = M.lm_forward(params, cfg, tokens, vision_feats=vision)

    _, cache = M.lm_prefill(params, cfg, tokens[:, :S], S + extra + 1,
                            vision_feats=vision)
    for t in range(S, S + extra):
        logits, cache = M.lm_decode_step(params, cfg, tokens[:, t:t + 1],
                                         cache)
        ref = full_logits[:, t]
        got = logits
        top_ref = int(jnp.argmax(ref[0, :cfg.vocab_size]))
        top_got = int(jnp.argmax(got[0, :cfg.vocab_size]))
        assert top_got == top_ref, (arch, t)
        np.testing.assert_allclose(
            np.asarray(got[0, :cfg.vocab_size], np.float32),
            np.asarray(ref[0, :cfg.vocab_size], np.float32),
            rtol=0.1, atol=0.35)


def test_linear_attention_variant_decodes(key):
    """The paper's attn_impl="linear" drop-in works end to end."""
    import dataclasses
    cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                              attn_impl="linear", subquadratic=True)
    params = init_params(key, cfg)
    tokens = (jnp.arange(40)[None] % 50 + 3).astype(jnp.int32)
    full_logits, _ = M.lm_forward(params, cfg, tokens)
    _, cache = M.lm_prefill(params, cfg, tokens[:, :32], 40)
    logits, cache = M.lm_decode_step(params, cfg, tokens[:, 32:33], cache)
    assert int(jnp.argmax(logits[0, :cfg.vocab_size])) == \
        int(jnp.argmax(full_logits[0, 32, :cfg.vocab_size]))


def test_cell_applicability_rules():
    """long_500k runs only for sub-quadratic archs (assignment rule)."""
    ok, _ = cell_applicable(get_config("mamba2-1.3b"), SHAPES["long_500k"])
    assert ok
    ok, _ = cell_applicable(get_config("jamba-1.5-large-398b"),
                            SHAPES["long_500k"])
    assert ok
    for arch in ("deepseek-67b", "qwen2-vl-7b", "seamless-m4t-large-v2"):
        ok, why = cell_applicable(get_config(arch), SHAPES["long_500k"])
        assert not ok and "attention" in why


def test_configs_match_assignment():
    """Exact published numbers from the assignment table."""
    c = get_config("deepseek-67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    c = get_config("dbrx-132b")
    assert c.moe.n_experts == 16 and c.moe.top_k == 4 and c.d_ff == 10752
    c = get_config("deepseek-moe-16b")
    assert c.moe.n_experts == 64 and c.moe.top_k == 6 and c.moe.n_shared == 2
    c = get_config("mamba2-1.3b")
    assert c.ssm.d_state == 128 and c.d_ff == 0 and c.n_layers == 48
    c = get_config("jamba-1.5-large-398b")
    assert c.hybrid_group == 8 and c.moe.top_k == 2 and c.vocab_size == 65536
    c = get_config("seamless-m4t-large-v2")
    assert c.encdec and c.vocab_size == 256206
    c = get_config("qwen2-vl-7b")
    assert c.rope == "mrope" and c.vocab_size == 152064
