"""HLO cost model: dot flops, while trip-count multiplication, collective
accounting — validated on freshly compiled modules with known answers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_cost
from repro.analysis.energy import (EDGE_NPU, TPU_V5E, hours_on_battery,
                                   step_energy, step_time, watts)
from repro.analysis.roofline import CollectiveStats, Roofline


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_dot_flops():
    M, K, N = 64, 128, 32
    x = jnp.ones((M, K), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    rep = hlo_cost.analyze(c.as_text(), 1)
    assert rep.flops == pytest.approx(2 * M * K * N, rel=0.01)


def test_scan_multiplies_flops_by_trip_count():
    M = 32
    x = jnp.ones((M, M), jnp.float32)
    w = jnp.ones((8, M, M), jnp.float32)

    def fn(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    c = _compile(fn, x, w)
    rep = hlo_cost.analyze(c.as_text(), 1)
    assert rep.flops == pytest.approx(8 * 2 * M ** 3, rel=0.05)


def test_nested_scan_trip_counts():
    M = 16
    x = jnp.ones((M, M), jnp.float32)

    def fn(x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ ci), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    c = _compile(fn, x)
    rep = hlo_cost.analyze(c.as_text(), 1)
    assert rep.flops == pytest.approx(15 * 2 * M ** 3, rel=0.05)


def test_traffic_counts_dus_at_update_size():
    """Scanned accumulator: traffic ~ slice-sized writes, not full-buffer."""
    big = jnp.zeros((64, 1024), jnp.float32)
    rows = jnp.ones((64, 8), jnp.float32)

    def fn(big, rows):
        def body(acc, i):
            return jax.lax.dynamic_update_slice(
                acc, rows, (0, i * 8)), None
        out, _ = jax.lax.scan(body, big, jnp.arange(64))
        return out

    c = _compile(fn, big, rows)
    rep = hlo_cost.analyze(c.as_text(), 1)
    full_buffer_total = 64 * big.size * 4
    assert rep.traffic_bytes < 0.5 * full_buffer_total


def test_parse_collective_shapes():
    hlo = '''
HloModule m
ENTRY %main (p: f32[256,64]) -> f32[256,64] {
  %p = f32[256,64]{1,0} parameter(0)
  %ar = f32[256,64]{1,0} all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %ag = f32[256,64]{1,0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
}
'''
    rep = hlo_cost.analyze(hlo, 256)
    nbytes = 256 * 64 * 4
    assert rep.coll_raw["all-reduce"] == nbytes
    assert rep.coll_transfer["all-reduce"] == pytest.approx(
        2 * nbytes * 15 / 16)
    assert rep.coll_transfer["all-gather"] == pytest.approx(nbytes * 3 / 4)


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        arch="x", shape="y", mesh="16x16", n_devices=256,
        flops_per_device=197e12 * 0.010,          # 10ms compute
        bytes_per_device=819e9 * 0.002,           # 2ms memory
        collective=CollectiveStats(transfer_bytes={"all-reduce": int(50e9
                                                                     * 0.02)}),
        model_flops=197e12 * 256 * 0.008,
        n_params=1, n_params_active=1)
    assert r.t_compute == pytest.approx(0.010)
    assert r.t_memory == pytest.approx(0.002)
    assert r.t_collective == pytest.approx(0.020)
    assert r.bottleneck == "collective"
    assert r.roofline_fraction == pytest.approx(0.008 / 0.020)
    assert r.useful_flops_ratio == pytest.approx(0.8)


def test_energy_model_sanity():
    t = step_time(TPU_V5E, flops=197e12, hbm_bytes=0)
    assert t == pytest.approx(1.0)
    e = step_energy(TPU_V5E, 197e12, 819e9, 0, wall_s=1.0)
    w = e / 1.0
    assert 100 < w < 400                      # chip-class power envelope
    assert hours_on_battery(0.375) == pytest.approx(19.7, rel=0.02)
    # the paper's 20.8h claim at 0.375W needs its quoted 2000mAh pack:
    assert hours_on_battery(0.375, battery_mah=2000, volts=3.9) > 20


def test_edge_profiles_order():
    """NPU most efficient per flop; CPU least (paper's premise)."""
    f = 1e9
    e_npu = step_energy(EDGE_NPU, f, 0, 0)
    from repro.analysis.energy import EDGE_CPU, EDGE_GPU
    e_gpu = step_energy(EDGE_GPU, f, 0, 0)
    e_cpu = step_energy(EDGE_CPU, f, 0, 0)
    assert e_npu < e_gpu < e_cpu
