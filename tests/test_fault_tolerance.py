"""Fault tolerance: heartbeats, re-mesh planning, stragglers, and the
end-to-end kill/restore/continue path."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as hst

from repro.configs import get_config
from repro.data import multimodal_batch_iter
from repro.distributed import checkpoint as ck
from repro.distributed.fault_tolerance import (HeartbeatMonitor, RemeshPlan,
                                               StragglerMitigator,
                                               plan_remesh)
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, fit


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_dead_worker():
    clock = FakeClock()
    mon = HeartbeatMonitor(n_workers=4, timeout_s=10, clock=clock)
    clock.t = 5.0
    for w in (0, 1, 3):
        mon.beat(w)
    clock.t = 12.0
    assert mon.dead_workers() == [2]
    mon.evict(2)
    assert mon.alive() == [0, 1, 3]
    assert mon.dead_workers() == []


@given(n_fail=hst.integers(0, 20))
def test_remesh_preserves_model_axis(n_fail):
    alive = list(range(32 - n_fail))           # 32 workers x 16 devices
    if len(alive) * 16 < 16:
        return
    plan = plan_remesh(alive, devices_per_worker=16, model_axis=16)
    assert plan.shape[-1] == 16                # TP degree preserved
    assert plan.n_devices <= len(alive) * 16
    assert plan.n_devices % 16 == 0
    assert set(plan.dropped).isdisjoint(plan.workers)


def test_remesh_multipod_when_divisible():
    plan = plan_remesh(list(range(32)), 16, model_axis=16, pod_axis=2)
    assert plan.axes == ("pod", "data", "model")
    assert plan.shape == (2, 16, 16)


def test_straggler_detection():
    sm = StragglerMitigator(n_workers=4, min_samples=4, multiplier=2.0)
    for _ in range(8):
        for w in range(3):
            sm.record(w, 1.0)
        sm.record(3, 5.0)                      # persistent straggler
    assert sm.stragglers() == [3]
    assert sm.step_deadline() == pytest.approx(2.0, rel=0.5)


def test_kill_restore_continue_elastic():
    """Train, 'lose' the job, restore onto a different (null) topology via
    the topology-free checkpoint + deterministic data seek."""
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    with tempfile.TemporaryDirectory() as d:
        it = multimodal_batch_iter(cfg, global_batch=4, seq_len=64)
        fit(cfg, OptConfig(lr=1e-3),
            TrainConfig(steps=6, ckpt_dir=d, ckpt_every=3, log_every=100),
            it)
        assert ck.latest_step(d) == 6
        # "failure": fresh process state; re-mesh = (new) data iter + restore
        it2 = multimodal_batch_iter(cfg, global_batch=4, seq_len=64)
        res = fit(cfg, OptConfig(lr=1e-3),
                  TrainConfig(steps=9, ckpt_dir=d, ckpt_every=3,
                              log_every=100), it2)
        steps = [m["step"] for m in res.metrics_history]
        assert steps == [7, 8, 9]
        assert all(np.isfinite(m["loss"]) for m in res.metrics_history)


def test_restore_with_resharding(key):
    """restore() binds new shardings — the reshard-on-load contract."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, tree)
        shard = {"w": NamedSharding(mesh, P("data"))}
        got, step, _ = ck.restore(d, tree, shardings=shard)
        assert got["w"].sharding == shard["w"]
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
