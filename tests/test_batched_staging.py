"""Batched staging & prefill pipeline (PR 5).

Covers the issue's acceptance criteria:
* **batched == sequential** — greedy tokens from the microbatching
  pipeline (``produce_many`` strided slab commits + grouped batch-B
  prefill + ``KVCache.insert_many``) are identical to one-by-one staging
  and batch-1 prefill, across ≥2 slot classes;
* **acceptance trace** — with 8 queued same-class requests the engine
  trace shows ≥1 multi-request slab commit and ≥1 batch>1 prefill call;
* **error isolation** — one bad request in a staging microbatch fails
  only its owner (slab abort-all, then one-by-one restage);
* **batch-aware scheduler** — ``brick_cost(batch=K)`` amortizes weight
  traffic; ``class_staging_budgets(stage_batch=...)`` charges one
  microbatch per round; ``Knobs.max_stage_batch`` shrinks under
  THROTTLED *before* depth sheds;
* **one substrate table** — the scheduler's bit-efficiency rows and the
  backend lowering selection read ``core/backends.SUBSTRATES``;
* **cross-class aging** — a request skipped long enough at admission
  reserves a KV slot against newer requests of other classes;
* **insert_many** — one strided KV scatter equals K slot-by-slot merges.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.backends import (BACKENDS, SUBSTRATES, bit_efficiency,
                                 substrate_backend)
from repro.core.power import PowerPolicy
from repro.core.scheduler import (brick_cost, class_staging_budgets,
                                  edge_accelerators, schedule)
from repro.core.tabm import EMPTY, SlotClassPool
from repro.launch.steps import init_params
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import SlotCache


@pytest.fixture(scope="module")
def vlm():
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _req(cfg, rid, n_tokens, n_images=1, n_new=4, seed=0, prompt_len=None):
    rng = np.random.default_rng(seed + rid)
    plen = prompt_len if prompt_len is not None else 6 + (rid % 3)
    return Request(
        rid=rid, tokens=(np.arange(plen) % 50 + 3).astype(np.int32),
        n_images=n_images, max_new_tokens=n_new,
        vision_feats=rng.standard_normal(
            (1, n_tokens, cfg.vision_feat_dim)).astype(np.float32) * 0.02)


# ---------------------------------------------------------------------------
# plan-level: produce_many == K sequential produce calls
# ---------------------------------------------------------------------------

def test_produce_many_embeds_match_sequential(vlm):
    """The strided slab carries exactly what K sequential produce calls
    would have committed — same per-slot views, same lengths, slab-padded
    tails zeroed."""
    from repro.core.bricks import decompose
    from repro.core.plan import compile_plan

    cfg, params = vlm
    pool_a = SlotClassPool.from_config(cfg, slots_per_class=4)
    pool_b = SlotClassPool.from_config(cfg, slots_per_class=4)
    plan_a = compile_plan(decompose(cfg), params, tabm=pool_a)
    plan_b = compile_plan(decompose(cfg), params, tabm=pool_b)
    rng = np.random.default_rng(7)
    feats = [rng.standard_normal((1, n, cfg.vision_feat_dim)
                                 ).astype(np.float32) * 0.02
             for n in (8, 5, 8)]               # mixed lengths, one class
    cls = pool_a.classify_total(8)

    slots = plan_a.produce_many(
        [{"vision_feats": jnp.asarray(f)} for f in feats], slot_class=cls)
    assert slots is not None and len(slots) == 3
    seq = [plan_b.produce({"vision_feats": jnp.asarray(f)}, slot_class=cls)
           for f in feats]
    for expect_n, f in zip((8, 5, 8), feats):
        got_a = plan_a.consume(slot_class=cls)
        got_b = plan_b.consume(slot_class=cls)
        assert got_a[2] == got_b[2] == expect_n
        np.testing.assert_array_equal(np.asarray(got_a[1], np.float32),
                                      np.asarray(got_b[1], np.float32))
        plan_a.release(got_a[0], slot_class=cls)
        plan_b.release(got_b[0], slot_class=cls)
    assert pool_a.ring(cls).stats["slab_commits"] == 1
    assert pool_b.ring(cls).stats["slab_commits"] == 0
    assert seq == slots


def test_produce_is_the_k1_case(vlm):
    """produce == produce_many of one request: same slot, same stats."""
    from repro.core.bricks import decompose
    from repro.core.plan import compile_plan
    from repro.core.tabm import RingBuffer

    cfg, params = vlm
    ring = RingBuffer(n_slots=2, max_tokens=cfg.vision_tokens,
                      dim=cfg.d_model)
    plan = compile_plan(decompose(cfg), params, tabm=ring)
    feats = jnp.ones((1, cfg.vision_tokens, cfg.vision_feat_dim),
                     jnp.float32)
    s1 = plan.produce({"vision_feats": feats})
    s2 = plan.produce_many([{"vision_feats": feats}])
    assert s1 == 0 and s2 == [1]
    assert ring.stats["writes"] == 2 and ring.stats["slab_commits"] == 0
    assert plan.tabm_capacity() == 2
    for _ in range(2):
        got = plan.consume()
        plan.release(got[0])


# ---------------------------------------------------------------------------
# engine-level: the acceptance criteria
# ---------------------------------------------------------------------------

def test_eight_same_class_requests_slab_commit_and_grouped_prefill(vlm):
    """The issue's acceptance trace: ≥1 multi-request slab commit and ≥1
    batch>1 prefill call with 8 queued same-class requests — and the ring
    ends clean."""
    cfg, params = vlm
    with ServingEngine(cfg, params, n_slots=4, max_len=128,
                       stage_batch=4) as eng:
        reqs = [_req(cfg, i, n_tokens=8, prompt_len=7) for i in range(8)]
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 8 and all(r.error is None for r in done)
        assert len({r.slot_class for r in reqs}) == 1
        events = [(e, k) for e, k, _ in eng.trace]
        slab_ks = [k for e, k in events if e == "slab_commit"]
        prefill_bs = [k for e, k in events if e == "prefill_batch"]
        assert slab_ks and max(slab_ks) > 1
        assert prefill_bs and max(prefill_bs) > 1
        ring = eng.tabm.ring(reqs[0].slot_class)
        assert ring.stats["slab_commits"] >= 1
        assert ring.stats["writes"] == ring.stats["reads"] == 8
        assert all(st == EMPTY for st in eng.tabm.states)


@pytest.mark.parametrize("oracle", ["sync_k1", "async_k1"])
def test_batched_tokens_identical_to_one_by_one(vlm, oracle):
    """Greedy tokens through strided slab staging + grouped prefill are
    identical to one-by-one staging and batch-1 prefill, with ≥2 slot
    classes in flight."""
    cfg, params = vlm
    specs = [(8, 1), (2, 1), (8, 1), (32, 4), (8, 1), (2, 1)]
    mk = lambda: [_req(cfg, i, n_tokens=t, n_images=n, n_new=5)
                  for i, (t, n) in enumerate(specs)]

    def run(async_staging, stage_batch, max_batch):
        eng = ServingEngine(cfg, params, n_slots=4, max_len=128,
                            async_staging=async_staging,
                            stage_batch=stage_batch)
        eng.executor.policy.full_batch = max_batch
        with eng:
            reqs = mk()
            for r in reqs:
                eng.submit(r)
            done = eng.run()
            assert len({r.slot_class for r in reqs}) >= 2
            return {r.rid: r.out_tokens for r in done}

    batched = run(True, 4, 128)
    one_by_one = run(oracle == "async_k1", 1, 1)
    assert batched == one_by_one
    assert all(batched[i] for i in range(len(specs)))


def test_staging_microbatch_error_isolated_to_owner(vlm):
    """A bad request inside a staging microbatch fails only its owner:
    the slab is aborted whole, then restaged one-by-one (batchmates
    commit, the bad input's error lands on the bad request)."""
    cfg, params = vlm
    with ServingEngine(cfg, params, n_slots=4, max_len=128,
                       stage_batch=4) as eng:
        good0 = _req(cfg, 0, n_tokens=8)
        bad = _req(cfg, 1, n_tokens=8)
        # wrong feature dim: stacking/projector cannot contract
        bad.vision_feats = np.ones(
            (1, 8, cfg.vision_feat_dim + 3), np.float32)
        bad.slot_class = good0.slot_class = None
        good1 = _req(cfg, 2, n_tokens=8)
        for r in (good0, bad, good1):
            eng.submit(r)
        done = eng.run()
        by_rid = {r.rid: r for r in done}
        assert by_rid[1].error is not None and not by_rid[1].out_tokens
        for rid in (0, 2):
            assert by_rid[rid].error is None
            assert len(by_rid[rid].out_tokens) >= 4
        assert all(st == EMPTY for st in eng.tabm.states)


def test_group_bind_failure_releases_unconsumed_ready_slots(vlm):
    """If a bind fails partway through a prefill group, the batchmates'
    staged-but-unconsumed READY slots must be pulled out of the ring too
    — an ownerless READY slot would break every later same-class consume
    (per-class FIFO) and eventually wedge the producer."""
    from repro.core.tabm import TABMError

    cfg, params = vlm
    with ServingEngine(cfg, params, n_slots=4, max_len=128,
                       stage_batch=4) as eng:
        reqs = [_req(cfg, i, n_tokens=8, prompt_len=7) for i in range(2)]
        real_wait = eng.plan.wait_ready
        eng.plan.wait_ready = lambda *a, **k: False    # every bind fails
        for r in reqs:
            eng.submit(r)
        deadline = time.monotonic() + 60
        while not all(r.error is not None for r in reqs):
            assert time.monotonic() < deadline
            eng.step()
        assert all(isinstance(r.error, TABMError) for r in reqs)
        assert all(st == EMPTY for st in eng.tabm.states)   # no orphans
        eng.plan.wait_ready = real_wait
        ok = _req(cfg, 9, n_tokens=8, prompt_len=7)
        eng.submit(ok)                         # the class keeps serving
        done = eng.run()
        assert ok in done and ok.error is None
        assert len(ok.out_tokens) >= 4


def test_cross_class_aging_reserves_kv_slot(vlm):
    """A hi-res head skipped (class ring jammed) for aging_steps rounds
    reserves the KV slot: a newer thumbnail may not take it; once the
    class unjams, the aged request admits first."""
    cfg, params = vlm
    eng = ServingEngine(cfg, params, n_slots=1, max_len=128,
                        async_staging=False, aging_steps=2)
    with eng:
        hi_cls = eng.tabm.classify(8, 1)
        ring = eng.tabm.ring(hi_cls)
        jam = []                               # occupy the hi-res ring
        for _ in range(ring.n_slots):
            s = ring.acquire_write()
            ring.commit_write(s, jnp.zeros((8, cfg.d_model)))
            jam.append(s)
        hi = _req(cfg, 0, n_tokens=8, n_new=2)
        th1 = _req(cfg, 1, n_tokens=2, n_new=2)
        th2 = _req(cfg, 2, n_tokens=2, n_new=2)
        for r in (hi, th1, th2):
            eng.submit(r)
        # the thumbnail flood cycles through the only KV slot while hi's
        # class is jammed — the starvation the reservation exists to stop
        for _ in range(60):
            eng.step()
            if th2.finish_t is not None:
                break
        assert th1.error is None and th2.error is None
        assert hi.slot is None
        for _ in range(4):                     # hi is skipped every round a
            eng.step()                         # slot is free: it ages
        assert hi.aging >= eng.aging_steps     # aged on real skips
        th3 = _req(cfg, 3, n_tokens=2, n_new=2)
        eng.submit(th3)
        # the freed slot is now reserved for aged hi: th3 must NOT take it
        for _ in range(4):
            eng.step()
        assert th3.slot is None and th3 in eng.queue
        assert len(eng.slots.free) == 1        # held free by the reservation
        for s in jam:                          # unjam hi's class ring
            got = ring.acquire_read()
            ring.release(got[0])
        done = eng.run()
        assert {r.rid for r in done} == {0, 1, 2, 3}
        assert all(r.error is None for r in done)
        order = [r for e, r, _ in eng.trace if e == "prefill"]
        assert order.index(0) < order.index(3)  # aged hi beat newer thumb


# ---------------------------------------------------------------------------
# insert_many == sequential insert
# ---------------------------------------------------------------------------

def test_kv_insert_many_matches_sequential_insert(vlm):
    cfg, params = vlm
    from repro.models import model as M

    many = SlotCache(cfg, n_slots=4, max_len=32)
    seq = SlotCache(cfg, n_slots=4, max_len=32)
    batch = M.init_decode_state(cfg, 3, 32, start_index=0)
    key = jax.random.PRNGKey(3)
    batch["layers"] = jax.tree.map(
        lambda l: jax.random.normal(key, l.shape, jnp.float32
                                    ).astype(l.dtype), batch["layers"])
    slots, lens = [2, 0, 3], [5, 7, 3]
    many.insert_many(slots, batch, lens)
    for b, (slot, n) in enumerate(zip(slots, lens)):
        one = {"layers": jax.tree.map(lambda l: l[:, b:b + 1],
                                      batch["layers"])}
        seq.insert(slot, one, n)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        many.cache["layers"], seq.cache["layers"])
    np.testing.assert_array_equal(np.asarray(many.lengths),
                                  np.asarray(seq.lengths))


# ---------------------------------------------------------------------------
# batch-aware scheduler + battery knob
# ---------------------------------------------------------------------------

def test_brick_cost_amortizes_weight_traffic_over_microbatch():
    cfg = get_config("llava-onevision-0.5b")
    from repro.core.bricks import decompose
    import dataclasses
    proj = dataclasses.replace(decompose(cfg).brick("projector"),
                               param_bytes=10**9)   # memory-bound
    gpu = edge_accelerators()[1]
    one = brick_cost(proj, gpu, n_tokens=729)
    four = brick_cost(proj, gpu, n_tokens=729, batch=4)
    # weight traffic is charged once per call: a memory-bound microbatch
    # rides the same weight stream (latency flat), while 4 independent
    # calls would pay it 4 times
    assert one.latency_s <= four.latency_s < 4 * one.latency_s
    assert one.energy_j < four.energy_j < 4 * one.energy_j  # flops do scale
    assert brick_cost(proj, gpu, 729, batch=1) == one
    # and the placement DP takes the same knob end to end: a batch-4
    # microbatch placement costs at most 4 sequential batch-1 ones
    g = decompose(cfg)
    g.bricks = [dataclasses.replace(
        b, param_bytes=max(1, int(b.flops_per_token))) for b in g.bricks]
    accels = edge_accelerators()
    p1 = schedule(g, accels, 256)
    p4 = schedule(g, accels, 256, batch=4)
    assert p1.latency_s <= p4.latency_s <= 4 * p1.latency_s


def test_class_staging_budgets_charge_one_microbatch_per_round(vlm):
    cfg, _ = vlm
    pool = SlotClassPool.from_config(cfg, slots_per_class=4)
    free = class_staging_budgets(pool, in_flight={})
    assert all(b == 4 for b in free.values())        # depth-capped only
    capped = class_staging_budgets(pool, in_flight={}, stage_batch=2)
    assert all(b == 2 for b in capped.values())      # one microbatch/round
    # in-flight still charges against depth before the microbatch cap
    some = class_staging_budgets(pool, in_flight={"1img-2tok": 3},
                                 stage_batch=2)
    assert some["1img-2tok"] == 1


def test_knobs_shrink_stage_batch_before_shedding_depth():
    pol = PowerPolicy(full_stage_batch=4)
    assert pol.knobs(0.9).max_stage_batch == 4       # UNCONSTRAINED
    high = pol.knobs(0.55)                           # alpha 0.875
    assert 1 <= high.max_stage_batch < 4             # batch shrinks already
    assert high.class_depth_scale > 0.8              # depth barely touched
    mid = pol.knobs(0.40)                            # alpha 0.5
    assert mid.max_stage_batch == 1                  # batch floored first...
    assert mid.class_depth_scale == pytest.approx(0.5)   # ...depth still up
    assert pol.knobs(0.05).max_stage_batch == 1      # CRITICAL: strictly K=1


# ---------------------------------------------------------------------------
# one substrate table (scheduler cost model == backend lowering)
# ---------------------------------------------------------------------------

def test_substrate_table_is_the_single_source_of_truth():
    # the scheduler's throughput scale reads the shared table
    for acc in edge_accelerators():
        row = SUBSTRATES[acc.profile.name]
        for label, eff in row.bit_efficiency:
            assert acc.throughput_scale(label) == pytest.approx(
                eff * acc.width)
            assert bit_efficiency(acc.profile.name, label) == eff
        # backend selection reads the same row
        assert acc.backend_name() == row.backend
        assert substrate_backend(acc.profile.name) == row.backend
    # kernel-mode coherence: units priced with an fp penalty are exactly
    # the ones lowering through reference-kernel backends
    for name, row in SUBSTRATES.items():
        fp = row.efficiency("bf16")
        assert (row.kernel_mode == "ref") == (fp < 1.0), (
            f"{name}: fp efficiency {fp} disagrees with kernel mode "
            f"{row.kernel_mode}")
    assert bit_efficiency("unknown-unit", "bf16") == 1.0
    assert BACKENDS[SUBSTRATES["rk-npu"].backend].kernel_mode == "ref"
