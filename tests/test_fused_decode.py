"""Fused low-bit Pallas cohort-decode kernels (PR 10).

The contract battery for ``kernels/fused_decode``:

* **kernel == oracle, per kernel** — fused QKV / fused MLP match the
  composed dequantize->einsum chains bit for bit across dense/q4/q8
  weights; the KV row scatter matches the engine's
  ``.at[...].set(mode="drop")`` pass, and sentinel rows write NOTHING
  (the aliased pool block keeps its prior bits);
* **fused cohort step == composed oracle, bit-identical** — the tentpole
  acceptance bar: ``cohort_step(use_fused=True)`` equals
  ``ref_cohort_step`` (today's three engine dispatches: gather ->
  ``lm_decode_step`` -> scatter) on logits AND pools, across cohort
  buckets x bit-widths, eager and under ``jax.jit`` (the engine always
  jits), plus a property sweep over random lengths / block tables /
  sentinel rows;
* **engine wiring** — ``ServingEngine(use_fused=True)`` emits greedy
  tokens identical to the composed engine; unsupported archs (hybrid
  SSM) refuse the fused path;
* **activation-aware sparsity** — ``prune_weights`` drops exactly the
  lowest |W|*act rows-quantile scores, the ``-spNN`` composite labels
  parse and price per substrate (EdgeMM-style sparse MACs), and the
  pruned-q4 decode path stays self-consistent with calibrated drift
  bounds vs fp.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.configs import get_config
from repro.core.backends import bit_efficiency
from repro.core.quantize import (PROFILES, QuantSpec, parse_label,
                                 prune_weights, quantize, quantize_tree)
from repro.kernels.fused_decode import (cohort_step, fused_mlp, fused_qkv,
                                        fused_supported, kv_scatter,
                                        ref_cohort_step, ref_fused_mlp,
                                        ref_fused_qkv, ref_kv_scatter)
from repro.launch.steps import init_params
from repro.serving.kv_cache import paged_positions


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def lm_q4(lm):
    cfg, params = lm
    return cfg, quantize_tree(params, PROFILES["nanomind-serve"])


@pytest.fixture(scope="module")
def lm_q8(lm):
    cfg, params = lm
    return cfg, quantize_tree(params, PROFILES["dec-q8"])


def _maybe_q(w, label):
    return w if label == "dense" else quantize(
        w, parse_label(label)[0])


# ---------------------------------------------------------------------------
# per-kernel oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label", ["dense", "q4f16-g32", "q8f16"])
@pytest.mark.parametrize("bias", [False, True])
def test_fused_qkv_matches_composed(key, label, bias):
    D, H, KV, hd, bc = 64, 4, 2, 16, 3
    ks = jax.random.split(key, 7)
    h = jax.random.normal(ks[0], (bc, 1, D), jnp.bfloat16)
    wq = _maybe_q(jax.random.normal(ks[1], (D, H, hd), jnp.bfloat16), label)
    wk = _maybe_q(jax.random.normal(ks[2], (D, KV, hd), jnp.bfloat16), label)
    wv = _maybe_q(jax.random.normal(ks[3], (D, KV, hd), jnp.bfloat16), label)
    bq = bk = bv = None
    if bias:
        bq = jax.random.normal(ks[4], (H, hd), jnp.bfloat16)
        bk = jax.random.normal(ks[5], (KV, hd), jnp.bfloat16)
        bv = jax.random.normal(ks[6], (KV, hd), jnp.bfloat16)
    got = fused_qkv(h, wq, wk, wv, bq, bk, bv, interpret=True)
    want = ref_fused_qkv(h, wq, wk, wv, bq, bk, bv)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype and bool(jnp.array_equal(g, w))


@pytest.mark.parametrize("label", ["dense", "q4f16-g32"])
@pytest.mark.parametrize("act", ["swiglu", "gelu"])
def test_fused_mlp_matches_composed(key, label, act):
    D, F, bc = 64, 128, 3
    ks = jax.random.split(key, 4)
    h = jax.random.normal(ks[0], (bc, 1, D), jnp.bfloat16)
    w_up = _maybe_q(jax.random.normal(ks[1], (D, F), jnp.bfloat16), label)
    w_down = _maybe_q(jax.random.normal(ks[2], (F, D), jnp.bfloat16), label)
    w_gate = None
    if act == "swiglu":
        w_gate = _maybe_q(jax.random.normal(ks[3], (D, F), jnp.bfloat16),
                          label)
    got = fused_mlp(h, w_up, w_down, w_gate, act=act, interpret=True)
    want = ref_fused_mlp(h, w_up, w_down, w_gate, act=act)
    assert got.dtype == want.dtype and bool(jnp.array_equal(got, want))


def test_kv_scatter_matches_and_sentinel_writes_nothing(key):
    L, nb, bs, KV, hd, bc = 2, 8, 4, 2, 16, 3
    ks = jax.random.split(key, 3)
    k_pool = jax.random.normal(ks[0], (L, nb, bs, KV, hd), jnp.bfloat16)
    v_pool = k_pool * 0.5
    k_rows = jax.random.normal(ks[1], (L, bc, KV, hd), jnp.bfloat16)
    v_rows = jax.random.normal(ks[2], (L, bc, KV, hd), jnp.bfloat16)
    blk = jnp.asarray([1, nb, 5], jnp.int32)       # row 1 is a sentinel
    off = jnp.asarray([2, 0, 3], jnp.int32)
    want = ref_kv_scatter(blk, off, k_rows, v_rows, k_pool, v_pool)
    got = kv_scatter(blk, off, k_rows, v_rows, k_pool, v_pool,
                     interpret=True)
    for g, w in zip(got, want):
        assert bool(jnp.array_equal(g, w))
    # sentinel semantics explicitly: every pool bit outside the two
    # written cells survives, including everything the sentinel row
    # would have addressed
    gk = got[0]
    mask = jnp.ones((L, nb, bs), bool).at[:, blk[0], off[0]].set(
        False).at[:, blk[2], off[2]].set(False)
    assert bool(jnp.array_equal(gk[mask], k_pool[mask]))


# ---------------------------------------------------------------------------
# the tentpole bar: fused cohort step == composed oracle, bit for bit
# ---------------------------------------------------------------------------

def _cohort_state(cfg, bc, *, nb=16, bs=4, W=6, seed=7, sentinel=True,
                  lengths=None, tables=None):
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    kp = jax.random.normal(jax.random.PRNGKey(seed), (L, nb, bs, KV, hd),
                           cfg.compute_dtype)
    pool = ((kp, kp * 0.5),)
    tokens = (jnp.arange(bc)[:, None] % 50 + 3).astype(jnp.int32)
    if lengths is None:
        lengths = jnp.asarray([(5 + 7 * i) % (W * bs) for i in range(bc)],
                              jnp.int32)
    if tables is None:
        tables = jnp.arange(bc * W, dtype=jnp.int32).reshape(bc, W) % nb
    if sentinel and bc >= 2:
        tables = tables.at[bc - 1].set(nb)
        lengths = lengths.at[bc - 1].set(0)
    slot_ids = jnp.arange(bc, dtype=jnp.int32)
    return tokens, lengths, slot_ids, tables, pool, bs


def _assert_bit_identical(cfg, params, bc, *, jit=False, **state_kw):
    tokens, lengths, slot_ids, tables, pool, bs = _cohort_state(
        cfg, bc, **state_kw)
    paged = paged_positions(cfg)
    kw = dict(block_size=bs, paged=paged)
    ref_fn = lambda *a: ref_cohort_step(params, cfg, *a, **kw)
    fused_fn = lambda *a: cohort_step(params, cfg, *a, use_fused=True,
                                      interpret=True, **kw)
    if jit:
        ref_fn, fused_fn = jax.jit(ref_fn), jax.jit(fused_fn)
    args = (tokens, lengths, slot_ids, tables, pool)
    lr, pr = ref_fn(*args)
    lf, pf = fused_fn(*args)
    assert bool(jnp.array_equal(lr, lf)), (
        f"bc={bc}: fused logits diverged, maxdiff "
        f"{float(jnp.max(jnp.abs(lr.astype(jnp.float32) - lf.astype(jnp.float32)))):.3e}")
    for a, b in zip(jax.tree.leaves(pr), jax.tree.leaves(pf)):
        assert bool(jnp.array_equal(a, b)), f"bc={bc}: pools diverged"


@pytest.mark.parametrize("bc", [1, 2, 4])
def test_cohort_step_bit_identical_dense(lm, bc):
    cfg, params = lm
    _assert_bit_identical(cfg, params, bc)


@pytest.mark.parametrize("bc", [1, 2, 4])
def test_cohort_step_bit_identical_q4(lm_q4, bc):
    cfg, params = lm_q4
    _assert_bit_identical(cfg, params, bc)


def test_cohort_step_bit_identical_q8(lm_q8):
    cfg, params = lm_q8
    _assert_bit_identical(cfg, params, 2)


def test_cohort_step_bit_identical_under_jit(lm_q4):
    """The engine always jits its cohort fn — equality must survive
    compilation, not just eager interpret mode."""
    cfg, params = lm_q4
    _assert_bit_identical(cfg, params, 2, jit=True)


@settings(max_examples=6, deadline=None)
@given(data=hst.lists(hst.tuples(hst.integers(0, 23), hst.integers(0, 97)),
                      min_size=2, max_size=2),
       sentinel=hst.integers(0, 2))
def test_cohort_step_property_lengths_and_tables(lm_q4, data, sentinel):
    """Random per-row lengths (any block offset, including block
    boundaries) and shuffled disjoint block tables, with 0-2 rows
    replaced by sentinels: fused stays bit-identical to composed."""
    cfg, params = lm_q4
    bc, W, nb, bs = 2, 6, 16, 4
    lengths = jnp.asarray([d[0] for d in data], jnp.int32)
    perm = np.random.RandomState(data[0][1]).permutation(nb)
    tables = jnp.asarray(perm[:bc * W].reshape(bc, W), jnp.int32)
    for i in range(min(sentinel, bc)):
        tables = tables.at[i].set(nb)
        lengths = lengths.at[i].set(0)
    _assert_bit_identical(cfg, params, bc, nb=nb, bs=bs, W=W,
                          sentinel=False, lengths=lengths, tables=tables)


def test_unsupported_arch_refuses_fused(lm):
    """Hybrid SSM groups keep the composed path: ``use_fused=None``
    resolves to composed, ``use_fused=True`` is an error."""
    cfg_h = get_config("jamba-1.5-large-398b").reduced()
    assert not fused_supported(cfg_h)
    cfg, params = lm
    assert fused_supported(cfg)
    with pytest.raises(AssertionError, match="dense-attention"):
        cohort_step(params, cfg_h, None, None, None, None, None,
                    block_size=4, paged=paged_positions(cfg_h),
                    use_fused=True)


def test_engine_fused_matches_composed_tokens(lm):
    """End to end through ServingEngine: identical greedy tokens."""
    from repro.serving.engine import Request, ServingEngine
    cfg = get_config("stablelm-1.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def reqs():
        return [Request(rid=i,
                        tokens=(np.arange(6 + i % 3) % 50 + 3).astype(
                            np.int32),
                        n_images=0, max_new_tokens=4, vision_feats=None)
                for i in range(3)]

    outs = {}
    for uf in (False, True):
        batch = reqs()
        with ServingEngine(cfg, params, n_slots=2, max_len=128,
                           block_size=32, use_fused=uf) as eng:
            for r in batch:
                eng.submit(r)
            done = eng.run()
            assert all(r.error is None for r in done)
            outs[uf] = {r.rid: r.out_tokens for r in done}
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# activation-aware sparsity (EdgeMM-style)
# ---------------------------------------------------------------------------

def test_prune_weights_sparsity_and_act_selection(key):
    w = jax.random.normal(key, (8, 64), jnp.bfloat16)
    p = prune_weights(w, 0.5)
    zeros = float(jnp.mean(p == 0))
    assert 0.45 <= zeros <= 0.56, zeros          # per-row quantile
    # survivors are the original weights, untouched
    kept = p != 0
    assert bool(jnp.array_equal(p[kept], w[kept]))
    # activation awareness: a huge per-column act scale rescues small
    # weights in that column from the magnitude cut
    act = jnp.ones((64,)).at[3].set(1e4)
    p_act = prune_weights(w, 0.5, act_scale=act)
    assert bool(jnp.all(p_act[:, 3] == w[:, 3]))


def test_sparse_labels_parse_and_price():
    spec, sparsity = parse_label("q4f16-g32-sp50")
    assert isinstance(spec, QuantSpec) and spec.bits == 4
    assert spec.group_size == 32 and sparsity == 0.5
    assert parse_label("q4f16")[1] == 0.0
    # the substrate rows: sparse MACs speed up units that skip them
    # (NPU > GPU) and buy nothing on the reference host path
    base = bit_efficiency("rk-npu", "q4f16-g32")
    assert bit_efficiency("rk-npu", "q4f16-g32-sp50") > base * 1.5
    assert bit_efficiency("rk-gpu", "q4f16-sp50") > \
        bit_efficiency("rk-gpu", "q4f16")
    assert bit_efficiency("rk-cpu", "q4f16-sp50") == \
        bit_efficiency("rk-cpu", "q4f16")


def test_pruned_q4_decode_self_consistent_and_bounded(lm):
    """The ``nanomind-sparse`` profile (50% activation-aware pruning
    under q4g32) through prefill + decode: the pruned model's
    free-running decode must replay its own full-forward argmax EXACTLY
    (path correctness), and teacher-forced logits stay within the
    calibrated drift bound vs fp.  NOTE the bound is loose (measured
    rel 0.75-1.0 across seeds): pruning half of a random-init model is
    a large perturbation — trained models have the redundancy pruning
    exploits, random weights do not — so the sharp assertion here is
    self-consistency, not agreement."""
    from repro.models import model as M
    cfg, params = lm
    qp = quantize_tree(params, PROFILES["nanomind-sparse"])
    tokens = (jnp.arange(24)[None] % 60 + 3).astype(jnp.int32)
    steps = 6

    def top1(lg):
        return int(jnp.argmax(lg.reshape(lg.shape[0], -1)[0], -1))

    lg, cache = M.lm_prefill(qp, cfg, tokens, 40)
    seq = [top1(lg)]
    for _ in range(steps - 1):
        lg, cache = M.lm_decode_step(
            qp, cfg, jnp.full((1, 1), seq[-1], jnp.int32), cache)
        seq.append(top1(lg))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    full = jnp.concatenate(
        [tokens, jnp.asarray(seq[:-1], jnp.int32)[None]], axis=1)
    out_q, _ = M.lm_forward(qp, cfg, full)
    S = tokens.shape[1]
    replay = [int(jnp.argmax(out_q[0, S - 1 + i])) for i in range(steps)]
    assert replay == seq

    ref, _ = M.lm_forward(params, cfg, full)
    rel = float(jnp.max(jnp.abs(out_q - ref))
                / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1.2, rel                # measured 0.75-1.0 across seeds
