"""Property tests for the hybrid quantization machinery (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as hst

from repro.core.quantize import (PROFILES, QTensor, QuantPolicy, QuantSpec,
                                 dequantize, quantize, quantize_tree,
                                 dequantize_tree, tree_bytes, unpack_codes)

bits_st = hst.sampled_from([2, 4, 8])
dims_st = hst.tuples(hst.integers(1, 7), hst.integers(8, 130))


@given(bits=bits_st, dims=dims_st, seed=hst.integers(0, 2**31 - 1))
def test_roundtrip_error_bound(bits, dims, seed):
    """Per-group error bound with the *chosen* scale s: unclipped values sit
    within s/2 of their code, clipped outliers within amax - s*qmax.  The
    MSE scale search (scale_search > 1) may shrink s below amax/qmax, so the
    bound uses qt.scales rather than assuming the max-abs scale; the search
    must also never do worse than max-abs in group MSE."""
    spec = QuantSpec(bits, group_size=32)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal(dims), jnp.float32)
    qt = quantize(w, spec)
    dq = dequantize(qt)
    assert dq.shape == w.shape and dq.dtype == w.dtype
    # per-group bound with the actual scale
    pad = (-dims[-1]) % 32
    wp = np.pad(np.asarray(w), [(0, 0)] * (w.ndim - 1) + [(0, pad)])
    grp = wp.reshape(*wp.shape[:-1], -1, 32)
    amax = np.abs(grp).max(-1)
    s = np.asarray(qt.scales, np.float64)
    bound = np.maximum(s / 2, amax - s * qt.spec.qmax) + 1e-6
    err = np.abs(np.asarray(dq) - np.asarray(w))
    errp = np.pad(err, [(0, 0)] * (w.ndim - 1) + [(0, pad)])
    err_grp = errp.reshape(*wp.shape[:-1], -1, 32).max(-1)
    assert np.all(err_grp <= bound)
    # the searched scale improves (or matches) max-abs in squared error
    base = dequantize(quantize(w, QuantSpec(bits, group_size=32,
                                            scale_search=1)))
    mse = float(jnp.sum((dq - w) ** 2))
    mse_base = float(jnp.sum((base - w) ** 2))
    assert mse <= mse_base + 1e-6


@given(bits=bits_st, seed=hst.integers(0, 2**31 - 1))
def test_pack_unpack_codes_exact(bits, seed):
    spec = QuantSpec(bits, group_size=32)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    qt = quantize(w, spec)
    codes = unpack_codes(qt.codes, spec)
    assert int(codes.max()) <= spec.qmax
    assert int(codes.min()) >= spec.qmin


def test_qtensor_is_pytree(key):
    w = jax.random.normal(key, (16, 64))
    qt = quantize(w, QuantSpec(4))
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert jnp.allclose(dequantize(qt2), dequantize(qt))
    # flows through jit
    out = jax.jit(lambda q: dequantize(q).sum())(qt)
    assert jnp.isfinite(out)


def test_bits_monotone_error(key):
    """Fig. 7's qualitative ordering: more bits -> less error."""
    w = jax.random.normal(key, (64, 256))
    errs = []
    for bits in (2, 4, 8):
        dq = dequantize(quantize(w, QuantSpec(bits)))
        errs.append(float(jnp.mean(jnp.abs(dq - w))))
    assert errs[0] > errs[1] > errs[2]


def test_policy_profiles_label_bricks(key):
    pol = PROFILES["nanomind-default"]
    assert pol.label_for("vis_proj/w1") == "fp16"
    assert pol.label_for("embed") == "fp16"
    assert pol.label_for("layers/0/mixer/wq") == "q4f16"
    assert pol.label_for("lm_head") == "q4f16"


def test_quantize_tree_and_memory_accounting(key):
    from repro.configs import get_config
    from repro.launch.steps import init_params
    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(key, cfg)
    full = tree_bytes(params)
    q4 = quantize_tree(params, PROFILES["all-q4"])
    q4_bytes = tree_bytes(q4)
    assert q4_bytes < full  # int4+scales < bf16
    # hybrid: vision stays fp16 -> bigger than all-q4, smaller than full
    hybrid = tree_bytes(quantize_tree(params, PROFILES["nanomind-default"]))
    assert q4_bytes <= hybrid <= full
    # dequantize_tree restores shapes/dtypes
    dq = dequantize_tree(q4)
    for a, b in zip(jax.tree.leaves(dq), jax.tree.leaves(params)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_quantized_model_still_predicts(key):
    """W4A16 forward stays close to bf16 (the paper's '4-bit LLMs are
    sufficient' claim at smoke scale)."""
    from repro.configs import get_config
    from repro.launch.steps import init_params
    from repro.models.model import lm_forward
    cfg = get_config("stablelm-1.6b").reduced(n_layers=2)
    params = init_params(key, cfg)
    tokens = jnp.arange(32)[None] % 100 + 3
    ref, _ = lm_forward(params, cfg, tokens)
    dq = dequantize_tree(quantize_tree(params, PROFILES["all-q4"]))
    out, _ = lm_forward(dq, cfg, tokens)
    # a random-init model has near-uniform logits, so top-1 flips easily;
    # the robust check is logit closeness + above-chance agreement
    err = jnp.max(jnp.abs(out[..., :cfg.vocab_size]
                          - ref[..., :cfg.vocab_size]))
    rel = float(err) / (float(jnp.max(jnp.abs(ref[..., :cfg.vocab_size])))
                        + 1e-9)
    assert rel < 1.0                               # same logit scale
    agree = jnp.mean((jnp.argmax(out, -1) == jnp.argmax(ref, -1))
                     .astype(jnp.float32))
    # random-init logits are near-uniform so q4 flips many argmaxes; the
    # signal is agreement FAR above chance (1/512).  Trained-model quality
    # is validated in benchmarks/fig7 and tests/test_serve_quant.py.
    assert float(agree) > 100.0 / cfg.vocab_size
