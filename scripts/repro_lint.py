#!/usr/bin/env python
"""replint CLI — gate the tree on the five rule families (docs/LINTS.md).

Usage:
    python scripts/repro_lint.py                 # lint src/, exit 1 on findings
    python scripts/repro_lint.py --json out.json # also write the JSON report
    python scripts/repro_lint.py --write-baseline  # accept current findings

Wired into ``make lint``, scripts/check.sh and the CI lint job (which
uploads the JSON report as an artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.lint import run_lint, write_baseline  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path, default=ROOT / "src" / "repro",
                    help="tree to lint (default: src/repro)")
    ap.add_argument("--baseline", type=Path,
                    default=ROOT / "scripts" / "replint_baseline.json",
                    help="checked-in accepted-debt file")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the machine-readable report here")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept every current unsuppressed finding into "
                         "the baseline and exit 0")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    result = run_lint(args.root, baseline=args.baseline)

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(result.to_json(), indent=2) + "\n",
                             encoding="utf-8")

    if args.write_baseline:
        write_baseline(args.baseline, result.findings
                       + result.baseline_matched)
        print(f"replint: baseline written to {args.baseline} "
              f"({len(result.findings) + len(result.baseline_matched)} "
              f"entries)")
        return 0

    for f in result.findings:
        print(f.render())
    if not args.quiet:
        print(f"replint: {result.files_checked} files, "
              f"{len(result.findings)} unsuppressed, "
              f"{len(result.suppressed)} suppressed, "
              f"{len(result.baseline_matched)} baselined")
    return 1 if result.findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
