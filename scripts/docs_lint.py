#!/usr/bin/env python
"""Docs lint — keeps documentation from rotting silently.

Two gates, both wired into scripts/check.sh:

1. **Docstring lint** (always): every module under ``src/repro/core/``
   must open with a module docstring — these are the paper-mapping
   modules (bricks, plan, tabm, scheduler, power, cascade, quantize) and
   their docstrings are the primary paper-term documentation.

2. **README smoke** (``--docs``): every ```python fenced block in
   README.md (and any file passed via --readme) is executed, in order,
   in one shared namespace.  If the quickstart drifts from the real API,
   check fails instead of shipping a broken first-run experience.

Usage:
    python scripts/docs_lint.py            # docstring lint only
    python scripts/docs_lint.py --docs     # + execute README code blocks
"""
from __future__ import annotations

import argparse
import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def lint_docstrings(pkg_dir: pathlib.Path) -> list[str]:
    errors = []
    for path in sorted(pkg_dir.glob("*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        if path.name == "__init__.py" and not tree.body:
            continue                       # empty package marker is fine
        if ast.get_docstring(tree) is None:
            errors.append(f"{path.relative_to(ROOT)}: missing module "
                          f"docstring")
    return errors


def run_readme_blocks(md_path: pathlib.Path) -> list[str]:
    errors = []
    blocks = _FENCE.findall(md_path.read_text())
    ns: dict = {"__name__": "__docs__"}
    for i, src in enumerate(blocks, 1):
        try:
            exec(compile(src, f"{md_path.name}[python block {i}]", "exec"),
                 ns)
        except Exception as e:             # report, keep linting the rest
            errors.append(f"{md_path.name} python block {i} failed: "
                          f"{type(e).__name__}: {e}")
    if not blocks:
        errors.append(f"{md_path.name}: no ```python blocks found — "
                      f"quickstart missing?")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docs", action="store_true",
                    help="also execute README ```python blocks (smoke)")
    ap.add_argument("--readme", default="README.md",
                    help="markdown file whose python blocks --docs runs")
    args = ap.parse_args(argv)

    sys.path.insert(0, str(ROOT / "src"))
    errors = lint_docstrings(ROOT / "src" / "repro" / "core")
    for doc in ("README.md", "docs/ARCHITECTURE.md", "docs/TABM.md"):
        if not (ROOT / doc).exists():
            errors.append(f"{doc}: missing")
    if args.docs and not errors:
        errors += run_readme_blocks(ROOT / args.readme)

    for e in errors:
        print(f"docs-lint: {e}", file=sys.stderr)
    print("docs-lint: OK" if not errors
          else f"docs-lint: {len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
