#!/usr/bin/env python
"""Regression gate over the versioned benchmark ledger.

    PYTHONPATH=src python scripts/bench_gate.py CANDIDATE.json \
        [--baseline BENCH_N.json] [--allow-missing]

Compares every ``gate: true`` metric in the BASELINE (the latest
committed ``BENCH_<n>.json`` at the repo root unless ``--baseline`` is
given) against the freshly produced CANDIDATE:

* a gated baseline metric missing from the candidate fails (a bench was
  silently dropped) unless ``--allow-missing``;
* a candidate value worse than baseline by more than the baseline's
  ``rel_tol`` in its ``better`` direction fails;
* no baseline at all accepts with a notice — the first PR that ships a
  ledger has nothing to regress against.

Only machine-independent metrics carry ``gate: true`` (simulated fleet
tokens/s and J/token, analytic traffic ratios); raw wall-clock rides
along ungated.  See src/repro/telemetry/writer.py.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.telemetry import writer  # noqa: E402


def compare(baseline: dict, candidate: dict, *,
            allow_missing: bool = False):
    """Pure gate: returns (ok, report_lines).  Testable without files."""
    base_gated = writer.gated_metrics(baseline)
    cand_all = {
        f"{sec}/{name}": m
        for sec, body in (candidate.get("sections") or {}).items()
        for name, m in (body.get("metrics") or {}).items()}
    ok = True
    lines = []
    for key, bm in sorted(base_gated.items()):
        cm = cand_all.get(key)
        if cm is None:
            if allow_missing:
                lines.append(f"SKIP {key}: missing from candidate "
                             f"(--allow-missing)")
                continue
            lines.append(f"FAIL {key}: gated metric missing from candidate")
            ok = False
            continue
        bv, cv = float(bm["value"]), float(cm["value"])
        tol = float(bm.get("rel_tol", 0.10))
        if bm.get("better") == "lower":
            worse = cv > bv * (1.0 + tol)
        else:
            worse = cv < bv * (1.0 - tol)
        rel = (cv - bv) / bv if bv else float("inf")
        verdict = "FAIL" if worse else "PASS"
        lines.append(f"{verdict} {key}: baseline={bv:.6g} "
                     f"candidate={cv:.6g} ({rel:+.1%}, tol ±{tol:.0%}, "
                     f"better={bm.get('better')})")
        ok = ok and not worse
    if not base_gated:
        lines.append("PASS: baseline has no gated metrics")
    return ok, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate a fresh BENCH json against the committed one")
    ap.add_argument("candidate", help="freshly produced BENCH_<pr>.json")
    ap.add_argument("--baseline", default=None,
                    help="explicit baseline (default: latest committed "
                         "BENCH_<n>.json at the repo root, excluding the "
                         "candidate)")
    ap.add_argument("--root", default=str(
        Path(__file__).resolve().parent.parent),
        help="where to look for committed baselines")
    ap.add_argument("--allow-missing", action="store_true",
                    help="tolerate gated baseline metrics absent from the "
                         "candidate (partial bench runs)")
    args = ap.parse_args(argv)

    candidate = writer.read_bench(args.candidate)
    base_path = args.baseline or writer.latest_baseline(
        args.root, exclude=args.candidate)
    if base_path is None:
        print(f"bench_gate: no committed baseline under {args.root}; "
              f"accepting {args.candidate}")
        return 0
    baseline = writer.read_bench(base_path)
    print(f"bench_gate: {args.candidate} vs baseline {base_path}")
    ok, lines = compare(baseline, candidate,
                        allow_missing=args.allow_missing)
    print("\n".join(lines))
    print("OK: no gated regressions" if ok
          else "FAIL: gated benchmark regression")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
