#!/usr/bin/env bash
# Tier-1 gate, runnable without TPU hardware: the full pytest suite plus a
# reduced lower+compile dry-run for one lm and one vlm arch, so ExecutionPlan
# or sharding regressions surface from a plain CPU container.
#
#     make check        (or: bash scripts/check.sh [extra pytest args])
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
# the two seed-era deselects (jamba hybrid decode drift, q4 decode top-1
# agreement) are fixed — the full suite runs with no exclusions
python -m pytest -x -q "$@"

echo "== docs lint (core docstrings + README quickstart smoke) =="
python scripts/docs_lint.py --docs

echo "== replint (lock discipline, donation, dispatch, host-sync, triples) =="
# AST analyzer over src/ — zero unsuppressed findings required; the JSON
# report lands next to the other check outputs (docs/LINTS.md)
mkdir -p /tmp/repro-check
python scripts/repro_lint.py --json /tmp/repro-check/replint.json

echo "== reduced dry-run: lm arch =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m repro.launch.dryrun --arch stablelm-1.6b --shape decode_32k \
    --reduced --out /tmp/repro-check/dryrun

echo "== reduced dry-run: vlm arch =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m repro.launch.dryrun --arch llava-onevision-0.5b \
    --shape decode_32k --reduced --out /tmp/repro-check/dryrun

echo "== backend lowering matrix: host | device | submesh =="
# the same reduced vlm graph must compile and run under every backend in
# the core/backends table (submesh on 8 placeholder devices), so no
# backend path rots without TPU hardware
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m repro.launch.dryrun_backends --arch llava-onevision-0.5b \
    --backends host,device,submesh

echo "== mixed-class TABM engine smoke: hi-res + thumbnail =="
# one high-resolution and one thumbnail request through ServingEngine on
# placeholder devices: classification at submit, per-class staging
# threads, class-sized ring commits, per-class drain (core/slot_classes)
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m repro.launch.smoke_classes

echo "== batched staging smoke: strided slab commit + grouped prefill =="
# eight queued same-class requests through the microbatching pipeline:
# multi-request produce_many slab commits, batch>1 grouped prefill with
# KVCache.insert_many, greedy tokens identical to the one-by-one oracle
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m repro.launch.smoke_classes --stage-batch 4

echo "== decode-cohort smoke: paged KV + mid-flight admit/retire =="
# five mixed-class requests against a 2-slot paged pool: continuous
# batching must retire and admit mid-flight while survivors decode in
# one batched cohort step, with tokens == the per-request oracle
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m repro.launch.smoke_classes --decode-cohort

echo "== disaggregated-fleet smoke: prefill fleet | pipe | decode fleet =="
# two-fleet serving with the decode fleet as a REAL subprocess over OS
# pipes: >=3 mixed-class requests cross as serialized RemotePrefill
# frames (slab + written KV blocks only); the driver asserts greedy
# tokens bit-identical to a single-process oracle and wire KV bytes
# under the whole-lane baseline (launch/serve_disagg.py)
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m repro.launch.serve_disagg --transport pipe --requests 3

echo "== fleet battery-simulation smoke: telemetry-priced devices =="
# >=100 simulated devices on a small pack traverse all three power
# states (per-device PMU under one PowerPolicy, modality profile priced
# from the modeled telemetry ledger) and report fleet tokens/s, J/token
# and a survival-hours histogram; asserts enforced by --smoke
BENCH_JSON="BENCH_$(python -c 'from repro.telemetry.writer import CURRENT_PR; print(CURRENT_PR)').json"
python -m repro.launch.fleet_sim --smoke --bench-json "$BENCH_JSON"

echo "== benchmark ledger + regression gate: $BENCH_JSON =="
# the versioned bench trajectory: fused cohort-decode (bit-identical
# pallas step; gates on the modeled HBM weight-traffic ratio and on
# cohort batching staying a real speedup) and the fused dequant-GEMM
# kernel (analytic traffic ratio), folded into the same BENCH_<pr>.json
# as the fleet metrics above, then regression-gated against the last
# committed baseline
python -m benchmarks.bench_decode --smoke --bench-json "$BENCH_JSON"
python -m benchmarks.bench_kernels --smoke --bench-json "$BENCH_JSON"
python scripts/bench_gate.py "$BENCH_JSON"

echo "OK: check passed"
