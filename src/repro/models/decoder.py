"""Scan-based decoder stack supporting dense / MoE / SSM / hybrid layouts.

The stack is a ``jax.lax.scan`` over *groups* of sublayers.  Uniform archs use
a group of one sublayer; Jamba-style hybrids use ``cfg.hybrid_group`` (8:
one attention layer at ``cfg.attn_every``, Mamba elsewhere, MoE FFN on odd
positions).  Scanning keeps the HLO O(1) in depth — a 95-layer model compiles
as fast as a 2-layer one, which is what makes the 80-cell multi-pod dry-run
tractable (DESIGN.md §3).

Each sublayer: ``x += mixer(norm(x))`` then ``x += ffn(norm(x))`` (pre-norm).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import linear_attention as lin
from repro.models import mamba2
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import apply_norm, init_norm


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def group_size(cfg) -> int:
    return cfg.hybrid_group or 1


def n_groups(cfg) -> int:
    g = group_size(cfg)
    assert cfg.n_layers % g == 0, (cfg.n_layers, g)
    return cfg.n_layers // g


def sublayer_spec(cfg, pos: int) -> Tuple[str, str]:
    """(mixer_kind, ffn_kind) for position ``pos`` within a group."""
    if cfg.family == "ssm":
        return "mamba", ("none" if cfg.d_ff == 0 else "mlp")
    if cfg.hybrid_group:
        mixer = "attn" if pos == cfg.attn_every else "mamba"
        ffn = "moe" if (cfg.moe and pos % cfg.moe.every == cfg.moe.every - 1) \
            else "mlp"
        return mixer, ffn
    ffn = "moe" if cfg.moe is not None else "mlp"
    return "attn", ffn


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_group(key, cfg, qkv_bias: bool = False):
    subs = []
    for pos in range(group_size(cfg)):
        key, k1, k2 = jax.random.split(key, 3)
        mixer_kind, ffn_kind = sublayer_spec(cfg, pos)
        sub: dict[str, Any] = {"norm1": init_norm(cfg, cfg.d_model)}
        if mixer_kind == "attn":
            sub["mixer"] = attn.init_attn(k1, cfg, cfg.d_model, qkv_bias)
        else:
            sub["mixer"] = mamba2.init_mamba(k1, cfg)
        if ffn_kind != "none":
            sub["norm2"] = init_norm(cfg, cfg.d_model)
            sub["ffn"] = (moe_mod.init_moe(k2, cfg, cfg.d_model)
                          if ffn_kind == "moe"
                          else mlp_mod.init_mlp(k2, cfg, cfg.d_model, cfg.d_ff))
        subs.append(sub)
    return tuple(subs)


def init_stack(key, cfg, qkv_bias: bool = False):
    """Stacked group params with leading dim n_groups (for lax.scan)."""
    keys = jax.random.split(key, n_groups(cfg))
    return jax.vmap(lambda k: init_group(k, cfg, qkv_bias))(keys)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _mixer_full(cfg, sub, pos, x, rope_fn, causal, want_cache, decode_len):
    mixer_kind, _ = sublayer_spec(cfg, pos)
    h = apply_norm(sub["norm1"], x)
    if mixer_kind == "mamba":
        y, (conv_tail, hstate) = mamba2.mamba_forward(sub["mixer"], cfg, h)
        cache = (conv_tail, hstate) if want_cache else None
        return x + y, cache
    if cfg_attn_impl(cfg) == "linear":
        q, k, v = attn.qkv_proj(sub["mixer"], h)
        q, k = rope_fn(q), rope_fn(k)
        G = cfg.n_heads // cfg.n_kv_heads
        k, v = jnp.repeat(k, G, axis=2), jnp.repeat(v, G, axis=2)
        o, state, z = lin.linear_attn_prefill(q, k, v)
        y = attn.out_proj(sub["mixer"], o)
        cache = (state, z) if want_cache else None
        return x + y, cache
    from repro.distributed.sharding import constrain_residual
    y, (k, v) = attn.attn_train(sub["mixer"], cfg, h, rope_fn, causal=causal)
    cache = None
    if want_cache:
        B, S, KV, hd = k.shape
        pad = decode_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = (k, v)
    return x + constrain_residual(y), cache


def _ffn(cfg, sub, pos, x):
    from repro.distributed.sharding import constrain_residual
    _, ffn_kind = sublayer_spec(cfg, pos)
    if ffn_kind == "none":
        return x, 0.0
    h = apply_norm(sub["norm2"], x)
    if ffn_kind == "moe":
        y, aux = moe_mod.apply_moe(sub["ffn"], cfg, h)
        return x + constrain_residual(y), aux
    # constraining the partial-sum product BEFORE the add makes GSPMD emit
    # a reduce-scatter instead of all-reduce(+slice) — §Perf iteration
    return x + constrain_residual(mlp_mod.apply_mlp(sub["ffn"], cfg, h)), 0.0


def cfg_attn_impl(cfg) -> str:
    return cfg.attn_impl


def group_forward(cfg, gp, x, rope_fn, *, causal=True, want_cache=False,
                  decode_len=0):
    caches, aux = [], 0.0
    for pos in range(group_size(cfg)):
        sub = gp[pos]
        x, cache = _mixer_full(cfg, sub, pos, x, rope_fn, causal,
                               want_cache, decode_len)
        x, a = _ffn(cfg, sub, pos, x)
        caches.append(cache)
        aux = aux + a
    return x, tuple(caches), aux


def stack_forward(params_layers, cfg, x, rope_fn, *, causal=True,
                  want_cache=False, decode_len=0, remat=None):
    """Run the whole stack.  Returns (x, stacked caches, aux)."""
    from repro.distributed.sharding import constrain_residual
    remat = cfg.remat if remat is None else remat

    from repro.distributed.sharding import rs_gradients

    def body(x, gp):
        # backward: cotangents constrained to param sharding -> per-layer
        # gradient reduce-scatter instead of all-reduce (§Perf)
        gp = rs_gradients(gp)
        x, caches, aux = group_forward(cfg, _maybe_dequant(gp), x, rope_fn,
                                       causal=causal,
                                       want_cache=want_cache,
                                       decode_len=decode_len)
        # sequence-parallel scan carry: bounds saved-activation memory
        return constrain_residual(x), (caches, aux)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (caches, aux) = jax.lax.scan(body, x, params_layers)
    return x, caches, jnp.sum(aux)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _mixer_decode(cfg, sub, pos, x, cache, index, rope_fn):
    mixer_kind, _ = sublayer_spec(cfg, pos)
    h = apply_norm(sub["norm1"], x)
    if mixer_kind == "mamba":
        conv_state, hstate = cache
        y, new_cache = mamba2.mamba_decode(sub["mixer"], cfg, h, conv_state,
                                           hstate)
        return x + y, new_cache
    if cfg_attn_impl(cfg) == "linear":
        state, z = cache
        q, k, v = attn.qkv_proj(sub["mixer"], h)
        q, k = rope_fn(q), rope_fn(k)
        G = cfg.n_heads // cfg.n_kv_heads
        k, v = jnp.repeat(k, G, axis=2), jnp.repeat(v, G, axis=2)
        o, state, z = lin.linear_attn_decode(q, k, v, state, z)
        return x + attn.out_proj(sub["mixer"], o), (state, z)
    cache_k, cache_v = cache
    y, k_new, v_new = attn.attn_decode(sub["mixer"], cfg, h, cache_k, cache_v,
                                       index, rope_fn)
    cache_k, cache_v = attn.update_cache(cache_k, cache_v, k_new, v_new, index)
    return x + y, (cache_k, cache_v)


def group_decode(cfg, gp, x, caches, index, rope_fn):
    new_caches = []
    for pos in range(group_size(cfg)):
        sub = gp[pos]
        x, nc = _mixer_decode(cfg, sub, pos, x, caches[pos], index, rope_fn)
        x, _ = _ffn(cfg, sub, pos, x)
        new_caches.append(nc)
    return x, tuple(new_caches)


def _maybe_dequant(gp):
    """W4A16 serving: dequantize one group's packed weights at use.  Inside
    the scan body XLA fuses the unpack into each consuming matmul — the
    paper's in-register dequant; the explicit MXU kernel is
    kernels/dequant_gemm (TPU dispatch)."""
    from repro.core.quantize import QTensor, dequantize_tree
    return dequantize_tree(gp)


def stack_decode(params_layers, cfg, x, caches, index, rope_fn):
    def body(x, xs):
        gp, cache = xs
        x, new_cache = group_decode(cfg, _maybe_dequant(gp), x, cache,
                                    index, rope_fn)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params_layers, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# cache allocation (decode without a prior prefill — dry-run entry)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    """Zero caches, stacked (n_groups, ...)."""
    def one_group():
        caches = []
        for pos in range(group_size(cfg)):
            mixer_kind, _ = sublayer_spec(cfg, pos)
            if mixer_kind == "mamba":
                caches.append(mamba2.init_mamba_cache(cfg, batch))
            elif cfg_attn_impl(cfg) == "linear":
                H, hd = cfg.n_heads, cfg.hd
                caches.append((jnp.zeros((batch, H, hd, hd), jnp.float32),
                               jnp.zeros((batch, H, hd), jnp.float32)))
            else:
                KV, hd = cfg.n_kv_heads, cfg.hd
                caches.append(
                    (jnp.zeros((batch, max_len, KV, hd), cfg.compute_dtype),
                     jnp.zeros((batch, max_len, KV, hd), cfg.compute_dtype)))
        return tuple(caches)

    one = one_group()
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (n_groups(cfg),) + t.shape), one)
