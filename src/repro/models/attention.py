"""GQA attention: chunked online-softmax (train/prefill) + cached decode.

Three entry points:

* :func:`attn_train`   — full-sequence attention (causal or bidirectional),
  memory-efficient chunked online softmax (the pure-jnp oracle for the Pallas
  flash kernel), returns per-position outputs.
* :func:`attn_prefill` — attn_train + returns (k, v) to seed the cache.
* :func:`attn_decode`  — one new token against a pre-allocated cache whose
  *sequence* dimension may be sharded across the `model` mesh axis; the
  softmax over the sharded axis lowers to an XLA distributed reduction
  (FlashDecoding-across-chips, see DESIGN.md §2).

GQA layout: q (B,S,H,hd), k/v (B,S,KV,hd) with H = KV*G.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

NEG_INF = -1e30


def init_attn(key, cfg, d_model: int, qkv_bias: bool = False):
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = cfg.compute_dtype
    p = {
        "wq": dense_init(ks[0], (d_model, H, hd), dt, fan_in=d_model),
        "wk": dense_init(ks[1], (d_model, KV, hd), dt, fan_in=d_model),
        "wv": dense_init(ks[2], (d_model, KV, hd), dt, fan_in=d_model),
        "wo": dense_init(ks[3], (H, hd, d_model), dt, fan_in=H * hd),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
    return p


def qkv_proj(p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def out_proj(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# chunked online-softmax attention
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                      kv_chunk: int = 1024) -> jnp.ndarray:
    """Memory-efficient attention.  q (B,Sq,H,hd), k/v (B,Sk,KV,hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0

    qc = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, kv_chunk, KV, hd)
    vc = v.reshape(B, nk, kv_chunk, KV, hd)
    q_pos = jnp.arange(Sq).reshape(nq, q_chunk)
    k_pos = jnp.arange(Sk).reshape(nk, kv_chunk)

    def per_q_chunk(qi, q_i):
        def per_kv_chunk(carry, j):
            m, l, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
            s = jnp.einsum("bqkgh,bckh->bqkgc", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = q_pos[qi][:, None] >= k_pos[j][None, :]
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckh->bqkgh", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, KV, G), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, KV, G, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(per_kv_chunk, (m0, l0, a0),
                                      jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(lambda args: per_q_chunk(*args),
                      (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def dense_attention(q, k, v, *, causal: bool) -> jnp.ndarray:
    """Single fused dot->softmax->dot region (no chunk loops).  This is the
    computational shape of the Pallas flash kernel
    (kernels/flash_attention); on TPU ops.flash_attention replaces it, and
    the dry-run cost model counts the score matrix VMEM-resident."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bikgh,bjkh->bkgij", qg, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgij,bjkh->bikgh", prob.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def attn_train(p, cfg, x, rope_fn, *, causal=True, kv_override=None):
    """Full-sequence attention.  ``rope_fn`` applies positions to q/k.

    kv_override: (k, v) for cross-attention (encoder-decoder)."""
    from repro.distributed.sharding import constrain_heads
    q, k, v = qkv_proj(p, x)
    if kv_override is not None:
        k, v = kv_override
        q = rope_fn(q)
    else:
        q, k = rope_fn(q), rope_fn(k)
    # TP-region layout: heads sharded, sequence replicated (see sharding.py)
    q, k, v = constrain_heads(q), constrain_heads(k), constrain_heads(v)
    if getattr(cfg, "attn_q_chunk", 512) == 0:
        from repro.kernels.dispatch import resolve_interpret
        if not resolve_interpret():
            # the real kernel on real hardware; dense_attention is its
            # compile-time stand-in off-TPU and under force_ref()
            from repro.kernels.flash_attention import flash_attention
            o = flash_attention(q, k, v, causal=causal)
        else:
            o = dense_attention(q, k, v, causal=causal)
    else:
        o = chunked_attention(q, k, v, causal=causal,
                              q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk)
    o = constrain_heads(o)
    return out_proj(p, o), (k, v)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def attn_decode(p, cfg, x, cache_k, cache_v, index, rope_fn
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode.  x (B,1,D); cache_k/v (B,S,KV,hd); index: current
    length (new token is written at ``index``).  Returns (out, k_new, v_new)
    where k/v_new are the (B,1,KV,hd) slices for the cache update."""
    q, k_new, v_new = qkv_proj(p, x)
    q, k_new = rope_fn(q), rope_fn(k_new)
    o = attn_context(q, k_new, v_new, cache_k, cache_v, index, cfg)
    return out_proj(p, o), k_new, v_new


def attn_context(q, k_new, v_new, cache_k, cache_v, index, cfg
                 ) -> jnp.ndarray:
    """The decode attention core between the QKV projection and the output
    projection: online softmax over the cache plus the (not yet written)
    new token.  Shared verbatim by :func:`attn_decode` and the fused
    decode path (kernels/fused_decode), so the two stay bit-identical."""
    B, S, KV, hd = cache_k.shape
    H = cfg.n_heads
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg[:, 0], cache_k,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.asarray(index)
    if idx.ndim == 0:                      # scalar: all slots same length
        idx = jnp.broadcast_to(idx, (B,))
    valid = (jnp.arange(S)[None, :] < idx[:, None])[:, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    s_new = jnp.einsum("bkgh,bkh->bkg", qg[:, 0], k_new[:, 0],
                       preferred_element_type=jnp.float32) * scale
    m = jnp.maximum(s.max(axis=-1), s_new)
    p_cache = jnp.exp(s - m[..., None])
    p_new = jnp.exp(s_new - m)
    denom = p_cache.sum(axis=-1) + p_new
    o = jnp.einsum("bkgs,bskh->bkgh", p_cache.astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = o + p_new[..., None] * v_new[:, 0, :, None, :].astype(jnp.float32)
    return (o / denom[..., None]).astype(q.dtype).reshape(B, 1, H, hd)


def update_cache(cache_k, cache_v, k_new, v_new, index):
    """Write the new token's K/V at ``index`` — ALWAYS as a batched
    scatter, never dynamic-update-slice.

    Perf iteration (EXPERIMENTS.md §Perf, deepseek decode): a DUS into a
    sequence-SHARDED cache lowers under GSPMD to a select over the full
    local shard — a whole-cache read+write per token (1.2 TB/step/device
    at the 32k cell).  A scatter with explicit (b, idx) indices partitions
    to the owning shard and updates in place under donation: traffic is
    the update row, not the buffer."""
    idx = jnp.asarray(index)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (cache_k.shape[0],))
    b = jnp.arange(cache_k.shape[0])
    cache_k = cache_k.at[b, idx].set(k_new[:, 0])
    cache_v = cache_v.at[b, idx].set(v_new[:, 0])
    return cache_k, cache_v
