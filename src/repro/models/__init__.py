"""Model substrate: layers, attention variants, SSM, MoE, full models."""
