"""Encoder-decoder model (seamless-m4t family).

The audio frontend is a stub per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, T_src, d_model).  Encoder = bidirectional
attention stack; decoder = causal self-attention + cross-attention + FFN.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (apply_norm, apply_rope, default_positions,
                                 dense_init, embed_init, init_norm)
from repro.models.model import _vocab_bias, Z_LOSS

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"norm1": init_norm(cfg, cfg.d_model),
            "attn": attn.init_attn(k1, cfg, cfg.d_model),
            "norm2": init_norm(cfg, cfg.d_model),
            "ffn": mlp_mod.init_mlp(k2, cfg, cfg.d_model, cfg.d_ff)}


def _init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": init_norm(cfg, cfg.d_model),
            "self_attn": attn.init_attn(k1, cfg, cfg.d_model),
            "norm_x": init_norm(cfg, cfg.d_model),
            "cross_attn": attn.init_attn(k2, cfg, cfg.d_model),
            "norm2": init_norm(cfg, cfg.d_model),
            "ffn": mlp_mod.init_mlp(k3, cfg, cfg.d_model, cfg.d_ff)}


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_final_norm": init_norm(cfg, cfg.d_model),
        "embed": embed_init(ks[2], (cfg.padded_vocab, cfg.d_model),
                            cfg.compute_dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": init_norm(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params, cfg, src_embeds, *, remat=None):
    """src_embeds (B, T, D) from the audio-frontend stub."""
    B, T, _ = src_embeds.shape
    rope_fn = lambda t: apply_rope(t, default_positions(B, T), cfg.rope_theta)
    remat = cfg.remat if remat is None else remat

    from repro.distributed.sharding import constrain_residual

    def body(x, lp):
        from repro.models.decoder import _maybe_dequant
        lp = _maybe_dequant(lp)
        h = apply_norm(lp["norm1"], x)
        y, _ = attn.attn_train(lp["attn"], cfg, h, rope_fn, causal=False)
        x = x + y
        h = apply_norm(lp["norm2"], x)
        return constrain_residual(x + mlp_mod.apply_mlp(lp["ffn"], cfg, h)), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, src_embeds.astype(cfg.compute_dtype),
                        params["enc_layers"])
    return apply_norm(params["enc_final_norm"], x)


# ---------------------------------------------------------------------------
# decoder (teacher-forced / prefill / decode)
# ---------------------------------------------------------------------------

def _dec_layer_full(cfg, lp, x, enc_out, rope_fn, want_cache, decode_len):
    h = apply_norm(lp["norm1"], x)
    y, (k, v) = attn.attn_train(lp["self_attn"], cfg, h, rope_fn, causal=True)
    x = x + y
    h = apply_norm(lp["norm_x"], x)
    ck = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross_attn"]["wk"])
    cv = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross_attn"]["wv"])
    y, _ = attn.attn_train(lp["cross_attn"], cfg, h, lambda t: t,
                           causal=False, kv_override=(ck, cv))
    x = x + y
    h = apply_norm(lp["norm2"], x)
    x = x + mlp_mod.apply_mlp(lp["ffn"], cfg, h)
    cache = None
    if want_cache:
        pad = decode_len - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = (k, v, ck, cv)
    return x, cache


def decode_stack(params, cfg, tgt_tokens, enc_out, *, want_cache=False,
                 decode_len=0, remat=None):
    B, S = tgt_tokens.shape
    rope_fn = lambda t: apply_rope(t, default_positions(B, S), cfg.rope_theta)
    x = params["embed"][tgt_tokens]
    remat = cfg.remat if remat is None else remat

    from repro.distributed.sharding import constrain_residual

    def body(x, lp):
        from repro.models.decoder import _maybe_dequant
        x, cache = _dec_layer_full(cfg, _maybe_dequant(lp), x, enc_out,
                                   rope_fn, want_cache, decode_len)
        return constrain_residual(x), cache

    if remat and not want_cache:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    return x, caches


def _logits(params, cfg, x):
    x = apply_norm(params["final_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits.astype(jnp.float32) + _vocab_bias(cfg)[None, None, :]


def encdec_loss(params, cfg, batch, *, remat=None, loss_chunk: int = 1024):
    """batch: src_embeds (B,T,D), tgt_tokens (B,S).  Chunked head (no full
    (B,S,V) logits) — see :func:`repro.models.model.head_loss_chunked`."""
    from repro.models.model import head_loss_chunked
    enc_out = encode(params, cfg, batch["src_embeds"], remat=remat)
    tokens = batch["tgt_tokens"]
    B, S = tokens.shape
    x, _ = decode_stack(params, cfg, tokens, enc_out, remat=remat)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = (jnp.arange(S) < S - 1)[None, :] * jnp.ones((B, 1), jnp.int32)
    nll_sum, z_sum, n = head_loss_chunked(params, cfg, x, labels, mask,
                                          chunk=loss_chunk)
    nll = nll_sum / jnp.maximum(n, 1.0)
    loss = nll + Z_LOSS * (z_sum / jnp.maximum(n, 1.0))
    return loss, {"nll": nll}


def encdec_prefill(params, cfg, src_embeds, tgt_tokens, max_len: int):
    enc_out = encode(params, cfg, src_embeds, remat=False)
    x, caches = decode_stack(params, cfg, tgt_tokens, enc_out,
                             want_cache=True, decode_len=max_len, remat=False)
    logits = _logits(params, cfg, x[:, -1:])
    return logits[:, 0], {"layers": caches,
                          "index": jnp.asarray(tgt_tokens.shape[1], jnp.int32)}


def _cross_decode(lp, cfg, x, ck, cv):
    """Dense cross-attention for one query token.  x (B,1,D)."""
    B, T, KV, hd = ck.shape
    H = cfg.n_heads
    G = H // KV
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, ck,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(cv.dtype), cv)
    return attn.out_proj(lp, o.reshape(B, 1, H, hd))


def encdec_decode_step(params, cfg, tokens, cache):
    """tokens (B,1) -> (logits (B,V), cache)."""
    B = tokens.shape[0]
    index = cache["index"]
    positions = jnp.broadcast_to(index[None, None], (B, 1)).astype(jnp.int32)
    rope_fn = lambda t: apply_rope(t, positions, cfg.rope_theta)
    x = params["embed"][tokens]

    def body(x, xs):
        lp, (k, v, ck, cv) = xs
        from repro.models.decoder import _maybe_dequant
        lp = _maybe_dequant(lp)
        h = apply_norm(lp["norm1"], x)
        y, k_new, v_new = attn.attn_decode(lp["self_attn"], cfg, h, k, v,
                                           index, rope_fn)
        k, v = attn.update_cache(k, v, k_new, v_new, index)
        x = x + y
        h = apply_norm(lp["norm_x"], x)
        x = x + _cross_decode(lp["cross_attn"], cfg, h, ck, cv)
        h = apply_norm(lp["norm2"], x)
        x = x + mlp_mod.apply_mlp(lp["ffn"], cfg, h)
        return x, (k, v, ck, cv)

    x, new_caches = jax.lax.scan(body, x, (params["dec_layers"],
                                           cache["layers"]))
    logits = _logits(params, cfg, x)
    return logits[:, 0], {"layers": new_caches, "index": index + 1}


def init_encdec_decode_state(cfg, batch: int, max_len: int):
    KV, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    dt = cfg.compute_dtype
    caches = (jnp.zeros((L, batch, max_len, KV, hd), dt),
              jnp.zeros((L, batch, max_len, KV, hd), dt),
              jnp.zeros((L, batch, cfg.enc_seq_len, KV, hd), dt),
              jnp.zeros((L, batch, cfg.enc_seq_len, KV, hd), dt))
    return {"layers": caches, "index": jnp.asarray(max_len - 1, jnp.int32)}
