"""Mixture-of-Experts FFN: top-k routed experts + optional shared experts.

Dispatch is the canonical GShard grouped one-hot einsum: tokens are split into
small groups (``group_size`` tokens) and each group gets a fixed per-expert
capacity C = ceil(group_size * top_k * capacity_factor / E).  Under SPMD the
group axis is sharded with the batch (`data`) and the expert axis with the
`model` mesh axis, so the dispatch/combine einsums lower to all-to-alls (EP).

Experts are *bricks at finer grain* in the paper's sense: the scheduler's
placement axis for MoE archs is which expert shard lives on which chip
(DESIGN.md §5).  Dispatch-einsum overhead is real FLOPs and is visible in the
roofline useful-FLOPs ratio; the sort-based dispatch lives in the perf log.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.mlp import init_mlp, apply_mlp

GROUP_SIZE = 256


def capacity(cfg_moe, group_size: int = GROUP_SIZE) -> int:
    c = math.ceil(group_size * cfg_moe.top_k * cfg_moe.capacity_factor
                  / cfg_moe.n_experts)
    return max(4, c)


def init_moe(key, cfg, d_model: int):
    m = cfg.moe
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 5)
    E, F = m.n_experts, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, E), jnp.float32, fan_in=d_model),
        "w_up": dense_init(ks[1], (E, d_model, F), dt, fan_in=d_model),
        "w_gate": dense_init(ks[2], (E, d_model, F), dt, fan_in=d_model),
        "w_down": dense_init(ks[3], (E, F, d_model), dt, fan_in=F),
    }
    if m.n_shared:
        # all assigned MoE archs use gated (SwiGLU) FFNs
        p["shared"] = init_mlp(ks[4], cfg, d_model,
                               m.d_ff_shared or m.d_ff_expert * m.n_shared)
    return p


def route(logits, top_k: int, cap: int):
    """logits (G, S, E) fp32 -> combine (G,S,E,C) fp32, dispatch bf16, aux."""
    G, S, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)                  # (G,S,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    counts = jnp.zeros((G, E), jnp.int32)
    combine = jnp.zeros((G, S, E, cap), jnp.float32)
    for j in range(top_k):
        oh = jax.nn.one_hot(idx[..., j], E, dtype=jnp.int32)  # (G,S,E)
        pos = counts[:, None, :] + jnp.cumsum(oh, axis=1) - oh
        counts = counts + oh.sum(axis=1)
        keep = (pos < cap) & (oh > 0)                          # (G,S,E)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, 0), cap, dtype=jnp.float32)
        combine = combine + (gates[..., j, None, None]
                             * keep[..., None].astype(jnp.float32) * pos_oh)
    dispatch = (combine > 0).astype(jnp.bfloat16)
    # load-balance aux loss (Switch): E * mean(f_e * p_e)
    me = probs.mean(axis=(0, 1))                               # (E,)
    f = (counts.sum(axis=0) / max(1, G * S * top_k)).astype(jnp.float32)
    aux = E * jnp.sum(me * f)
    return combine, dispatch, aux


def apply_moe(p, cfg, x, group_size: int = GROUP_SIZE
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    N = B * S
    gs = min(group_size, N)
    G = N // gs
    xg = x.reshape(G, gs, D)
    cap = capacity(m, gs)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    combine, dispatch, aux = route(logits, m.top_k, cap)

    xe = jnp.einsum("gsd,gsec->gecd", xg, dispatch)            # (G,E,C,D)
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gecd,gsec->gsd", ye, combine.astype(ye.dtype))
    y = y.reshape(B, S, D).astype(x.dtype)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], cfg, x)
    return y, aux
