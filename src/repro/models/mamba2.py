"""Mamba-2 mixer (SSD — state-space duality, arXiv:2405.21060).

Scalar-per-head decay A, per-token dt, grouped B/C projections, causal
depthwise conv on (x,B,C), gated RMSNorm, out projection.

The SSD sequence transform here is the *chunked dual form*: intra-chunk
quadratic attention-like matmuls (MXU-friendly) + inter-chunk state-passing
scan.  ``ssd_reference`` is the slow sequential recurrence used as the oracle
in tests; the Pallas kernel (``repro.kernels.ssd``) mirrors the chunked form.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


# ---------------------------------------------------------------------------
# SSD core: h_t = a_t * h_{t-1} + dt_t * B_t (x) x_t ;  y_t = C_t . h_t + D x_t
#   a_t = exp(dt_t * A)  (A < 0 scalar per head)
# shapes: x (B,S,H,P), dt (B,S,H), B/C (B,S,G,N) with H % G == 0
# ---------------------------------------------------------------------------

def ssd_reference(x, dt, A, Bm, Cm, h0=None):
    """Sequential recurrence oracle.  Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    b, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    a = jnp.exp(dt * A[None, None, :])                       # (B,S,H)

    def step(h, t):
        xt, dtt, at = x[:, t], dt[:, t], a[:, t]
        h = at[..., None, None] * h + (dtt[..., None, None]
                                       * xt[..., :, None] * Bh[:, t, :, None, :])
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch[:, t])
        return h, y

    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                         jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)
    return y.astype(x.dtype), h


def ssd_chunked(x, dt, A, Bm, Cm, h0=None, chunk: int = 256
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked dual-form SSD (matches ``ssd_reference`` to fp32 tolerance)."""
    b, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, H)
    Bf = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32).reshape(b, nc, chunk, H, N)
    Cf = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32).reshape(b, nc, chunk, H, N)
    la = dtf * A[None, None, None, :]                        # log a, (b,nc,c,H)
    cum = jnp.cumsum(la, axis=2)                             # within-chunk cumsum

    # intra-chunk: Y[i] = sum_{j<=i} exp(cum_i - cum_j) * (C_i.B_j) dt_j x_j
    # NOTE: mask INSIDE the exp — for j > i the argument is large-positive
    # (cum decreases), and where(mask, exp(x), 0) is inf*0 = NaN in the VJP.
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (b,nc,i,j,H)
    dec = jnp.exp(jnp.where(mask[None, None, :, :, None], dec, -1e30))
    cb = jnp.einsum("bkihn,bkjhn->bkijh", Cf, Bf)
    w = cb * dec * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bkijh,bkjhp->bkihp", w, xf)

    # chunk states: s_k = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (b,nc,c,H)
    sbx = jnp.einsum("bkjhn,bkjhp->bkhnp",
                     Bf * (decay_to_end * dtf)[..., None], xf)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (b,nc,H)

    def step(h, xs):
        s_k, d_k = xs                                        # (b,H,N,P), (b,H)
        h_new = d_k[..., None, None] * h + s_k
        return h_new, h                                       # emit state *before* this chunk

    if h0 is None:
        h0 = jnp.zeros((b, H, N, P), jnp.float32)
    else:
        h0 = jnp.swapaxes(h0, -1, -2).astype(jnp.float32)    # (b,H,P,N)->(b,H,N,P)
    h_fin, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(sbx, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                    # (b,nc,H,N,P)

    # inter-chunk contribution: C_i . (exp(cum_i) * h_prev)
    y_inter = jnp.einsum("bkihn,bkhnp->bkihp", Cf * jnp.exp(cum)[..., None], h_prevs)

    y = (y_intra + y_inter).reshape(b, S, H, P).astype(x.dtype)
    return y, jnp.swapaxes(h_fin, -1, -2)                    # (b,H,P,N)


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """One-token recurrence.  h (B,H,P,N); x (B,H,P); dt (B,H); B/C (B,G,N)."""
    H = x.shape[1]
    rep = H // Bm.shape[1]
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(dt.astype(jnp.float32) * A[None, :])
    h = a[..., None, None] * h + (dt.astype(jnp.float32)[..., None, None]
                                  * x.astype(jnp.float32)[..., :, None]
                                  * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch)
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# full Mamba-2 mixer layer
# ---------------------------------------------------------------------------

def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    d_conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, H, d_conv_ch


def init_mamba(key, cfg):
    s = cfg.ssm
    d_inner, H, d_conv_ch = _dims(cfg)
    dt_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + H  # z,x,B,C,dt widths
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, dt_proj), dt, fan_in=cfg.d_model),
        "conv_w": dense_init(ks[1], (s.d_conv, d_conv_ch), dt, fan_in=s.d_conv),
        "conv_b": jnp.zeros((d_conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(ks[3], (d_inner, cfg.d_model), dt, fan_in=d_inner),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    gs = s.n_groups * s.d_state
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * gs], axis=-1)
    return z, xbc, dt                                         # dt: (..., H)


def _conv_mix(win, w, b):
    """The ONE depthwise-conv contraction both the full-sequence and the
    one-token decode path share: windows (..., K, C) against taps (K, C),
    accumulated in fp32 with bias+silu applied before the cast back.
    Teacher forcing vs decode must agree bit-for-bit per token, so the
    two paths may not each pick their own summation association."""
    out = jnp.einsum("...kc,kc->...c", win.astype(jnp.float32),
                     w.astype(jnp.float32)) + b.astype(jnp.float32)
    return jax.nn.silu(out)


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d.  xbc (B,S,C); w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    win = jnp.stack([pad[:, i:i + xbc.shape[1], :] for i in range(K)],
                    axis=2)                                   # (B,S,K,C)
    return _conv_mix(win, w, b).astype(xbc.dtype)


def _gated_norm(y, z, scale, eps=1e-5):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(y.dtype)


def mamba_forward(p, cfg, x, h0=None, use_chunked=True):
    """Full-sequence Mamba-2.  x (B,S,D) -> (y (B,S,D), (conv_tail, h_final))."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    B_, S, _ = x.shape
    gs = s.n_groups * s.d_state

    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, proj)
    conv_tail = xbc[:, -(s.d_conv - 1):, :]
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + gs], axis=-1)
    xs = xs.reshape(B_, S, H, s.head_dim)
    Bm = Bm.reshape(B_, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(B_, S, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    fn = ssd_chunked if use_chunked else ssd_reference
    y, h = fn(xs, dtv, A, Bm, Cm, h0=h0,
              **({"chunk": s.chunk_size} if use_chunked else {}))
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, d_inner)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, (conv_tail, h)


def mamba_decode(p, cfg, x, conv_state, h):
    """One-token decode.  x (B,1,D); conv_state (B,d_conv-1,C); h (B,H,P,N)."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    B_ = x.shape[0]
    gs = s.n_groups * s.d_state

    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, proj)
    window = jnp.concatenate([conv_state, xbc], axis=1)       # (B, d_conv, C)
    conv_state_new = window[:, 1:, :]
    conv = _conv_mix(window, p["conv_w"], p["conv_b"]).astype(xbc.dtype)
    xs, Bm, Cm = jnp.split(conv, [d_inner, d_inner + gs], axis=-1)
    xs = xs.reshape(B_, H, s.head_dim)
    Bm = Bm.reshape(B_, s.n_groups, s.d_state)
    Cm = Cm.reshape(B_, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, h = ssd_decode_step(h, xs, dtv, A, Bm, Cm)
    y = y + xs * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(B_, 1, d_inner)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, (conv_state_new, h)


def init_mamba_cache(cfg, batch: int):
    s = cfg.ssm
    d_inner, H, d_conv_ch = _dims(cfg)
    return (jnp.zeros((batch, s.d_conv - 1, d_conv_ch), cfg.compute_dtype),
            jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32))
