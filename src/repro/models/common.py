"""Shared model building blocks: norms, activations, rotary embeddings, init.

All functions are pure; parameters are plain dict pytrees.  Weights are stored
in the config compute dtype (bf16 by default); norm statistics and softmax run
in fp32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    """Truncated-normal init scaled by 1/sqrt(fan_in) (LLaMA-style)."""
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------

def init_norm(cfg, d: int):
    p = {"scale": jnp.ones((d,), cfg.compute_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.compute_dtype)
    return p


def apply_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "squared_relu": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE / partial RoPE / M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta: float, rope_frac: float = 1.0):
    """x: (B, S, H, hd); positions: (B, S) int32.  Partial RoPE rotates only
    the first ``rope_frac`` of head_dim (StableLM-style)."""
    hd = x.shape[-1]
    rot = int(hd * rope_frac)
    rot -= rot % 2
    if rot == 0:
        return x
    freqs = _rope_freqs(rot, theta)                       # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x_rot = _rotate(x_rot, cos, sin)
    return jnp.concatenate([x_rot, x_pass], axis=-1) if x_pass.shape[-1] else x_rot


# M-RoPE (Qwen2-VL): head_dim split into (temporal, height, width) sections,
# each rotated with its own position stream.
MROPE_SECTIONS = (0.25, 0.375, 0.375)


def apply_mrope(x, positions3, theta: float):
    """x: (B, S, H, hd); positions3: (3, B, S) int32 (t, h, w streams)."""
    hd = x.shape[-1]
    half = hd // 2
    sec = [int(half * f) for f in MROPE_SECTIONS]
    sec[-1] = half - sec[0] - sec[1]
    freqs = _rope_freqs(hd, theta)                        # (half,)
    # Build per-frequency positions by interleaving the three streams over
    # frequency sections (Qwen2-VL's "multimodal rotary").
    parts = []
    off = 0
    for i, s in enumerate(sec):
        pos = positions3[i].astype(jnp.float32)           # (B,S)
        parts.append(pos[..., None] * freqs[off:off + s])
        off += s
    ang = jnp.concatenate(parts, axis=-1)                 # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _rotate(x, cos, sin)


def default_positions(batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


def default_mrope_positions(batch: int, seq: int, offset=0):
    p = default_positions(batch, seq, offset)
    return jnp.stack([p, p, p], axis=0)  # text-only: all three streams equal
