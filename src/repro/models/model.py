"""Top-level language-model API: init / forward / loss / prefill / decode.

Handles the decoder-only families (dense, moe, ssm, hybrid, vlm).  The
encoder-decoder (audio) family lives in :mod:`repro.models.encdec`; both share
the same sublayer machinery from :mod:`repro.models.decoder`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decoder as dec
from repro.models.common import (apply_mrope, apply_norm, apply_rope,
                                 default_mrope_positions, default_positions,
                                 dense_init, embed_init, init_norm)

Z_LOSS = 1e-4
AUX_LOSS = 1e-2


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    dt = cfg.compute_dtype
    qkv_bias = cfg.family == "vlm"  # Qwen2 uses qkv biases
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dt),
        "layers": dec.init_stack(ks[1], cfg, qkv_bias),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.padded_vocab),
                                       dt, fan_in=cfg.d_model)
    if cfg.vlm:
        params["vis_proj"] = {
            "w1": dense_init(ks[3], (cfg.vision_feat_dim, cfg.d_model), dt),
            "w2": dense_init(ks[4], (cfg.d_model, cfg.d_model), dt),
        }
    return params


def make_rope_fn(cfg, positions, mrope_positions=None):
    if cfg.rope == "none":
        return lambda t: t
    if cfg.rope == "mrope":
        return lambda t: apply_mrope(t, mrope_positions, cfg.rope_theta)
    return lambda t: apply_rope(t, positions, cfg.rope_theta, cfg.rope_frac)


def _vocab_bias(cfg):
    """-inf bias on padded vocab rows so they never receive probability."""
    v = jnp.arange(cfg.padded_vocab)
    return jnp.where(v < cfg.vocab_size, 0.0, -1e30).astype(jnp.float32)


def _embed(params, cfg, tokens, vision_feats=None):
    x = params["embed"][tokens]
    if cfg.vlm and vision_feats is not None:
        vp = params["vis_proj"]
        v = jax.nn.gelu(jnp.einsum("bnf,fd->bnd",
                                   vision_feats.astype(cfg.compute_dtype),
                                   vp["w1"]))
        v = jnp.einsum("bnd,de->bne", v, vp["w2"])
        x = jnp.concatenate([v, x[:, v.shape[1]:]], axis=1)
    return x


def _head(params, cfg, x):
    from repro.core.quantize import QTensor, dequantize
    x = apply_norm(params["final_norm"], x)
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if isinstance(w, QTensor):
        w = dequantize(w)                      # fuses into the matmul
    w = w.T if cfg.tie_embeddings else w
    # fp32 accumulation: bf16 logits produce *exact* top-1 ties that make
    # greedy argmax an unstable function of benign numeric noise
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    return logits + _vocab_bias(cfg)[None, None, :]


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def lm_forward(params, cfg: ModelConfig, tokens, *, vision_feats=None,
               mrope_positions=None, remat=None):
    B, S = tokens.shape
    positions = default_positions(B, S)
    if cfg.rope == "mrope" and mrope_positions is None:
        mrope_positions = default_mrope_positions(B, S)
    rope_fn = make_rope_fn(cfg, positions, mrope_positions)
    x = _embed(params, cfg, tokens, vision_feats)
    x, _, aux = dec.stack_forward(params["layers"], cfg, x, rope_fn,
                                  causal=True, remat=remat)
    return _head(params, cfg, x), aux


def head_loss_chunked(params, cfg: ModelConfig, x, labels, mask,
                      chunk: int = 1024):
    """Cross-entropy over the vocab WITHOUT materializing (B, S, V) logits.

    Scans the head matmul + softmax-xent over sequence chunks; each chunk's
    logits are transient (recomputed in the backward via checkpoint), so peak
    memory is (B, chunk, V)/shards instead of (B, S, V)/shards.  x (B,S,D);
    labels (B,S) int32; mask (B,S) {0,1}.  Returns (nll_sum, z_sum, n)."""
    from repro.distributed.sharding import constrain_batch_only
    B, S, D = x.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    bias = _vocab_bias(cfg)
    # gather the sequence dim before the chunk scan: scanning a seq-sharded
    # dim would dynamic-slice across shards every iteration
    x = constrain_batch_only(x)

    xc = x.reshape(B, n, chunk, D)
    lc = labels.reshape(B, n, chunk)
    mc = mask.reshape(B, n, chunk)

    @jax.checkpoint
    def body(carry, xs):
        nll_sum, z_sum = carry
        xi, li, mi = xs                                # (B,chunk,D), (B,chunk)
        xi = apply_norm(params["final_norm"], xi)
        logits = jnp.einsum("bsd,dv->bsv", xi, w).astype(jnp.float32)
        logits = logits + bias[None, None, :]
        lse = jax.nn.logsumexp(logits, axis=-1)
        true_logit = jnp.take_along_axis(logits, li[..., None],
                                         axis=-1)[..., 0]
        m = mi.astype(jnp.float32)
        nll_sum = nll_sum + jnp.sum((lse - true_logit) * m)
        z_sum = z_sum + jnp.sum(jnp.square(lse) * m)
        return (nll_sum, z_sum), None

    (nll_sum, z_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0),
         jnp.moveaxis(mc, 1, 0)))
    return nll_sum, z_sum, jnp.sum(mask.astype(jnp.float32))


def lm_loss(params, cfg: ModelConfig, batch, *, remat=None,
            loss_chunk: int = 1024):
    """Next-token cross-entropy (+ z-loss + MoE aux).  batch["tokens"] (B,S).

    Uses the chunked head (no full-seq logits) — required at the 4k x 256
    train cells where (B, S, V) fp32 would not fit."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = default_positions(B, S)
    mrope_positions = batch.get("mrope_positions")
    if cfg.rope == "mrope" and mrope_positions is None:
        mrope_positions = default_mrope_positions(B, S)
    rope_fn = make_rope_fn(cfg, positions, mrope_positions)
    x = _embed(params, cfg, tokens, batch.get("vision_feats"))
    x, _, aux = dec.stack_forward(params["layers"], cfg, x, rope_fn,
                                  causal=True, remat=remat)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = (jnp.arange(S) < S - 1)[None, :] * jnp.ones((B, 1), jnp.int32)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"]
    nll_sum, z_sum, n = head_loss_chunked(params, cfg, x, labels, mask,
                                          chunk=loss_chunk)
    nll = nll_sum / jnp.maximum(n, 1.0)
    z = z_sum / jnp.maximum(n, 1.0)
    loss = nll + Z_LOSS * z + AUX_LOSS * aux
    return loss, {"nll": nll, "z_loss": z, "aux_loss": aux}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def lm_prefill(params, cfg: ModelConfig, tokens, max_len: int, *,
               vision_feats=None, mrope_positions=None):
    """Run the prompt, build caches padded to ``max_len``.

    Returns (last_token_logits (B, V), cache)."""
    B, S = tokens.shape
    positions = default_positions(B, S)
    if cfg.rope == "mrope" and mrope_positions is None:
        mrope_positions = default_mrope_positions(B, S)
    rope_fn = make_rope_fn(cfg, positions, mrope_positions)
    x = _embed(params, cfg, tokens, vision_feats)
    x, caches, _ = dec.stack_forward(params["layers"], cfg, x, rope_fn,
                                     causal=True, want_cache=True,
                                     decode_len=max_len, remat=False)
    logits = _head(params, cfg, x[:, -1:])
    return logits[:, 0], {"layers": caches,
                          "index": jnp.asarray(S, jnp.int32)}


def lm_decode_step(params, cfg: ModelConfig, tokens, cache):
    """One decode step.  tokens (B,1) -> (logits (B,V), new cache).

    cache["index"] may be a scalar (lockstep decode, dry-run cells) or a
    (B,) vector of per-slot lengths (continuous batching)."""
    B = tokens.shape[0]
    index = jnp.asarray(cache["index"])
    if index.ndim == 0:
        positions = jnp.broadcast_to(index[None, None],
                                     (B, 1)).astype(jnp.int32)
    else:
        positions = index[:, None].astype(jnp.int32)
    mrope = jnp.stack([positions] * 3) if cfg.rope == "mrope" else None
    rope_fn = make_rope_fn(cfg, positions, mrope)
    x = _embed(params, cfg, tokens)
    x, new_caches = dec.stack_decode(params["layers"], cfg, x,
                                     cache["layers"], index, rope_fn)
    logits = _head(params, cfg, x)
    return logits[:, 0], {"layers": new_caches, "index": index + 1}


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      start_index: Optional[int] = None):
    """Cache for a decode-only entry (dry-run decode cells: a full cache of
    ``max_len`` tokens already exists; the step appends one)."""
    idx = max_len - 1 if start_index is None else start_index
    return {"layers": dec.init_cache(cfg, batch, max_len),
            "index": jnp.asarray(idx, jnp.int32)}


# ---------------------------------------------------------------------------
# analytic parameter count (MODEL_FLOPS = 6 N D)
# ---------------------------------------------------------------------------

def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    D, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    total = 0
    for pos in range(dec.group_size(cfg)):
        mixer, ffn = dec.sublayer_spec(cfg, pos)
        if mixer == "attn":
            total += D * hd * (H + 2 * KV) + H * hd * D
        else:
            s = cfg.ssm
            d_inner = s.expand * D
            ch = d_inner + 2 * s.n_groups * s.d_state
            Hm = d_inner // s.head_dim
            total += (D * (2 * d_inner + 2 * s.n_groups * s.d_state + Hm)
                      + s.d_conv * ch + ch + 3 * Hm + d_inner + d_inner * D)
        if ffn == "mlp":
            n_mats = 3 if cfg.act in ("swiglu", "geglu") else 2
            total += n_mats * D * cfg.d_ff
        elif ffn == "moe":
            m = cfg.moe
            E = m.top_k if active_only else m.n_experts
            total += D * m.n_experts  # router (always dense)
            total += E * 3 * D * m.d_ff_expert
            if m.n_shared:
                total += 3 * D * (m.d_ff_shared or m.d_ff_expert * m.n_shared)
        total += 2 * D  # norms
    total *= dec.n_groups(cfg)
    total += cfg.padded_vocab * D * (1 if cfg.tie_embeddings else 2)
    if cfg.vlm:
        total += cfg.vision_feat_dim * D + D * D
    if cfg.encdec:
        enc_layer = (D * hd * (H + 2 * KV) + H * hd * D
                     + 2 * D * cfg.d_ff + 2 * D)
        cross = D * hd * (H + 2 * KV) + H * hd * D + D
        total += cfg.n_enc_layers * enc_layer + cfg.n_layers * cross
    return int(total)
