"""Streaming linear attention — the paper's sub-quadratic attention (§3.2 GPU).

NANOMIND replaces quadratic attention with a kernelized, streaming variant that
"maintains running summaries of past keys and values, updating them as new
tokens arrive and computing outputs via a single matrix-vector pass".  That is
exactly causal linear attention (Katharopoulos et al.) with feature map
phi(x) = elu(x)+1:

    S_t = S_{t-1} + phi(k_t) v_t^T          (d x d running summary)
    z_t = z_{t-1} + phi(k_t)                (d   running normalizer)
    o_t = (phi(q_t)^T S_t) / (phi(q_t)^T z_t)

Prefill uses the chunked parallel form (intra-chunk quadratic, inter-chunk
state passing) so the MXU sees dense matmuls; decode is the paper's single
matvec against the running state.  The Pallas kernel lives in
``repro.kernels.linear_attention``; this module is its jnp implementation and
the `attn_impl="linear"` drop-in used for the beyond-paper long_500k runs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def feature_map(x):
    return jax.nn.elu(x.astype(jnp.float32)) + 1.0


def linear_attn_prefill(q, k, v, *, chunk: int = 256
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Causal linear attention over a full sequence (chunked state-passing).

    q,k (B,S,H,hd), v (B,S,H,hd) — GQA callers expand kv heads first.
    Returns (out, state (B,H,hd,hd), normalizer (B,H,hd))."""
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    qf = feature_map(q).reshape(B, n, chunk, H, hd)
    kf = feature_map(k).reshape(B, n, chunk, H, hd)
    vc = v.reshape(B, n, chunk, H, hd).astype(jnp.float32)

    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def step(carry, xs):
        state, z = carry                       # (B,H,hd,hd), (B,H,hd)
        qi, ki, vi = xs                        # (B,chunk,H,hd)
        # inter-chunk: contribution of the running state
        o_inter = jnp.einsum("bchk,bhkd->bchd", qi, state)
        z_inter = jnp.einsum("bchk,bhk->bch", qi, z)
        # intra-chunk: causal quadratic within the chunk
        s = jnp.einsum("bchk,bdhk->bhcd", qi, ki) * mask[None, None]
        o_intra = jnp.einsum("bhcd,bdhk->bchk", s, vi)
        z_intra = jnp.einsum("bhcd->bhc", s).transpose(0, 2, 1)  # (B,chunk,H)
        o = o_inter + o_intra
        zt = z_inter + z_intra
        # state update
        state = state + jnp.einsum("bchk,bchd->bhkd", ki, vi)
        z = z + kf_sum(ki)
        return (state, z), (o, zt)

    def kf_sum(ki):
        return jnp.einsum("bchk->bhk", ki)

    state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    z0 = jnp.zeros((B, H, hd), jnp.float32)
    (state, z), (o, zt) = jax.lax.scan(
        step, (state0, z0),
        (jnp.moveaxis(qf, 1, 0), jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vc, 1, 0)))
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, H, hd)
    zt = jnp.moveaxis(zt, 0, 1).reshape(B, S, H)
    out = o / jnp.maximum(zt, 1e-6)[..., None]
    return out.astype(q.dtype), state, z


def linear_attn_decode(q, k_new, v_new, state, z
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode: single matvec against the running summary.

    q,k_new,v_new (B,1,H,hd); state (B,H,hd,hd); z (B,H,hd)."""
    qf = feature_map(q[:, 0])                  # (B,H,hd)
    kf = feature_map(k_new[:, 0])
    vf = v_new[:, 0].astype(jnp.float32)
    state = state + jnp.einsum("bhk,bhd->bhkd", kf, vf)
    z = z + kf
    o = jnp.einsum("bhk,bhkd->bhd", qf, state)
    denom = jnp.maximum(jnp.einsum("bhk,bhk->bh", qf, z), 1e-6)
    out = (o / denom[..., None]).astype(q.dtype)[:, None]
    return out, state, z
