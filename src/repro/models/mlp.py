"""Dense FFN variants: SwiGLU / GeGLU (gated) and squared-ReLU / GELU (plain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init

GATED = {"swiglu": "silu", "geglu": "gelu"}


def init_mlp(key, cfg, d_model: int, d_ff: int):
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dt, fan_in=d_model),
         "w_down": dense_init(ks[1], (d_ff, d_model), dt, fan_in=d_ff)}
    if cfg.act in GATED:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dt, fan_in=d_model)
    return p


def apply_mlp(p, cfg, x):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = activation(GATED[cfg.act])(gate) * up
    else:
        h = activation(cfg.act)(up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
