"""Logical-axis sharding rules: DP / FSDP / TP / EP / SP on a (pod, data,
model) mesh.

The paper's scheduling problem — *which module runs where* — becomes, at pod
scale, *which tensor dimension lives on which mesh axis*.  This module is the
single place where that decision is made:

* **DP**     batch dims             -> ("pod", "data")
* **FSDP**   weight "width" dims    -> "data"  (ZeRO-3 gather-on-use)
* **TP**     head / ffn / expert / vocab dims -> "model"
* **EP**     MoE expert dim         -> "model" (dispatch lowers to all-to-all)
* **SP**     decode-cache sequence  -> "model" (+ spare "data" when batch is
             too small) — FlashDecoding-across-chips; softmax stats reduce
             over the sharded axis with tiny payloads.

Every rule is *divisibility-checked against the actual mesh*: an axis that
does not evenly divide the dim is dropped (falls back to the next candidate
or replication), so the same rule table serves all ten assigned archs — e.g.
qwen2-vl's 28 heads reject the 16-way "model" axis and fall back to sharding
head_dim.

All functions return ``PartitionSpec`` pytrees; :func:`tree_shardings` binds
them to a mesh as ``NamedSharding``.  Nothing here allocates.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, np.shape(mesh.devices)))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Pure-data-parallel axes (batch)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


FSDP_AXIS = "data"
TP_AXIS = "model"

# ---------------------------------------------------------------------------
# sharding modes (the §Perf levers; see EXPERIMENTS.md)
#
#   "tp"    (default) Megatron TP+SP: weights FSDP x TP, activations
#           sequence-parallel between blocks, head-parallel inside attention.
#   "fsdp"  pure ZeRO-3: BOTH mesh axes act as data-parallel for
#           activations; weights stay 2D-sharded and are gathered on use.
#           No per-layer activation collectives at all — comm = weight
#           all-gathers (batch-size independent) + gradient reduce-scatter.
#   "serve" decode-optimized: weights replicated over "data" (no per-step
#           FSDP regather), TP over "model"; caches sequence-sharded.
# ---------------------------------------------------------------------------

_MODE = "tp"


def set_mode(mode: str):
    global _MODE
    assert mode in ("tp", "fsdp", "serve"), mode
    _MODE = mode


def get_mode() -> str:
    return _MODE


class _Ruler:
    """Divisibility-checked PartitionSpec builder for one mesh."""

    def __init__(self, mesh: Mesh):
        self.sizes = axis_sizes(mesh)
        self.dp = dp_axes(mesh)

    def _fits(self, dim: int, axes) -> bool:
        if axes is None:
            return True
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        total = int(np.prod([self.sizes[a] for a in axes]))
        return dim % total == 0

    def spec(self, shape: Sequence[int], *dim_axes) -> P:
        """Build a PartitionSpec, dropping axes that don't divide.

        ``dim_axes`` is per-dimension: None | axis | tuple | list of
        candidates tried in order (first that divides wins).
        """
        out = []
        for size, cand in zip(shape, dim_axes):
            if cand is None:
                out.append(None)
                continue
            cands = cand if isinstance(cand, list) else [cand]
            chosen = None
            for c in cands:
                if c is not None and self._fits(size, c):
                    chosen = c
                    break
            out.append(chosen)
        # PartitionSpec must not repeat a mesh axis
        seen: set = set()
        clean = []
        for c in out:
            names = (c,) if isinstance(c, str) else tuple(c or ())
            if any(n in seen for n in names):
                clean.append(None)
            else:
                seen.update(names)
                clean.append(c)
        return P(*clean)


def _leaf_name(path) -> str:
    # skip index-style entries (tuple positions, QTensor's
    # FlattenedIndexKey children) and the codes/scales suffix: a packed
    # weight follows its parent weight's layout (packing is along the
    # LAST axis, which every rule leaves unsharded or divisible).
    names = [str(p.key) for p in path
             if hasattr(p, "key") and not isinstance(p.key, int)]
    for n in reversed(names):
        if n not in ("codes", "scales"):
            return n
    return names[-1] if names else ""


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _param_rule(r: _Ruler, path, shape) -> P:
    """FSDP x TP rule table, keyed on leaf name; specs are for the TRAILING
    dims so stacked (scan-leading) and flat leaves share one table.

    In "serve" mode the FSDP axis is dropped (weights replicated over
    "data"): a decode step would otherwise re-gather every layer's weights
    every token — the dominant decode collective in the baseline."""
    name = _leaf_name(path)
    nd = len(shape)
    fsdp = None if _MODE == "serve" else FSDP_AXIS

    def trail(*axes):
        axes = tuple(fsdp if a == FSDP_AXIS else a for a in axes)
        pad = (None,) * (nd - len(axes))
        return r.spec(shape, *(pad + axes))

    if name == "embed":                       # (V, D): vocab-parallel table
        return trail(TP_AXIS, FSDP_AXIS)
    if name == "lm_head":                     # (D, V): output-parallel head
        return trail(FSDP_AXIS, TP_AXIS)
    # NOTE: no head_dim fallback — hd-sharded K/Q makes the RoPE half-split
    # reshard catastrophically ("involuntary full rematerialization").
    # Indivisible head counts (qwen2-vl's 28H, GQA kv=8 on a 16-way axis)
    # replicate over "model" and keep FSDP on d_model.
    if name in ("wq", "wk", "wv"):            # (D, H, hd)
        return trail(FSDP_AXIS, TP_AXIS, None)
    if name == "wo":                          # (H, hd, D)
        return trail(TP_AXIS, None, FSDP_AXIS)
    if name in ("bq", "bk", "bv"):            # (H, hd)
        return trail(TP_AXIS, None)
    if name in ("w_up", "w_gate"):
        if nd >= 4:                           # MoE: (E, D, F) trailing
            return trail(TP_AXIS, FSDP_AXIS, None)
        return trail(FSDP_AXIS, TP_AXIS)      # (D, F)
    if name == "w_down":
        if nd >= 4:                           # MoE: (E, F, D)
            return trail(TP_AXIS, None, FSDP_AXIS)
        return trail(TP_AXIS, FSDP_AXIS)      # (F, D)
    if name == "router":                      # (D, E): replicated-ish
        return trail(FSDP_AXIS, None)
    if name == "in_proj":                     # (D, P)
        return trail(FSDP_AXIS, TP_AXIS)
    if name == "out_proj":                    # (P, D)
        return trail(TP_AXIS, FSDP_AXIS)
    if name == "conv_w":                      # (K, C)
        return trail(None, TP_AXIS)
    if name in ("conv_b", "norm_scale"):      # (C,)
        return trail(TP_AXIS)
    if name in ("A_log", "D", "dt_bias"):     # (H,)
        return trail([TP_AXIS])
    if name == "w1":                          # vis_proj (F, D)
        return trail(None, TP_AXIS)
    if name == "w2":                          # vis_proj (D, D)
        return trail(FSDP_AXIS, TP_AXIS)
    # norms / biases / anything small: replicated
    return P()


def tree_param_specs(mesh: Mesh, params_shapes) -> Any:
    """PartitionSpec pytree for a param (or grad / adam-state) pytree of
    arrays or ShapeDtypeStructs."""
    r = _Ruler(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(r, path, leaf.shape), params_shapes)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def _dp_candidates(mesh: Mesh):
    """Batch-dim sharding candidates, mode-aware.  In "fsdp" mode the
    "model" axis is data-parallel too (pure ZeRO-3)."""
    dp = list(dp_axes(mesh))
    if _MODE == "fsdp":
        full = tuple(dp + [TP_AXIS])
        return [full, tuple(dp), dp[-1] if dp else None]
    return [tuple(dp), dp[-1] if dp else None]


def batch_spec(mesh: Mesh, name: str, shape) -> P:
    """Inputs: tokens (B,S), vision_feats (B,N,F), src_embeds (B,T,D)..."""
    r = _Ruler(mesh)
    rest = (None,) * (len(shape) - 1)
    return r.spec(shape, _dp_candidates(mesh), *rest)


def tree_batch_specs(mesh: Mesh, batch_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: batch_spec(mesh, _path_str(path), leaf.shape),
        batch_shapes)


def _cache_rule(r: _Ruler, path, shape) -> P:
    """Decode caches.  Trailing-dim patterns:

    * attn KV cache   (..., B, S, KV, hd): B->dp, S->model (+ spare dp when
      B indivisible) — sequence-parallel FlashDecoding layout.
    * linear-attn     (..., B, H, hd, hd) / (..., B, H, hd): B->dp, H->model.
    * mamba conv      (..., B, K, C): B->dp, C->model.
    * mamba state     (..., B, H, P, N): B->dp, H->model.
    """
    nd = len(shape)
    if nd == 0:
        return P()
    path_s = _path_str(path)
    dp = r.dp
    dp_total = int(np.prod([r.sizes[a] for a in dp])) if dp else 1

    def trail(*axes):
        pad = (None,) * (nd - len(axes))
        return r.spec(shape, *(pad + axes))

    if re.search(r"conv", path_s) and nd >= 3:
        return trail(tuple(dp), None, TP_AXIS)
    if nd >= 4 and shape[-1] == shape[-2]:    # linear-attn state (B,H,hd,hd)
        return trail(tuple(dp), TP_AXIS, None, None)
    if nd >= 4:
        # (B, S, KV, hd) attn cache or (B, H, P, N) ssm state: disambiguate
        # by the "seq" dim being the big one.
        b, s = shape[-4], shape[-3]
        if s >= 1024:                          # attn cache
            if b % dp_total == 0 and dp:
                return trail(tuple(dp), TP_AXIS, None, None)
            # small batch: spend leftover dp on the sequence axis too
            seq_axes = [tuple(list(dp) + [TP_AXIS]), TP_AXIS]
            return trail([tuple(dp)], seq_axes, None, None)
        return trail(tuple(dp), TP_AXIS, None, None)  # ssm state: H->model
    if nd >= 2:
        return trail(tuple(dp), [TP_AXIS])
    return P()


def tree_cache_specs(mesh: Mesh, cache_shapes) -> Any:
    r = _Ruler(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_rule(r, path, leaf.shape), cache_shapes)


# ---------------------------------------------------------------------------
# binding
# ---------------------------------------------------------------------------

def tree_shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def with_specs(shapes_tree, spec_tree, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        shapes_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


def constrain(x, spec: P):
    """Sharding constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# ---------------------------------------------------------------------------
# in-model activation constraints (sequence parallelism)
# ---------------------------------------------------------------------------

def current_mesh() -> Optional[Mesh]:
    """The mesh from an enclosing ``with mesh:`` block, or None."""
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is None or m.empty or m.devices.size <= 1:
            return None
        return m
    except Exception:
        return None


def constrain_residual(x):
    """Residual stream (B, S, D): batch over DP axes, sequence over "model"
    (Megatron-style sequence parallelism).  This is what bounds the scan
    carry saved per layer for the backward — without it the 95-layer x
    (B,S,D) activations are only batch-sharded and overflow HBM.  No-op
    outside a mesh / when dims don't divide (e.g. decode's S=1)."""
    mesh = current_mesh()
    if mesh is None or x.ndim != 3:
        return x
    r = _Ruler(mesh)
    if _MODE == "fsdp":   # pure DP: batch over every axis, no seq sharding
        spec = r.spec(x.shape, _dp_candidates(mesh), None, None)
    else:
        spec = r.spec(x.shape, [tuple(r.dp)], TP_AXIS, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_heads(x):
    """Attention-interior activations (B, S, H, hd): heads over "model",
    sequence REPLICATED.  Critical: if the sequence sharding is allowed to
    leak into the chunked-attention loop, the partitioner emits per-chunk
    gathers *inside* the scan (3040x multiplicity on a 95L model).  The
    Megatron-SP pattern — all-gather S at attention entry, reduce-scatter at
    exit — falls out of this constraint + constrain_residual."""
    mesh = current_mesh()
    if mesh is None or x.ndim != 4:
        return x
    r = _Ruler(mesh)
    if _MODE == "fsdp":
        spec = r.spec(x.shape, _dp_candidates(mesh), None, None, None)
    else:
        spec = r.spec(x.shape, [tuple(r.dp)], None, TP_AXIS, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def rs_gradients(tree):
    """Identity forward; in the BACKWARD the cotangents are constrained to
    the parameter sharding — GSPMD then emits per-layer reduce-scatters for
    weight gradients instead of the all-reduce(+local slice) it otherwise
    chooses inside scan bodies (2x wire bytes).  §Perf train iteration."""
    mesh = current_mesh()
    if mesh is None:
        return tree

    leaves, treedef = jax.tree_util.tree_flatten(tree)

    @jax.custom_vjp
    def ident(*ls):
        return ls

    def fwd(*ls):
        return ls, None

    def bwd(_, gs):
        r = _Ruler(mesh)
        flat = jax.tree_util.tree_flatten_with_path(
            treedef.unflatten(list(gs)))[0]
        out = []
        for (path, g) in flat:
            try:
                spec = _param_rule(r, path, g.shape)
                out.append(jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, spec)))
            except Exception:
                out.append(g)
        return tuple(out)

    ident.defvjp(fwd, bwd)
    return treedef.unflatten(list(ident(*leaves)))


def constrain_batch_only(x):
    """(B, ...): batch over DP axes, everything else replicated."""
    mesh = current_mesh()
    if mesh is None:
        return x
    r = _Ruler(mesh)
    spec = r.spec(x.shape, _dp_candidates(mesh), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
