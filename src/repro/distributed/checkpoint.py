"""Topology-independent checkpointing (no orbax in the container — built
from numpy + a json manifest).

Design for 1000+ nodes:

* **Logical layout**: each pytree leaf is stored under its tree path with
  shape/dtype metadata — nothing about the mesh is persisted, so a restore
  may bind ANY mesh/sharding (elastic re-mesh after node failure just
  restores onto the survivor mesh; fault_tolerance.py drives this).
* **Atomicity**: writes go to ``step_XXXX.tmp`` then os.rename — a crashed
  writer never corrupts the latest pointer.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread — the train loop overlaps I/O with the
  next steps, the standard trick for minimizing checkpoint stalls.
* **GC**: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    items, _ = _flatten(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {},
                "time": time.time()}
    for i, (key, leaf) in enumerate(items):
        arr = np.asarray(leaf)
        logical = str(arr.dtype)
        if arr.dtype.kind not in "fiub":      # bf16 etc: store fp32 (lossless)
            arr = arr.astype(np.float32)
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({"key": key, "file": fn,
                                   "shape": list(arr.shape),
                                   "dtype": logical})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Snapshot-to-host now, write-to-disk in the background."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def save_async(self, step: int, tree, extra=None):
        self.wait()                                   # one in flight
        host_tree = jax.tree.map(np.asarray, tree)    # device -> host now

        def _run():
            try:
                save(self.ckpt_dir, step, host_tree, keep=self.keep,
                     extra=extra)
            except Exception as e:                    # surfaced on wait()
                self.last_error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the NEW mesh — this is the reshard-on-load that makes
    checkpoints elastic across topologies."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    items, treedef = _flatten(like)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    leaves = []
    shard_items = (_flatten(shardings)[0] if shardings is not None
                   else [(k, None) for k, _ in items])
    for (key, leaf), (_, shard) in zip(items, shard_items):
        meta = by_key[key]
        arr = np.load(os.path.join(path, meta["file"]))
        want_dtype = getattr(leaf, "dtype", meta["dtype"])
        out = jax.numpy.asarray(arr).astype(want_dtype)
        leaves.append(jax.device_put(out, shard) if shard is not None
                      else out)
    return treedef.unflatten(leaves), step, manifest.get("extra", {})


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
