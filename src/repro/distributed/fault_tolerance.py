"""Fault tolerance at pod scale: heartbeats, elastic re-mesh, stragglers.

The container has one process, so the *distributed control plane* is built
as a simulation-faithful library: the same classes drive (a) the unit tests
(simulated clocks/failures), and (b) a real deployment, where the heartbeat
source is `jax.distributed` worker liveness instead of the injected clock.

Recovery contract (what the 1000-node design needs):
  1. ``HeartbeatMonitor`` detects dead workers (missed-beat threshold).
  2. ``plan_remesh`` picks the largest (data, model) grid that fits the
     survivors while preserving the model-axis size (TP degree is a model
     property; DP shrinks).  Elastic scaling both directions: workers coming
     back -> larger DP.
  3. Checkpoints are topology-independent (distributed/checkpoint.py), so
     restart = restore(ckpt, shardings(new_mesh)) + ShardedLoader.seek(step)
     — replay-deterministic data (data/pipeline.py).
  4. ``StragglerMitigator`` tracks per-worker step times; persistent
     stragglers (p50 > multiplier x fleet median) are evicted exactly like
     failures (the re-mesh path), the standard large-run mitigation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class HeartbeatMonitor:
    n_workers: int
    timeout_s: float = 30.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self.last_beat: Dict[int, float] = {w: now
                                            for w in range(self.n_workers)}
        self.evicted: set = set()

    def beat(self, worker: int):
        if worker not in self.evicted:
            self.last_beat[worker] = self.clock()

    def dead_workers(self) -> List[int]:
        now = self.clock()
        return sorted(w for w, t in self.last_beat.items()
                      if w not in self.evicted and now - t > self.timeout_s)

    def evict(self, worker: int):
        self.evicted.add(worker)

    def alive(self) -> List[int]:
        return sorted(set(self.last_beat) - self.evicted)


@dataclass(frozen=True)
class RemeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    workers: Tuple[int, ...]
    dropped: Tuple[int, ...]

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


def plan_remesh(alive_workers: List[int], devices_per_worker: int,
                model_axis: int = 16, pod_axis: Optional[int] = None
                ) -> RemeshPlan:
    """Largest (data, model) mesh over the survivors.

    The model axis is preserved (sharded weights need their TP degree);
    the data axis absorbs the loss — standard elastic-DP.  Workers whose
    devices don't fill a data row are left warm as spares."""
    n_dev = len(alive_workers) * devices_per_worker
    data = n_dev // model_axis
    if data < 1:
        raise RuntimeError(
            f"{n_dev} devices cannot host model axis {model_axis}")
    used_workers = (data * model_axis) // devices_per_worker
    workers = tuple(alive_workers[:used_workers])
    dropped = tuple(alive_workers[used_workers:])
    if pod_axis and data % pod_axis == 0 and data > pod_axis:
        return RemeshPlan((pod_axis, data // pod_axis, model_axis),
                          ("pod", "data", "model"), workers, dropped)
    return RemeshPlan((data, model_axis), ("data", "model"),
                      workers, dropped)


@dataclass
class StragglerMitigator:
    """Per-worker step-time tracker with eviction policy."""

    n_workers: int
    window: int = 32
    multiplier: float = 2.0
    min_samples: int = 8

    def __post_init__(self):
        self.times: Dict[int, List[float]] = {w: []
                                              for w in range(self.n_workers)}

    def record(self, worker: int, step_time_s: float):
        buf = self.times.setdefault(worker, [])
        buf.append(step_time_s)
        del buf[:-self.window]

    def fleet_median(self) -> float:
        all_t = [t for buf in self.times.values() for t in buf]
        return float(np.median(all_t)) if all_t else 0.0

    def stragglers(self) -> List[int]:
        med = self.fleet_median()
        if med == 0.0:
            return []
        out = []
        for w, buf in self.times.items():
            if len(buf) >= self.min_samples \
                    and float(np.median(buf)) > self.multiplier * med:
                out.append(w)
        return sorted(out)

    def step_deadline(self) -> float:
        """Per-step deadline: fleet median x multiplier (the synchronous-
        step timeout after which the monitor treats a worker as failed)."""
        med = self.fleet_median()
        return med * self.multiplier if med else float("inf")


@dataclass
class RecoveryLog:
    """Audit trail of failures/re-meshes (exposed by the train loop)."""
    events: List[dict] = field(default_factory=list)

    def record(self, kind: str, **kw):
        self.events.append({"kind": kind, "t": time.time(), **kw})
