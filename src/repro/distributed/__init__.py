"""Distribution substrate: sharding rules, checkpointing, fault tolerance,
gradient compression.  Mesh construction lives in :mod:`repro.launch.mesh`."""
