"""Gradient compression for the data-parallel axis: int8 block-quantized
all-reduce with error feedback.

At 1000+ nodes the DP gradient reduce-scatter is DCN/ICI-bound; 8-bit
block-quantized reduction cuts it 4x vs fp32 (2x vs bf16).  The scheme:

    q = round(g / s),  s = max|g|_block / 127        (per 256-value block)
    psum in int32 (no overflow below ~2^23 workers), rescale by s_psum

Error feedback keeps the residual (g - dequant(q)) and adds it to the next
step's gradient — the standard trick that restores convergence to near-
uncompressed quality.

Two integration points:
* ``compress / decompress`` — building blocks (tested exhaustively);
* ``psum_compressed`` — drop-in for explicit shard_map DP training steps
  (see training/train_loop.py ``ddp_train_step``); under pjit the implicit
  reduction cannot be intercepted, which is WHY the explicit-DP variant
  exists.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(-1, BLOCK), n


def compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g -> (int8 codes (nb, BLOCK), fp32 scales (nb, 1))."""
    blocks, _ = _pad_to_block(g.astype(jnp.float32))
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype
               ) -> jnp.ndarray:
    n = 1
    for d in shape:
        n *= d
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def psum_compressed(tree, axis_name: str):
    """Quantized-gradient psum over a shard_map/pmap axis.

    Each worker quantizes to int8 blocks locally, then the *quantized*
    values are reduced: result = sum_w dequant(q_w) — carrying exactly the
    int8 compression error a real low-bit reduction would.  (In this
    emulation the reduction runs in fp32 on the wire; a production backend
    implements it as int8 all-gather + local int32 sum, or ring segments
    re-quantized per hop — the *numerics* modeled here are the standard
    'quantize-then-reduce' scheme whose convergence error feedback fixes.)"""
    def one(g):
        q, s = compress(g)
        qs = q.astype(jnp.float32) * s                 # dequantized blocks
        total = jax.lax.psum(qs, axis_name)
        n = g.size
        return total.reshape(-1)[:n].reshape(g.shape).astype(g.dtype)
    return jax.tree.map(one, tree)


@dataclass
class ErrorFeedback:
    """Residual memory: g_eff = g + residual; residual = g_eff - dq(q)."""

    residual: Any = None

    def apply(self, grads):
        if self.residual is None:
            self.residual = jax.tree.map(
                lambda g: jnp.zeros_like(g, jnp.float32), grads)
        g_eff = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, self.residual)

        def split(g):
            q, s = compress(g)
            dq = decompress(q, s, g.shape, jnp.float32)
            return dq, g - dq

        pairs = jax.tree.map(split, g_eff)
        leaves, treedef = jax.tree_util.tree_flatten(
            pairs, is_leaf=lambda x: isinstance(x, tuple)
            and len(x) == 2 and not isinstance(x[0], tuple))
        dqs = treedef.unflatten([p[0] for p in leaves])
        self.residual = treedef.unflatten([p[1] for p in leaves])
        return dqs
