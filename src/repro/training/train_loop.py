"""Training driver: pjit step + async checkpointing + fault tolerance.

``fit()`` is the single-process entry the example/train launcher uses; it is
written against the same abstractions a multi-host deployment binds to
(jax.distributed for heartbeats, per-host ShardedLoader, topology-free
checkpoints), with the control-plane pieces injectable so the fault paths
are testable in-container.

Features per the 1000-node brief:
* gradient accumulation (scan over microbatches) — fits big global batches;
* async checkpoint every N steps, atomic, keep-k, restart from latest;
* heartbeat monitor + straggler tracker hooks; on failure: plan_remesh ->
  rebuild mesh/shardings -> restore -> ShardedLoader.seek (elastic restart);
* optional int8-compressed explicit-DP step (distributed/compression.py).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import checkpoint as ckpt_lib
from repro.distributed import sharding as sh
from repro.distributed.fault_tolerance import (HeartbeatMonitor, RecoveryLog,
                                               StragglerMitigator)
from repro.launch import steps as st
from repro.training.optimizer import OptConfig, adamw_update, init_opt


def build_accum_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                           grad_accum: int = 1):
    """train_step with microbatch accumulation: batch dims (A*B, ...) are
    split into A sequential microbatches; grads are averaged in fp32."""
    from repro.models import encdec as ED
    from repro.models import model as M
    loss_fn = ED.encdec_loss if cfg.encdec else M.lm_loss

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, parts), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, _), g = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, mb), has_aux=True)(params)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / grad_accum,
                    acc, g)
                return (acc, loss_acc + loss / grad_accum), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda p, g: g.astype(p.dtype),
                                 params, grads)
            parts = {}
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    grad_accum: int = 1
    log_every: int = 10


@dataclass
class TrainResult:
    final_step: int
    metrics_history: list
    recovery: RecoveryLog


def fit(cfg: ModelConfig, opt_cfg: OptConfig, tcfg: TrainConfig,
        data_iter: Iterator[Dict[str, np.ndarray]], mesh=None,
        params=None, log: Callable[[str], None] = print) -> TrainResult:
    """Single-controller training loop (CPU-runnable at reduced configs;
    the pjit path is identical on a pod)."""
    recovery = RecoveryLog()
    straggler = StragglerMitigator(n_workers=1)

    if params is None:
        params = st.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = init_opt(params, opt_cfg)
    start_step = 0

    checkpointer = None
    if tcfg.ckpt_dir:
        checkpointer = ckpt_lib.AsyncCheckpointer(tcfg.ckpt_dir, tcfg.keep)
        last = ckpt_lib.latest_step(tcfg.ckpt_dir)
        if last is not None:
            state, start_step, _ = ckpt_lib.restore(
                tcfg.ckpt_dir, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            recovery.record("restore", step=start_step)
            log(f"[fit] restored step {start_step} from {tcfg.ckpt_dir}")

    step_fn = build_accum_train_step(cfg, opt_cfg, tcfg.grad_accum)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    history = []
    ctx = mesh if mesh is not None else _NullCtx()
    with ctx:
        for step in range(start_step, tcfg.steps):
            batch = jax.tree.map(jnp.asarray, next(data_iter))
            t0 = time.time()
            params, opt_state, metrics = jitted(params, opt_state, batch)
            metrics = jax.tree.map(float, metrics)
            dt = time.time() - t0
            straggler.record(0, dt)
            history.append({"step": step + 1, "dt": dt, **metrics})
            if (step + 1) % tcfg.log_every == 0:
                log(f"[fit] step {step+1} loss={metrics['loss']:.4f} "
                    f"gnorm={metrics.get('grad_norm', 0):.3f} dt={dt:.2f}s")
            if checkpointer and (step + 1) % tcfg.ckpt_every == 0:
                checkpointer.save_async(
                    step + 1, {"params": params, "opt": opt_state})
                recovery.record("checkpoint", step=step + 1)
    if checkpointer:
        checkpointer.wait()
    return TrainResult(tcfg.steps, history, recovery)


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


# ---------------------------------------------------------------------------
# explicit-DP variant with compressed gradient reduction
# ---------------------------------------------------------------------------

def build_ddp_train_step(cfg: ModelConfig, opt_cfg: OptConfig, mesh,
                         compress: bool = True):
    """shard_map data-parallel step: params replicated, batch sharded on
    "data"; the gradient psum goes through the int8 scheme when
    ``compress`` (the pjit path can't intercept its implicit reduction)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compression import psum_compressed
    from repro.models import model as M

    def local_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: M.lm_loss(p, cfg, batch), has_aux=True)(params)
        if compress:
            grads = psum_compressed(grads, "data")
            grads = jax.tree.map(
                lambda g: g / mesh.devices.shape[0], grads)
        else:
            grads = jax.lax.pmean(grads, "data")
        loss = jax.lax.pmean(loss, "data")
        params, opt_state, om = adamw_update(grads=grads, params=params,
                                             state=opt_state, cfg=opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    return shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P(), P("data")),
        out_specs=(P(), P(), P()),
        check_rep=False)
