"""Training substrate: optimizer, LR schedules, train step/loop."""
