"""AdamW from scratch (no optax in the container), pod-scale features:

* **dtype-configurable moments** — fp32 (default), bf16, or int8 blockwise
  (quantized with :mod:`repro.core.quantize` machinery).  bf16/int8 states are
  what lets jamba-398B train on a single 256-chip pod (DESIGN.md §6).
* global-norm clipping, decoupled weight decay, cosine/linear schedules.
* states inherit the *param sharding* (elementwise update ⇒ zero extra
  collectives beyond the gradient reduce-scatter GSPMD already emits).

Pytree layout: ``{"m": tree, "v": tree, "step": int32 scalar}``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"       # float32 | bfloat16
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"           # cosine | linear | constant
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(1.0, cfg.warmup_steps), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0, 1)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * (1 - t)
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_opt(params, cfg: OptConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    sq = [jnp.sum(jnp.square(g.astype(jnp.float32)))
          for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))


_DECAY_EXEMPT = ("norm", "scale", "bias", "A_log", "dt_bias", "/D")


def _decay_mask(path) -> bool:
    s = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
    return not any(t in s for t in _DECAY_EXEMPT)


def adamw_update(params, grads, state, cfg: OptConfig,
                 lr_override: Optional[jnp.ndarray] = None):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step) if lr_override is None else lr_override
    b1, b2 = cfg.betas

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    p_flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_flat = jax.tree.leaves(grads)
    m_flat = jax.tree.leaves(state["m"])
    v_flat = jax.tree.leaves(state["v"])
    outs = [upd(path, p, g, m, v)
            for (path, p), g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
    new_params = treedef.unflatten([o[0] for o in outs])
    new_state = {"m": treedef.unflatten([o[1] for o in outs]),
                 "v": treedef.unflatten([o[2] for o in outs]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
