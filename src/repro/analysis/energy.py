"""Energy model: joules from roofline terms (the PMU the container lacks).

The paper measures watts with a hardware PMU (§3.3); on a dry-run-only
container we *model* energy the same way the roofline models time:

    E = FLOPs * e_flop + HBM_bytes * e_hbm + ICI_bytes * e_ici + P_idle * t

Per-unit energies are public-estimate constants (order-of-magnitude right
for 7nm-class accelerators); what the benchmarks compare is RELATIVE energy
between execution modes (monolithic vs modular vs cascade), mirroring the
paper's -42.3% claim structure, so constant offsets cancel.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class EnergyProfile:
    name: str
    e_flop: float          # J per FLOP
    e_hbm: float           # J per HBM byte
    e_link: float          # J per interconnect byte
    p_idle: float          # W while powered
    peak_flops: float
    hbm_bw: float
    link_bw: float


# TPU v5e-class chip (brief constants; energy from ~200W/197TFLOPs class)
TPU_V5E = EnergyProfile("tpu-v5e", e_flop=0.8e-12, e_hbm=15e-12,
                        e_link=10e-12, p_idle=60.0,
                        peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)

# The paper's RK3566-class units (order-of-magnitude edge numbers):
EDGE_NPU = EnergyProfile("rk-npu", e_flop=0.5e-12, e_hbm=80e-12,
                         e_link=100e-12, p_idle=0.15,
                         peak_flops=1.0e12, hbm_bw=8e9, link_bw=4e9)
EDGE_GPU = EnergyProfile("rk-gpu", e_flop=2.0e-12, e_hbm=80e-12,
                         e_link=100e-12, p_idle=0.25,
                         peak_flops=0.5e12, hbm_bw=8e9, link_bw=4e9)
EDGE_CPU = EnergyProfile("rk-cpu", e_flop=20e-12, e_hbm=80e-12,
                         e_link=100e-12, p_idle=0.35,
                         peak_flops=0.05e12, hbm_bw=6e9, link_bw=4e9)


def step_energy(profile: EnergyProfile, flops: float, hbm_bytes: float,
                link_bytes: float, wall_s: float = 0.0) -> float:
    """Joules for one step on one unit."""
    return (flops * profile.e_flop + hbm_bytes * profile.e_hbm
            + link_bytes * profile.e_link + profile.p_idle * wall_s)


def step_time(profile: EnergyProfile, flops: float, hbm_bytes: float,
              link_bytes: float = 0.0) -> float:
    """Roofline step time on one unit (max of the three terms)."""
    return max(flops / profile.peak_flops, hbm_bytes / profile.hbm_bw,
               link_bytes / profile.link_bw if profile.link_bw else 0.0)


def watts(profile: EnergyProfile, flops: float, hbm_bytes: float,
          link_bytes: float = 0.0) -> float:
    """Average power of a unit running this workload back-to-back."""
    t = step_time(profile, flops, hbm_bytes, link_bytes)
    if t == 0:
        return profile.p_idle
    e = step_energy(profile, flops, hbm_bytes, link_bytes, wall_s=t)
    return e / t


def hours_on_battery(avg_watts: float, battery_mah: float = 2000.0,
                     volts: float = 3.7) -> float:
    """The paper's Fig. 8 metric: runtime on a COTS battery pack."""
    wh = battery_mah / 1000.0 * volts
    return wh / max(avg_watts, 1e-9)
