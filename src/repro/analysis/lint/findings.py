"""replint core types: findings, per-module context, and the rule protocol.

A :class:`Finding` is one violation of one rule family at one source
location.  Rules receive a :class:`ModuleInfo` (parsed AST + source +
repo-relative path) and yield findings; the driver owns suppression
(inline ``# replint: disable=RULE`` pragmas), the checked-in baseline,
and the JSON report (see driver.py and docs/LINTS.md).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``symbol`` is the enclosing ``Class.method`` / function (or ``<module>``)
    — together with ``rule``, ``path`` and ``message`` it forms the
    line-number-independent identity the baseline matches on, so accepted
    debt survives unrelated edits that shift lines."""

    rule: str                  # rule family id, e.g. "lock-discipline"
    path: str                  # repo-relative posix path
    line: int
    col: int
    message: str
    symbol: str = "<module>"

    def key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.message)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.symbol}] {self.message}")


@dataclass
class LintConfig:
    """Knobs the rules read; defaults encode this repo's conventions
    (documented per rule in docs/LINTS.md)."""

    # dispatch-hygiene: repo-relative path prefixes where raw backend /
    # REPRO_FORCE_REF probes are legal.  kernels/dispatch.py IS the
    # dispatch layer; launch/ holds diagnostics that print the substrate;
    # the analyzer itself names the probes it greps for.
    dispatch_allowed: Tuple[str, ...] = (
        "repro/kernels/dispatch.py",
        "repro/launch/",
        "repro/analysis/lint/",
    )
    # host-sync: (ClassName, method) pairs treated as hot-path even though
    # they are not lexically jitted — the decode step loop and the plan's
    # staging/run paths, where a stray device sync stalls the pipeline.
    hot_paths: Tuple[Tuple[str, str], ...] = (
        ("ServingEngine", "step"),
        ("ExecutionPlan", "run"),
        ("ExecutionPlan", "produce_many"),
        # telemetry collectors: WallProbe.record is called FROM the paths
        # above (a device sync in the probe would stall the very pipeline
        # it measures), and the fleet simulator's per-device tick runs
        # thousands of times per simulated hour — both must stay host-only
        ("WallProbe", "record"),
        ("FleetSimulator", "step"),
        # disaggregated-fleet wire paths: Transport send/recv frame every
        # cross-fleet hand-off, and the fleet workers' run loops sit
        # between the engine and the wire — a stray sync there serializes
        # the two fleets.  KV block export IS the serialization boundary
        # (its pulls carry explicit pragmas); everything around it must
        # not add more.
        ("Transport", "send"),
        ("Transport", "recv"),
        ("PrefillWorker", "run"),
        ("DecodeWorker", "run"),
        ("PagedKVCache", "export_blocks"),
        ("PagedKVCache", "import_blocks"),
    )
    # kernel-triple: the package that is the dispatch layer, not a triple
    kernels_skip: Tuple[str, ...] = ("dispatch.py", "__init__.py")


@dataclass
class ModuleInfo:
    """One parsed source file handed to the per-module rules."""

    path: str                  # repo-relative posix path
    source: str
    tree: ast.Module
    config: LintConfig = field(default_factory=LintConfig)
    abspath: Optional[Path] = None

    @classmethod
    def from_source(cls, source: str, path: str = "<fixture>",
                    config: Optional[LintConfig] = None,
                    abspath: Optional[Path] = None) -> "ModuleInfo":
        return cls(path=path, source=source,
                   tree=ast.parse(source, filename=path),
                   config=config or LintConfig(), abspath=abspath)


class Rule:
    """Base rule: override ``check_module`` (per-file rules) and/or
    ``check_project`` (cross-file rules like kernel-triple)."""

    name: str = "base"
    description: str = ""

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        return iter(())

    def check_project(self, mods: List[ModuleInfo]) -> Iterator[Finding]:
        return iter(())


# ---------------------------------------------------------------------------
# small AST helpers shared by the rules
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` / ``a.b[0]`` as a stable string, or None for expressions
    too dynamic to track (calls, arithmetic, ...)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    if isinstance(node, ast.Subscript):
        base = dotted(node.value)
        if base is None:
            return None
        if isinstance(node.slice, ast.Constant):
            return f"{base}[{node.slice.value!r}]"
        return f"{base}[*]"
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """The called expression as a dotted string (``jax.jit``,
    ``self._cond.notify_all``), or None."""
    return dotted(node.func)


def parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent
    return parents


def enclosing(node: ast.AST, parents: Dict[ast.AST, ast.AST],
              kinds: tuple) -> Optional[ast.AST]:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, kinds):
            return cur
        cur = parents.get(cur)
    return None


def symbol_of(node: ast.AST, parents: Dict[ast.AST, ast.AST]) -> str:
    """``Class.method`` / ``func`` / ``<module>`` for a node."""
    names: List[str] = []
    cur: Optional[ast.AST] = node
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            names.append(cur.name)
        elif isinstance(cur, ast.Lambda):
            names.append("<lambda>")
        cur = parents.get(cur)
    return ".".join(reversed(names)) or "<module>"


def assign_targets(stmt: ast.stmt) -> Iterable[ast.expr]:
    """Flattened store targets of an assignment-like statement."""
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    out: List[ast.expr] = []
    stack = targets
    while stack:
        t = stack.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        else:
            out.append(t)
    return out


def lambda_arity(fn: ast.AST) -> Optional[Tuple[int, int]]:
    """(required, total) positional-arg counts of a lambda/def."""
    if not isinstance(fn, (ast.Lambda, ast.FunctionDef,
                           ast.AsyncFunctionDef)):
        return None
    a = fn.args
    total = len(a.posonlyargs) + len(a.args)
    required = total - len(a.defaults)
    return required, total
