"""dispatch-hygiene — backend probing only through kernels/dispatch.py.

PR 3 deduplicated five hand-rolled ``jax.default_backend() == "tpu"``
checks into :mod:`repro.kernels.dispatch` (``resolve_interpret`` /
``on_tpu`` / ``force_ref``), because a raw probe frozen into a jit trace
silently ignores ``REPRO_FORCE_REF`` and ``force_ref()`` overrides, and a
sixth copy crept straight back in (models/attention.py, fixed alongside
this rule).  This rule keeps the dispatch decision in one place:

* calls to ``jax.default_backend()`` / ``jax.lib.xla_bridge.get_backend``,
* any literal mention of the ``REPRO_FORCE_REF`` environment variable
  (``os.environ`` / ``os.getenv`` reads or otherwise),

are only legal under the path prefixes in
:attr:`LintConfig.dispatch_allowed` — by default the dispatch module
itself, ``launch/`` diagnostics (which *print* the substrate rather than
branch on it), and this analyzer.  Everything else must call the
dispatch API (``resolve_interpret`` for the kernel choice, ``on_tpu``
for a hardware fact).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.lint.findings import (Finding, ModuleInfo, Rule,
                                          call_name, parent_map, symbol_of)

_PROBE_CALLS = {
    "jax.default_backend",
    "jax.lib.xla_bridge.get_backend",
    "xla_bridge.get_backend",
}
_ENV_VAR = "REPRO_FORCE_REF"


class DispatchHygieneRule(Rule):
    name = "dispatch-hygiene"
    description = ("raw backend probes and REPRO_FORCE_REF reads are only "
                   "legal in kernels/dispatch.py and launch/ diagnostics")

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        if any(mod.path.startswith(p) or f"/{p}" in mod.path
               for p in mod.config.dispatch_allowed):
            return
        parents = parent_map(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and call_name(node) in _PROBE_CALLS:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"raw backend probe '{call_name(node)}()' outside the "
                    f"dispatch layer — use repro.kernels.dispatch "
                    f"(resolve_interpret / on_tpu) so REPRO_FORCE_REF and "
                    f"force_ref() overrides keep working",
                    symbol_of(node, parents))
            elif isinstance(node, ast.Constant) and node.value == _ENV_VAR:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"'{_ENV_VAR}' referenced outside the dispatch layer — "
                    f"only repro.kernels.dispatch may read the override "
                    f"env var (call dispatch.force_ref_active instead)",
                    symbol_of(node, parents))
