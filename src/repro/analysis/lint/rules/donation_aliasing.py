"""donation-aliasing — never read a buffer after donating it to jit.

``jax.jit(..., donate_argnums=...)`` invalidates the caller's buffer: the
donated array aliases the output and a later dispatch against the old
reference raises (or worse, on some backends, reads freed memory).  The
repo's convention is *rebind in the calling statement*:

    self.pool = _write_slot(self.pool, ...)        # OK — rebound
    params, opt, m = jitted(params, opt, batch)    # OK — rebound

This rule tracks every jitted-with-donation callable defined in a module —

* ``@functools.partial(jax.jit, donate_argnums=(...))`` decorated defs,
* ``name = jax.jit(fn, donate_argnums=(...))`` assignments (including
  attribute targets like ``self._decode``),

— then audits every direct call site: the expression at each donated
position (when it is a trackable name/attribute) must not be read again
after the call in the enclosing function, unless rebound first.  A
same-statement rebind or ``return`` is safe; passing the same buffer at
two argument positions of one donating call is flagged outright.

Approximations (documented in docs/LINTS.md): tracking is lexical within
one function — reads at earlier lines of a surrounding loop body are not
seen, and ``jitted.lower(...)`` (tracing, no buffers consumed) is
deliberately not treated as a call.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.lint.findings import (Finding, ModuleInfo, Rule,
                                          assign_targets, call_name, dotted,
                                          enclosing, parent_map, symbol_of)

_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _donate_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """``donate_argnums`` keyword of a jax.jit/partial(jax.jit) call."""
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if not (isinstance(e, ast.Constant)
                            and isinstance(e.value, int)):
                        return None
                    out.append(e.value)
                return tuple(out)
            return None
    return None


def _jit_call(node: ast.AST) -> Optional[ast.Call]:
    """The jax.jit(...) call inside ``jax.jit(...)`` or
    ``functools.partial(jax.jit, ...)``, else None."""
    if not isinstance(node, ast.Call):
        return None
    name = call_name(node)
    if name == "jax.jit":
        return node
    if name in ("functools.partial", "partial") and node.args \
            and dotted(node.args[0]) == "jax.jit":
        return node
    return None


def _collect_donating(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    """callable-name -> donated positions, for this module."""
    out: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                jit = _jit_call(dec)
                pos = _donate_positions(jit) if jit is not None else None
                if pos:
                    out[node.name] = pos
        elif isinstance(node, ast.Assign):
            jit = _jit_call(node.value)
            pos = _donate_positions(jit) if jit is not None else None
            if pos:
                for t in assign_targets(node):
                    name = dotted(t)
                    if name is not None:
                        out[name] = pos
    return out


class DonationAliasingRule(Rule):
    name = "donation-aliasing"
    description = ("a buffer passed at a donate_argnums position must not "
                   "be read after the call (rebind it in the statement)")

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        donating = _collect_donating(mod.tree)
        if not donating:
            return
        parents = parent_map(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            pos = donating.get(call_name(node) or "")
            if not pos:
                continue
            yield from self._check_call(mod, node, pos, parents)

    def _check_call(self, mod: ModuleInfo, call: ast.Call,
                    pos: Tuple[int, ...], parents) -> Iterator[Finding]:
        sym = symbol_of(call, parents)
        arg_names = [dotted(a) for a in call.args]
        for p in pos:
            if p >= len(call.args):
                continue
            name = arg_names[p]
            if name is None:
                continue                      # untrackable expression
            if arg_names.count(name) > 1:
                yield Finding(
                    self.name, mod.path, call.lineno, call.col_offset,
                    f"'{name}' is donated at position {p} but also passed "
                    f"at another argument position of the same call "
                    f"(aliased donation)", sym)
            stmt = enclosing(call, parents, (ast.stmt,))
            if stmt is None or isinstance(stmt, ast.Return):
                continue
            if name in (dotted(t) for t in assign_targets(stmt)):
                continue                      # rebound by this statement
            fn = enclosing(call, parents, _FUNCS)
            if fn is None or isinstance(fn, ast.Lambda):
                continue                      # lambda body: nothing follows
            read = self._first_read_after(fn, stmt, name)
            if read is not None:
                yield Finding(
                    self.name, mod.path, read.lineno, read.col_offset,
                    f"'{name}' read after being donated to "
                    f"'{call_name(call)}' at line {call.lineno} — the "
                    f"buffer is invalid; rebind it in the calling "
                    f"statement", sym)

    @staticmethod
    def _first_read_after(fn: ast.AST, call_stmt: ast.stmt,
                          name: str) -> Optional[ast.expr]:
        """First Load of ``name`` after the call statement, unless a
        rebind (Store) comes first.  Events are ordered by line."""
        end = getattr(call_stmt, "end_lineno", call_stmt.lineno)
        events: List[Tuple[int, int, str, ast.expr]] = []
        for node in ast.walk(fn):
            if not isinstance(node, (ast.Name, ast.Attribute,
                                     ast.Subscript)):
                continue
            if dotted(node) != name:
                continue
            if node.lineno <= end:
                continue
            ctx = getattr(node, "ctx", None)
            if isinstance(ctx, ast.Store):
                events.append((node.lineno, node.col_offset, "store", node))
            elif isinstance(ctx, ast.Load):
                events.append((node.lineno, node.col_offset, "load", node))
        for _ln, _col, kind, node in sorted(events, key=lambda e: e[:2]):
            if kind == "store":
                return None
            return node
        return None
