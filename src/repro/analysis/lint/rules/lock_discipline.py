"""lock-discipline — every guarded state transition under the owning lock.

The TABM ring's correctness argument (docs/TABM.md) is "every state
transition happens under one ``threading.Condition``".  This rule makes
that machine-checked, per class that owns a lock:

* a class *owns a lock* when any method assigns
  ``self.X = threading.Condition() | Lock() | RLock()``;
* a field is *guarded* when it is ever written lexically inside
  ``with self.X:`` (or inside a lock-required method, below) outside
  ``__init__``/``__post_init__``;
* every other write to a guarded field must itself be lexically inside
  ``with self.X:`` — constructor writes are exempt (the object is not
  shared yet);
* ``self.X.notify*() / wait() / wait_for()`` must be inside
  ``with self.X:`` (calling them unlocked raises at runtime only when the
  race actually fires — this catches it at push time);
* a method whose docstring declares the convention ("Caller must hold
  ``self._cond``", "called with the lock held", ...) is **lock-required**:
  its own guarded writes are legal, but every intra-class call site
  (``self.meth(...)``) must be inside a locked region or inside another
  lock-required method — the intra-class call-graph walk.

Known approximation: "inside" is lexical containment.  A closure built
under the lock but invoked later escapes this analysis; keep such
callbacks out of locked regions (none exist in the tree today).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.lint.findings import (Finding, ModuleInfo, Rule,
                                          assign_targets, call_name, dotted,
                                          parent_map)

_LOCK_CTORS = {"threading.Condition", "threading.Lock", "threading.RLock"}
_WAIT_NOTIFY = {"notify", "notify_all", "wait", "wait_for"}
_HELD_RE = re.compile(
    r"(?i)(caller\s+(must|should)\s+hold|called\s+with\s+.{0,40}\bheld)")
_INIT_METHODS = {"__init__", "__post_init__", "__new__"}


def _is_lock_required(fn: ast.FunctionDef) -> bool:
    doc = ast.get_docstring(fn) or ""
    return bool(_HELD_RE.search(doc))


def _field_of_target(t: ast.expr) -> Optional[str]:
    """``self.attr`` / ``self.attr[...]`` -> ``attr`` (writes to locals or
    other objects are not this class's state)."""
    if isinstance(t, ast.Subscript):
        t = t.value
    if isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name) \
            and t.value.id == "self":
        return t.attr
    return None


class _MethodScan:
    """Lexical facts about one method body."""

    def __init__(self, fn: ast.FunctionDef, lock_attrs: Set[str]):
        self.fn = fn
        self.lock_required = _is_lock_required(fn)
        self.locked_nodes: Set[ast.AST] = set()
        # writes: (field, node, locked); lock-ops / self-calls similarly
        self.writes: List[Tuple[str, ast.stmt, bool]] = []
        self.lock_ops: List[Tuple[ast.Call, bool]] = []
        self.self_calls: List[Tuple[str, ast.Call, bool]] = []
        self._walk(fn, locked=False, lock_attrs=lock_attrs)

    def _walk(self, node: ast.AST, locked: bool, lock_attrs: Set[str]):
        for child in ast.iter_child_nodes(node):
            child_locked = locked
            if isinstance(child, ast.With):
                for item in child.items:
                    d = dotted(item.context_expr)
                    if d is not None and d.startswith("self.") \
                            and d[len("self."):] in lock_attrs:
                        child_locked = True
            if isinstance(child, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                for t in assign_targets(child):
                    f = _field_of_target(t)
                    if f is not None:
                        self.writes.append((f, child, child_locked))
            if isinstance(child, ast.Call):
                name = call_name(child)
                if name is not None and name.startswith("self."):
                    parts = name.split(".")
                    if (len(parts) == 3 and parts[1] in lock_attrs
                            and parts[2] in _WAIT_NOTIFY):
                        self.lock_ops.append((child, child_locked))
                    elif len(parts) == 2:
                        self.self_calls.append((parts[1], child,
                                                child_locked))
            # nested defs still belong to the method lexically; a nested
            # def/lambda inside a locked region inherits "locked" (the
            # wait_for predicate lambda pattern)
            self._walk(child, child_locked, lock_attrs)


class LockDisciplineRule(Rule):
    name = "lock-discipline"
    description = ("guarded-field writes, notify*/wait* and lock-required "
                   "method calls must hold the owning lock")

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        parents = parent_map(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(mod, node, parents)

    def _check_class(self, mod: ModuleInfo, cls: ast.ClassDef,
                     parents) -> Iterator[Finding]:
        methods = [n for n in cls.body
                   if isinstance(n, ast.FunctionDef)]
        lock_attrs: Set[str] = set()
        for fn in methods:
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Assign) and isinstance(sub.value,
                                                              ast.Call):
                    ctor = call_name(sub.value)
                    if ctor in _LOCK_CTORS:
                        for t in assign_targets(sub):
                            f = _field_of_target(t)
                            if f is not None:
                                lock_attrs.add(f)
        if not lock_attrs:
            return

        scans: Dict[str, _MethodScan] = {
            fn.name: _MethodScan(fn, lock_attrs) for fn in methods}
        required = {name for name, s in scans.items() if s.lock_required}

        # guarded fields: written under a lock (or in a lock-required
        # method) anywhere outside construction
        guarded: Set[str] = set()
        for name, s in scans.items():
            if name in _INIT_METHODS:
                continue
            for f, _stmt, locked in s.writes:
                if (locked or s.lock_required) and f not in lock_attrs:
                    guarded.add(f)

        for name, s in scans.items():
            if name in _INIT_METHODS:
                continue
            sym = f"{cls.name}.{name}"
            if not s.lock_required:
                for f, stmt, locked in s.writes:
                    if f in guarded and not locked:
                        yield Finding(
                            self.name, mod.path, stmt.lineno,
                            stmt.col_offset,
                            f"write to guarded field 'self.{f}' outside "
                            f"'with self.<lock>:' (guarded because it is "
                            f"written under the lock elsewhere in "
                            f"{cls.name})", sym)
                for call, locked in s.lock_ops:
                    if not locked:
                        yield Finding(
                            self.name, mod.path, call.lineno,
                            call.col_offset,
                            f"'{call_name(call)}()' called without "
                            f"holding the lock", sym)
            for callee, call, locked in s.self_calls:
                if callee in required and not locked and not s.lock_required:
                    yield Finding(
                        self.name, mod.path, call.lineno, call.col_offset,
                        f"'self.{callee}()' is documented as "
                        f"called-with-lock-held but this call site does "
                        f"not hold the lock", sym)
