"""host-sync — no silent device round-trips where they stall the pipeline.

Two hot-context kinds are scanned:

* **jit regions** — functions decorated with ``jax.jit`` /
  ``functools.partial(jax.jit, ...)``, lambdas passed to ``jax.jit``, and
  local defs wrapped via ``jax.jit(fn, ...)``.  Here ``.item()``,
  ``np.asarray`` / ``np.array``, ``jax.device_get``,
  ``block_until_ready`` and ``float()``/``int()`` over non-static values
  are all flagged: under trace they either raise
  (``ConcretizationTypeError``) at an unhelpful distance or silently
  constant-fold a value that should be traced.
* **hot-path functions** (:attr:`LintConfig.hot_paths` — the engine's
  decode step loop and the plan's run/staging paths).  These run host
  Python between device dispatches, so a stray sync serializes the
  pipeline; the same calls are flagged.  Deliberate syncs (the plan's
  residency trace points, the engine's per-token sampling reads) carry
  ``# replint: disable=host-sync`` pragmas with their one-line why.

``float()``/``int()`` over shape/ndim/size/len expressions or literals
are static and exempt.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from repro.analysis.lint.findings import (Finding, ModuleInfo, Rule,
                                          call_name, dotted, parent_map,
                                          symbol_of)

_SYNC_CALLS = {"jax.device_get", "jax.block_until_ready",
               "np.asarray", "np.array", "numpy.asarray", "numpy.array"}
_CASTS = {"float", "int"}


def _jit_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = call_name(node)
    if name == "jax.jit":
        return True
    return name in ("functools.partial", "partial") and bool(node.args) \
        and dotted(node.args[0]) == "jax.jit"


def _static_cast(call: ast.Call) -> bool:
    """float()/int() over literals or shape arithmetic is trace-static."""
    if not call.args:
        return True
    arg = call.args[0]
    if isinstance(arg, ast.Constant):
        return True
    for sub in ast.walk(arg):
        if isinstance(sub, ast.Attribute) and sub.attr in ("shape", "ndim",
                                                           "size", "dtype"):
            return True
        if isinstance(sub, ast.Call) and call_name(sub) == "len":
            return True
    return False


def _jit_regions(tree: ast.Module) -> List[ast.AST]:
    """Function/lambda nodes whose bodies trace under jax.jit."""
    defs_by_name = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, node)
    regions: List[ast.AST] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and any(_jit_call(d) for d in node.decorator_list):
            regions.append(node)
        elif isinstance(node, ast.Call) and call_name(node) == "jax.jit" \
                and node.args:
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                regions.append(target)
            elif isinstance(target, ast.Name) \
                    and target.id in defs_by_name:
                regions.append(defs_by_name[target.id])
    return regions


class HostSyncRule(Rule):
    name = "host-sync"
    description = ("no .item()/np.asarray/device_get/block_until_ready/"
                   "float()/int() syncs inside jit regions or hot-path "
                   "functions")

    def check_module(self, mod: ModuleInfo) -> Iterator[Finding]:
        parents = parent_map(mod.tree)
        hot: List[tuple] = []          # (node, context-label)
        for region in _jit_regions(mod.tree):
            hot.append((region, "jit region"))
        hot_paths: Set[tuple] = set(mod.config.hot_paths)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                for m in node.body:
                    if isinstance(m, ast.FunctionDef) \
                            and (node.name, m.name) in hot_paths:
                        hot.append((m, "hot path"))
        seen: Set[int] = set()
        for region, label in hot:
            for f in self._scan(mod, region, label, parents):
                key = hash((f.line, f.col, f.message))
                if key not in seen:
                    seen.add(key)
                    yield f

    def _scan(self, mod: ModuleInfo, region: ast.AST, label: str,
              parents) -> Iterator[Finding]:
        for node in ast.walk(region):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            what: Optional[str] = None
            if name in _SYNC_CALLS:
                what = f"'{name}'"
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("item", "block_until_ready"):
                what = f"'.{node.func.attr}()'"
            elif name in _CASTS and not _static_cast(node):
                what = f"'{name}()' over a device value"
            if what is not None:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"{what} forces a host sync inside a {label} — hoist "
                    f"it out of the hot path or suppress with a "
                    f"justification if the sync is the design",
                    symbol_of(node, parents))
