"""replint rule registry — one entry per rule family (docs/LINTS.md)."""
from __future__ import annotations

from typing import List

from repro.analysis.lint.findings import Rule
from repro.analysis.lint.rules.dispatch_hygiene import DispatchHygieneRule
from repro.analysis.lint.rules.donation_aliasing import DonationAliasingRule
from repro.analysis.lint.rules.host_sync import HostSyncRule
from repro.analysis.lint.rules.kernel_triples import KernelTripleRule
from repro.analysis.lint.rules.lock_discipline import LockDisciplineRule

ALL_RULES = (
    LockDisciplineRule,
    DonationAliasingRule,
    DispatchHygieneRule,
    HostSyncRule,
    KernelTripleRule,
)


def default_rules() -> List[Rule]:
    return [cls() for cls in ALL_RULES]


__all__ = ["ALL_RULES", "default_rules", "DispatchHygieneRule",
           "DonationAliasingRule", "HostSyncRule", "KernelTripleRule",
           "LockDisciplineRule"]
