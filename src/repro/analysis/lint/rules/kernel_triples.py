"""kernel-triple — every kernels/*/ package keeps ops/ref/kernel coherent.

The repo's kernel contract (docs/ARCHITECTURE.md): each Pallas kernel is a
*triple* — ``ops.py`` (public jit wrapper), ``ref.py`` (pure-jnp oracle),
``kernel.py`` (the Pallas body) — kept interchangeable so tests can assert
kernel==ref and the dispatch layer can force the reference path anywhere.
Per package this rule checks:

* all three files exist;
* ``ops.py`` exposes at least one public wrapper with a keyword-only
  ``interpret`` parameter defaulting to ``None`` whose body calls
  ``resolve_interpret`` (the kernels/dispatch resolution, outside the
  inner jit);
* the wrapper's positional signature matches its ``ref_*`` oracle
  (wrapper positional names must be a prefix of the ref's, any extra ref
  parameters defaulted — e.g. the oracle's optional initial state);
  aliased oracles (``from ... import x as ref_y``) are resolved through
  the import;
* ``kernel.py`` exposes a public entry that accepts ``interpret`` and
  plumbs it into ``pl.pallas_call(..., interpret=...)``;
* every ``pl.BlockSpec`` index-map lambda's required arity equals the
  grid rank plus ``num_scalar_prefetch`` (a mismatched index map is a
  shape error only on real TPU hardware — this catches it at push time).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.lint.findings import (Finding, LintConfig, ModuleInfo,
                                          Rule, call_name, lambda_arity)


def _positional_names(fn: ast.FunctionDef) -> List[str]:
    return [a.arg for a in (*fn.args.posonlyargs, *fn.args.args)]


def _required_positional(fn: ast.FunctionDef) -> List[str]:
    names = _positional_names(fn)
    n_def = len(fn.args.defaults)
    return names[: len(names) - n_def] if n_def else names


def _kwonly(fn: ast.FunctionDef) -> Dict[str, Optional[ast.expr]]:
    return {a.arg: d for a, d in zip(fn.args.kwonlyargs,
                                     fn.args.kw_defaults)}


def _top_defs(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}


def _calls_in(fn: ast.FunctionDef, name: str) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            cn = call_name(node) or ""
            if cn == name or cn.endswith("." + name):
                return True
    return False


class KernelTripleRule(Rule):
    name = "kernel-triple"
    description = ("kernels/*/ packages must keep ops/ref/kernel "
                   "signatures matching, plumb interpret=, and have "
                   "BlockSpec index-map arity == grid rank")

    def check_project(self, mods: List[ModuleInfo]) -> Iterator[Finding]:
        by_path = {m.path: m for m in mods}
        packages: Dict[str, Dict[str, ModuleInfo]] = {}
        for m in mods:
            parts = Path(m.path).parts
            if "kernels" not in parts:
                continue
            i = parts.index("kernels")
            if len(parts) != i + 3:        # kernels/<pkg>/<file>.py
                continue
            pkg, fname = parts[i + 1], parts[i + 2]
            packages.setdefault(pkg, {})[fname] = m
        for pkg in sorted(packages):
            yield from self._check_package(pkg, packages[pkg], by_path)

    def _check_package(self, pkg: str, files: Dict[str, ModuleInfo],
                       by_path: Dict[str, ModuleInfo]) -> Iterator[Finding]:
        anchor = next(iter(files.values()))
        missing = [f for f in ("ops.py", "ref.py", "kernel.py")
                   if f not in files]
        if missing:
            yield Finding(self.name, anchor.path, 1, 0,
                          f"kernels/{pkg} is missing {missing} — every "
                          f"kernel package is an ops/ref/kernel triple",
                          f"kernels.{pkg}")
            return
        ops, ref, kern = files["ops.py"], files["ref.py"], files["kernel.py"]

        wrappers = [fn for fn in _top_defs(ops.tree).values()
                    if not fn.name.startswith("_")
                    and "interpret" in _kwonly(fn)]
        if not wrappers:
            yield Finding(self.name, ops.path, 1, 0,
                          f"kernels/{pkg}/ops.py has no public wrapper "
                          f"with a keyword-only 'interpret' parameter",
                          f"kernels.{pkg}")
            return
        for fn in wrappers:
            default = _kwonly(fn)["interpret"]
            if not (isinstance(default, ast.Constant)
                    and default.value is None):
                yield Finding(
                    self.name, ops.path, fn.lineno, fn.col_offset,
                    f"'{fn.name}' must default interpret=None so "
                    f"kernels/dispatch resolves it (hardware + "
                    f"REPRO-override aware)", f"kernels.{pkg}.{fn.name}")
            if not _calls_in(fn, "resolve_interpret"):
                yield Finding(
                    self.name, ops.path, fn.lineno, fn.col_offset,
                    f"'{fn.name}' does not resolve interpret= through "
                    f"kernels.dispatch.resolve_interpret (the dispatch "
                    f"decision must stay outside the inner jit)",
                    f"kernels.{pkg}.{fn.name}")

        refs = self._ref_fns(ref, by_path)
        if not refs:
            yield Finding(self.name, ref.path, 1, 0,
                          f"kernels/{pkg}/ref.py defines (or re-exports) "
                          f"no 'ref_*' oracle", f"kernels.{pkg}")
        else:
            yield from self._match_signatures(pkg, ops, wrappers, refs)

        yield from self._check_kernel(pkg, kern)

    # -- oracle discovery (including aliased re-exports) --------------------
    def _ref_fns(self, ref: ModuleInfo, by_path: Dict[str, ModuleInfo]
                 ) -> Dict[str, ast.FunctionDef]:
        out = {name: fn for name, fn in _top_defs(ref.tree).items()
               if name.startswith("ref_")}
        for node in ref.tree.body:
            if not isinstance(node, ast.ImportFrom) or node.module is None:
                continue
            target = "/".join(node.module.split(".")) + ".py"
            target_mod = next((m for p, m in by_path.items()
                               if p.endswith(target)), None)
            if target_mod is None:
                continue
            defs = _top_defs(target_mod.tree)
            for alias in node.names:
                ref_name = alias.asname or alias.name
                if ref_name.startswith("ref_") and alias.name in defs:
                    out.setdefault(ref_name, defs[alias.name])
        return out

    def _match_signatures(self, pkg: str, ops: ModuleInfo,
                          wrappers: List[ast.FunctionDef],
                          refs: Dict[str, ast.FunctionDef]
                          ) -> Iterator[Finding]:
        for fn in wrappers:
            ref_fn = refs.get(f"ref_{fn.name}")
            if ref_fn is None and len(refs) == 1 and len(wrappers) == 1:
                ref_fn = next(iter(refs.values()))
            if ref_fn is None:
                yield Finding(
                    self.name, ops.path, fn.lineno, fn.col_offset,
                    f"no oracle pairs with '{fn.name}' (expected "
                    f"'ref_{fn.name}' or a single ref_* export)",
                    f"kernels.{pkg}.{fn.name}")
                continue
            w, r = _positional_names(fn), _positional_names(ref_fn)
            ref_required = _required_positional(ref_fn)
            if r[: len(w)] != w or len(ref_required) > len(w):
                yield Finding(
                    self.name, ops.path, fn.lineno, fn.col_offset,
                    f"'{fn.name}{tuple(w)}' does not match its oracle "
                    f"'{ref_fn.name}{tuple(r)}' — wrapper positional "
                    f"names must prefix the oracle's, extra oracle "
                    f"params defaulted", f"kernels.{pkg}.{fn.name}")

    # -- pallas entry + BlockSpec arity -------------------------------------
    def _check_kernel(self, pkg: str, kern: ModuleInfo) -> Iterator[Finding]:
        entries = [fn for fn in _top_defs(kern.tree).values()
                   if not fn.name.startswith("_")
                   and ("interpret" in _kwonly(fn)
                        or "interpret" in _positional_names(fn))]
        if not entries:
            yield Finding(self.name, kern.path, 1, 0,
                          f"kernels/{pkg}/kernel.py has no public entry "
                          f"taking interpret= — the Pallas body must stay "
                          f"runnable in interpret mode off-TPU",
                          f"kernels.{pkg}")
            return
        for fn in entries:
            plumbed = False
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) \
                        and (call_name(node) or "").endswith("pallas_call"):
                    if any(kw.arg == "interpret" for kw in node.keywords):
                        plumbed = True
            if not plumbed:
                yield Finding(
                    self.name, kern.path, fn.lineno, fn.col_offset,
                    f"'{fn.name}' takes interpret= but never passes it to "
                    f"pl.pallas_call", f"kernels.{pkg}.{fn.name}")
            yield from self._check_blockspecs(pkg, kern, fn)

    def _check_blockspecs(self, pkg: str, kern: ModuleInfo,
                          fn: ast.FunctionDef) -> Iterator[Finding]:
        rank: Optional[int] = None
        prefetch = 0
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg == "grid" and isinstance(kw.value, ast.Tuple):
                    rank = len(kw.value.elts)
                elif kw.arg == "num_scalar_prefetch" \
                        and isinstance(kw.value, ast.Constant):
                    prefetch = int(kw.value.value)
        if rank is None:
            return
        want = rank + prefetch
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and (call_name(node) or "").endswith("BlockSpec")
                    and len(node.args) >= 2):
                continue
            arity = lambda_arity(node.args[1])
            if arity is None:
                continue                   # named/opaque index map: skip
            required, total = arity
            if not (required <= want <= total):
                yield Finding(
                    self.name, kern.path, node.lineno, node.col_offset,
                    f"BlockSpec index map takes {required} required "
                    f"args but the grid rank (+scalar prefetch) is "
                    f"{want} — the index map runs once per grid "
                    f"coordinate", f"kernels.{pkg}.{fn.name}")
