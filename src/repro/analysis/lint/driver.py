"""replint driver: discovery, pragmas, baseline, JSON report.

The driver walks ``src/**/*.py``, parses each file once, runs every rule
(per-module and cross-module), then partitions findings three ways:

* **suppressed** — an inline ``# replint: disable=RULE[,RULE]`` pragma on
  the finding's line or the line directly above it.  Pragmas are the
  tool's escape hatch for *deliberate* violations and each one in the
  tree carries a one-line justification (see docs/LINTS.md).
* **baselined** — present in the checked-in baseline file
  (``scripts/replint_baseline.json``), matched on the line-number-
  independent :meth:`Finding.key` so accepted debt survives unrelated
  edits.  The baseline ships empty; growing it is a reviewed change.
* **unsuppressed** — everything else.  ``make lint`` exits non-zero if
  any exist.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.lint.findings import Finding, LintConfig, ModuleInfo, Rule
from repro.analysis.lint.rules import default_rules

_PRAGMA_RE = re.compile(r"#\s*replint:\s*disable=([A-Za-z0-9_,\- ]+)")


def _pragmas(source: str) -> Dict[int, Set[str]]:
    """line number -> rule names disabled on that line."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _suppressed(f: Finding, pragmas: Dict[int, Set[str]]) -> bool:
    """A pragma applies on the finding's own line or the line above it."""
    for line in (f.line, f.line - 1):
        rules = pragmas.get(line)
        if rules and (f.rule in rules or "all" in rules):
            return True
    return False


@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)      # unsuppressed
    suppressed: List[Finding] = field(default_factory=list)
    baseline_matched: List[Finding] = field(default_factory=list)
    files_checked: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        def enc(items: List[Finding]) -> List[dict]:
            return [{"rule": f.rule, "path": f.path, "line": f.line,
                     "col": f.col, "symbol": f.symbol,
                     "message": f.message} for f in items]
        return {"tool": "replint", "files_checked": self.files_checked,
                "ok": self.ok, "findings": enc(self.findings),
                "suppressed": enc(self.suppressed),
                "baseline_matched": enc(self.baseline_matched)}


def discover(root: Path) -> List[Path]:
    """All tracked .py files under ``root`` (``src/`` in production)."""
    return sorted(p for p in root.rglob("*.py")
                  if "__pycache__" not in p.parts)


def load_modules(root: Path, config: Optional[LintConfig] = None
                 ) -> List[ModuleInfo]:
    config = config or LintConfig()
    mods: List[ModuleInfo] = []
    for p in discover(root):
        rel = p.relative_to(root.parent if root.name == "repro"
                            else root).as_posix()
        source = p.read_text(encoding="utf-8")
        mods.append(ModuleInfo.from_source(source, path=rel, config=config,
                                           abspath=p))
    return mods


def run_rules(mods: Sequence[ModuleInfo],
              rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    rules = list(rules) if rules is not None else default_rules()
    findings: List[Finding] = []
    for rule in rules:
        for mod in mods:
            findings.extend(rule.check_module(mod))
        findings.extend(rule.check_project(list(mods)))
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def load_baseline(path: Optional[Path]) -> Set[Tuple[str, str, str, str]]:
    if path is None or not path.exists():
        return set()
    entries = json.loads(path.read_text(encoding="utf-8"))
    return {(e["rule"], e["path"], e["symbol"], e["message"])
            for e in entries}


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    entries = [{"rule": f.rule, "path": f.path, "symbol": f.symbol,
                "message": f.message}
               for f in sorted(findings, key=lambda f: f.key())]
    path.write_text(json.dumps(entries, indent=2) + "\n", encoding="utf-8")


def run_lint(root: Path, rules: Optional[Sequence[Rule]] = None,
             config: Optional[LintConfig] = None,
             baseline: Optional[Path] = None) -> LintResult:
    """Lint every .py file under ``root``; partition findings."""
    mods = load_modules(root, config)
    pragma_by_path = {m.path: _pragmas(m.source) for m in mods}
    result = LintResult(files_checked=len(mods))
    base = load_baseline(baseline)
    for f in run_rules(mods, rules):
        if _suppressed(f, pragma_by_path.get(f.path, {})):
            result.suppressed.append(f)
        elif f.key() in base:
            result.baseline_matched.append(f)
        else:
            result.findings.append(f)
    return result


def lint_source(source: str, path: str = "<fixture>",
                rules: Optional[Sequence[Rule]] = None,
                config: Optional[LintConfig] = None) -> List[Finding]:
    """Test hook: lint one source string, honoring inline pragmas."""
    mod = ModuleInfo.from_source(source, path=path, config=config)
    pragmas = _pragmas(source)
    return [f for f in run_rules([mod], rules)
            if not _suppressed(f, pragmas)]
