"""replint — AST-based concurrency + JAX-discipline analyzer.

Five rule families gate every push (docs/LINTS.md has the catalog):

* ``lock-discipline`` — guarded-field writes / notify / wait and
  called-with-lock-held methods must hold the owning lock;
* ``donation-aliasing`` — a buffer donated to jit must not be read after
  the call;
* ``dispatch-hygiene`` — backend probes and REPRO_FORCE_REF only through
  kernels/dispatch.py;
* ``host-sync`` — no silent device round-trips in jit regions or the
  decode/staging hot paths;
* ``kernel-triple`` — every kernels/*/ package keeps ops/ref/kernel
  signatures coherent and BlockSpec index-map arity == grid rank.

Entry point: ``scripts/repro_lint.py`` (wired into ``make lint``,
scripts/check.sh and CI).  ``lint_source`` is the in-process test hook.
"""
from repro.analysis.lint.driver import (LintResult, lint_source,
                                        load_baseline, run_lint,
                                        write_baseline)
from repro.analysis.lint.findings import (Finding, LintConfig, ModuleInfo,
                                          Rule)
from repro.analysis.lint.rules import ALL_RULES, default_rules

__all__ = ["ALL_RULES", "Finding", "LintConfig", "LintResult", "ModuleInfo",
           "Rule", "default_rules", "lint_source", "load_baseline",
           "run_lint", "write_baseline"]
