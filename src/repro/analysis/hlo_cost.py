"""Trip-count-aware cost model over post-partitioning optimized HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — useless for
scan-over-layers models (a 95-layer stack reports 1/95th of its FLOPs).  This
module walks the HLO computation graph instead:

* every computation's ops are parsed with result shapes (symbol table);
* ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` on
  the CPU/TPU pipelines — multiplicities propagate body/cond counts;
* dot FLOPs = 2 * prod(result_dims) * prod(lhs contracting dims);
* collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute) are ring-transfer weighted and multiplied by the
  enclosing trip counts;
* traffic bytes ~= op result bytes (+ dot/fusion operand reads) x mult —
  an HBM-traffic proxy consistent across perf iterations.

All numbers are PER DEVICE (the module is the per-device SPMD program).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_ITER = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->")
_OP_LINE = re.compile(
    r"^\s+(%[\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")
_ROOT_OP = re.compile(
    r"^\s+ROOT\s+(%[\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")
_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALLED_ONE = re.compile(r"(?:condition|body|calls|to_apply)=(%?[\w\.\-]+)")
_CALLED_LIST = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERANDS = re.compile(r"%[\w\.\-]+")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota"}


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """bytes + [(dtype, dims), ...] for a result-type string (incl tuples)."""
    total, shapes = 0, []
    for dt, dims_s in _SHAPE_ITER.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",")] if dims_s else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dims))
    return total, shapes


@dataclass
class Op:
    name: str
    kind: str
    result_bytes: int
    result_shapes: list
    line: str
    operands: List[str]
    called: List[str]
    trip: int = 1


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symtab: Dict[str, list] = field(default_factory=dict)
    sym_bytes: Dict[str, int] = field(default_factory=dict)


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and "->" in line and "(" in line:
            m = _COMP_HDR.match(line)
            if m:
                name = m.group(2).lstrip("%")
                cur = Computation(name)
                comps[name] = cur
                if m.group(1):
                    entry = name
                # parameters declared in the header get shapes lazily from
                # their own "parameter(N)" op lines.
                continue
        if cur is None:
            continue
        m = _OP_LINE.match(line) or _ROOT_OP.match(line)
        if not m:
            continue
        name, type_str, kind = m.group(1), m.group(2), m.group(3)
        nbytes, shapes = _shape_info(type_str)
        # operand names: inside the first (...) after the opcode
        paren = line.find(kind + "(") + len(kind)
        depth, j = 0, paren
        while j < len(line):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        operand_str = line[paren:j + 1]
        operands = _OPERANDS.findall(operand_str)
        attrs = line[j:]
        called = [c.lstrip("%") for c in _CALLED_ONE.findall(attrs)]
        for grp in _CALLED_LIST.findall(attrs):
            called += [c.strip().lstrip("%") for c in grp.split(",")
                       if c.strip()]
        called = list(dict.fromkeys(called))
        trip = 1
        if kind == "while":
            tm = _TRIP.search(line)
            trip = int(tm.group(1)) if tm else 1
        op = Op(name, kind, nbytes, shapes, line, operands, called, trip)
        cur.ops.append(op)
        cur.symtab[name] = shapes
        cur.sym_bytes[name] = nbytes
    return comps, entry


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    return default


def _dot_flops(comp: Computation, op: Op) -> float:
    if not op.result_shapes:
        return 0.0
    _, rdims = op.result_shapes[0]
    out = 1.0
    for d in rdims:
        out *= d
    cdims = _LHS_CDIMS.search(op.line)
    contract = 1.0
    if cdims and op.operands:
        lhs = comp.symtab.get(op.operands[0])
        if lhs:
            _, ldims = lhs[0]
            for ds in cdims.group(1).split(","):
                if ds.strip():
                    i = int(ds)
                    if i < len(ldims):
                        contract *= ldims[i]
    return 2.0 * out * contract


# ops transparent to TPU operand/epilogue fusion: elementwise chains (and
# the kLoop fusions CPU-HLO has already collapsed them into) melt into the
# neighbouring matmuls.  Backed by kernels/: flash_attention keeps the
# dot->softmax->dot chain in VMEM; dequant_gemm streams packed codes and
# unpacks in-register.
_TRANSPARENT = {"fusion", "convert", "multiply", "add", "subtract", "divide",
                "exponential", "maximum", "minimum", "select", "compare",
                "broadcast", "reshape", "bitcast", "transpose", "copy",
                "and", "or", "shift-right-logical", "shift-left",
                "negate", "tanh", "rsqrt", "sqrt", "abs", "power", "reduce",
                "slice", "pad", "clamp", "exponential-minus-one", "log"}
_CHAIN_SOURCES = {"parameter", "constant", "iota", "get-tuple-element",
                  "partition-id"}


def _chain_dot_traffic(comp: "Computation") -> Dict[str, float]:
    """Per-dot traffic under the TPU fusion model.

    operand charge: walk the producer chain through transparent ops; the
    charge is min(operand bytes, sum of chain-source bytes) — a dequant
    chain (codes -> unpack -> rescale -> dot) charges the packed codes; a
    convert chain (bf16 param -> f32 dot input) charges the bf16 bytes.

    result charge: 0 if every consumer path through transparent ops ends in
    another dot in this computation (flash-attention pattern: scores ->
    masked softmax -> PV dot stays in VMEM); else result bytes."""
    producers = {op.name: op for op in comp.ops}
    consumers: Dict[str, list] = {}
    for op in comp.ops:
        for o in op.operands:
            consumers.setdefault(o, []).append(op)
    root = comp.ops[-1].name if comp.ops else None

    vmem_dots: set = set()

    def source_bytes(name, depth=0, seen=None):
        seen = seen if seen is not None else set()
        if name in seen or depth > 24:
            return 0.0
        seen.add(name)
        op = producers.get(name)
        if op is None:
            return 0.0
        if op.kind == "dot" and name in vmem_dots:
            return 0.0                          # stays in VMEM (flash)
        if op.kind in _CHAIN_SOURCES:
            return comp.sym_bytes.get(name, 0)
        if op.kind in _TRANSPARENT:
            return sum(source_bytes(o, depth + 1, seen) for o in op.operands)
        return comp.sym_bytes.get(name, 0)      # dot/gather/etc: real buffer

    SMALL = 4 << 20     # online-softmax stats (m, l) are register-resident
                        # in the flash kernel; a path ending in a small
                        # reduction does not force the big tensor to HBM

    def feeds_only_dots(name, depth=0, seen=None):
        seen = seen if seen is not None else set()
        if name in seen or depth > 24:
            return False
        seen.add(name)
        if name == root:
            return False
        cons = consumers.get(name, [])
        if not cons:
            return False
        for c in cons:
            if c.kind == "dot":
                continue
            if c.kind in _TRANSPARENT:
                if comp.sym_bytes.get(c.name, 0) <= SMALL:
                    continue                     # shrinks to stats: fine
                if not feeds_only_dots(c.name, depth + 1, seen):
                    return False
            elif comp.sym_bytes.get(c.name, 0) <= SMALL:
                continue
            else:
                return False
        return True

    for op in comp.ops:
        if op.kind == "dot" and feeds_only_dots(op.name):
            vmem_dots.add(op.name)

    out: Dict[str, float] = {}
    for op in comp.ops:
        if op.kind == "dot":
            charge = 0.0
            for o in op.operands:
                ob = comp.sym_bytes.get(o, 0)
                sb = source_bytes(o)
                charge += min(ob, sb) if sb > 0 else ob
            if op.name not in vmem_dots:
                charge += op.result_bytes
            out[op.name] = charge
        elif op.kind in COLLECTIVES or op.kind.endswith("-start"):
            # f32 converts inserted by the CPU dot-promotion pipeline can
            # land BEFORE a collective; on TPU the wire payload is the
            # bf16 source.  Scale the moved bytes by source/operand.
            if op.operands:
                o = op.operands[0]
                ob = comp.sym_bytes.get(o, 0)
                sb = source_bytes(o)
                if 0 < sb < ob:
                    out[op.name] = sb / ob      # shrink factor
    return out


@dataclass
class CostReport:
    flops: float = 0.0                       # dot flops, per device
    traffic_bytes: float = 0.0               # HBM traffic proxy, per device
    traffic_bytes_raw: float = 0.0           # unfused (CPU-HLO) proxy
    coll_raw: Dict[str, float] = field(default_factory=dict)
    coll_transfer: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, float] = field(default_factory=dict)
    top_collectives: List[dict] = field(default_factory=list)
    top_dots: List[dict] = field(default_factory=list)
    top_traffic: List[dict] = field(default_factory=list)

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_transfer.values())

    def to_dict(self) -> dict:
        return {"flops": self.flops, "traffic_bytes": self.traffic_bytes,
                "traffic_bytes_raw": self.traffic_bytes_raw,
                "coll_raw": self.coll_raw, "coll_transfer": self.coll_transfer,
                "coll_count": self.coll_count,
                "top_collectives": self.top_collectives[:12],
                "top_dots": self.top_dots[:12],
                "top_traffic": self.top_traffic[:12]}


def _min_source_bytes(comp: "Computation", name: str, depth=0,
                      seen=None) -> Optional[float]:
    """Smallest non-transparent source feeding ``name`` — the information
    content of an in-place update (a one-row cache write shows up as a
    shard-sized select; its smallest real source is the row)."""
    seen = seen if seen is not None else set()
    if name in seen or depth > 16:
        return None
    seen.add(name)
    producers = getattr(comp, "_producers", None)
    if producers is None:
        producers = {op.name: op for op in comp.ops}
        comp._producers = producers
    op = producers.get(name)
    if op is None:
        return None
    if op.kind in _TRANSPARENT and op.operands:
        vals = [_min_source_bytes(comp, o, depth + 1, seen)
                for o in op.operands]
        vals = [v for v in vals if v is not None and v > 64]
        return min(vals) if vals else None
    b = comp.sym_bytes.get(name, 0)
    return b if b > 64 else None


def analyze(hlo: str, n_devices: int,
            fusion_model: str = "chain") -> CostReport:
    """fusion_model: "chain" (TPU operand/epilogue-fusion model, default) |
    "basic" (dots at face value)."""
    comps, entry = parse_module(hlo)
    rep = CostReport()
    if entry is None:
        return rep
    colls: List[dict] = []
    dots: List[dict] = []
    chain_cache: Dict[str, Dict[str, float]] = {}

    def chain_for(comp):
        if comp.name not in chain_cache:
            chain_cache[comp.name] = (_chain_dot_traffic(comp)
                                      if fusion_model == "chain" else {})
        return chain_cache[comp.name]

    # multiplicity propagation (entry = 1); memoized on (comp, mult) sums
    mult: Dict[str, float] = {}

    def visit(comp_name: str, m: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        mult[comp_name] = mult.get(comp_name, 0.0) + m
        for op in comp.ops:
            if op.kind == "dot":
                fl = _dot_flops(comp, op) * m
                rep.flops += fl
                dots.append({"flops": fl, "mult": m,
                             "shape": op.line.split(" dot(")[0].split("= ")[-1]})
            if op.kind in COLLECTIVES or any(
                    op.kind == c + "-start" for c in COLLECTIVES):
                kind = op.kind.replace("-start", "")
                size = op.result_bytes
                shrink = chain_for(comp).get(op.name)
                if isinstance(shrink, float) and shrink <= 1.0:
                    size = size * shrink        # bf16-source wire payload
                n = _group_size(op.line, n_devices)
                if n > 1:
                    ring = (n - 1) / n
                    if kind == "all-reduce":
                        moved = 2 * ring * size
                    elif kind == "reduce-scatter":
                        moved = ring * size * n
                    elif kind in ("all-gather", "all-to-all"):
                        moved = ring * size
                    else:
                        moved = size
                    rep.coll_raw[kind] = rep.coll_raw.get(kind, 0) + size * m
                    rep.coll_transfer[kind] = (rep.coll_transfer.get(kind, 0)
                                               + moved * m)
                    rep.coll_count[kind] = rep.coll_count.get(kind, 0) + m
                    colls.append({"kind": kind, "bytes": size,
                                  "moved": moved * m, "mult": m, "n": n})
            if op.kind not in _SKIP_TRAFFIC:
                if op.kind in ("dynamic-update-slice", "scatter"):
                    # in-place update: traffic = update operand, not the
                    # full buffer (else scanned grad accumulators count at
                    # buffer-size x trip-count)
                    upd = (comp.sym_bytes.get(op.operands[1], 0)
                           if len(op.operands) > 1 else 0)
                    traffic = 2 * upd               # read-modify-write
                    # fused model: the true update region is the SMALLEST
                    # real source (GSPMD rewrites one-row cache updates
                    # into shard-sized selects; kernels/cache_update
                    # realizes the row write on TPU)
                    if fusion_model == "chain" and len(op.operands) > 1:
                        ms = _min_source_bytes(comp, op.operands[1])
                        fused_traffic = 2 * ms if ms else traffic
                    else:
                        fused_traffic = traffic
                elif op.kind == "while":
                    traffic = fused_traffic = 0     # carries counted in body
                elif (op.kind == "fusion"
                      and "dynamic-update-slice" in op.name):
                    # fusion with in-place DUS root: writes only the update
                    # region; reads = the non-buffer operands
                    ob = sorted((comp.sym_bytes.get(o, 0)
                                 for o in op.operands), reverse=True)
                    traffic = 2 * sum(ob[1:])       # drop the aliased buffer
                    if fusion_model == "chain":
                        ms = [_min_source_bytes(comp, o)
                              for o in op.operands]
                        ms = [v for v in ms if v]
                        fused_traffic = 2 * min(ms) if ms else traffic
                    else:
                        fused_traffic = traffic
                else:
                    traffic = op.result_bytes
                    if op.kind in ("dot", "fusion", "custom-call"):
                        traffic += sum(comp.sym_bytes.get(o, 0)
                                       for o in op.operands)
                    # TPU-fusion model: elementwise chains / small fusions
                    # melt into their matmul producers/consumers; only ops
                    # that MUST materialize count (dots, gathers,
                    # collectives, layout ops at module edges).
                    if op.kind == "dot":
                        fused_traffic = chain_for(comp).get(op.name, traffic)
                    elif op.kind in ("custom-call", "gather", "all-to-all"):
                        fused_traffic = traffic
                    elif (op.kind in COLLECTIVES
                          or op.kind.endswith("-start")):
                        fused_traffic = op.result_bytes
                    else:
                        fused_traffic = 0
                rep.traffic_bytes += fused_traffic * m
                rep.traffic_bytes_raw += traffic * m
                if fused_traffic * m > 0:
                    heavy.append({"kind": op.kind,
                                  "bytes": fused_traffic * m,
                                  "mult": m, "name": op.name})
            for callee in op.called:
                visit(callee, m * op.trip)

    heavy: List[dict] = []
    visit(entry, 1.0)
    rep.top_collectives = sorted(colls, key=lambda d: -d["moved"])[:20]
    rep.top_dots = sorted(dots, key=lambda d: -d["flops"])[:20]
    rep.top_traffic = sorted(heavy, key=lambda d: -d["bytes"])[:20]
    return rep
