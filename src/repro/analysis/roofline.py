"""Three-term roofline from a compiled (dry-run) artifact — no wall clock.

    compute    = HLO_FLOPs_total      / (chips * PEAK_FLOPS)
    memory     = HLO_bytes_total      / (chips * HBM_BW)
    collective = per-chip ICI bytes   /  LINK_BW

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed) — on an SPMD-
partitioned module these are *per-device* numbers, so totals are x chips.
Collective bytes are NOT in cost_analysis: we parse the *post-partitioning*
optimized HLO (``compiled.as_text()``) and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
weighted by the ring-transfer factor for the op's replica-group size n:

    all-reduce      2 (n-1)/n      (reduce-scatter + all-gather ring)
    all-gather        (n-1)/n   of the gathered output
    reduce-scatter    (n-1)/n   of the scattered input (= out * n)
    all-to-all        (n-1)/n
    collective-permute  1

Hardware constants per the brief: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every 'dtype[a,b,c]' in a result-shape string (tuples
    for -start ops)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        nbytes = _DTYPE_BYTES[dt]
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))             # [n_groups, group_size]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


@dataclass
class CollectiveStats:
    """Per-op-type raw result bytes and ring-weighted transfer bytes
    (both per device, since the module is the per-device program)."""
    raw_bytes: Dict[str, int] = field(default_factory=dict)
    transfer_bytes: Dict[str, int] = field(default_factory=dict)
    count: Dict[str, int] = field(default_factory=dict)

    @property
    def total_transfer(self) -> int:
        return sum(self.transfer_bytes.values())

    @property
    def total_raw(self) -> int:
        return sum(self.raw_bytes.values())


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done" in line.split("=")[1][:40]:
            continue
        size = _shape_bytes(shape_str)
        n = _group_size(line, n_devices)
        if n <= 1:
            continue
        ring = (n - 1) / n
        if kind == "all-reduce":
            moved = 2 * ring * size
        elif kind == "all-gather":
            moved = ring * size                     # result is gathered size
        elif kind == "reduce-scatter":
            moved = ring * size * n                 # result is scattered size
        elif kind == "all-to-all":
            moved = ring * size
        else:                                       # collective-permute
            moved = size
        stats.raw_bytes[kind] = stats.raw_bytes.get(kind, 0) + size
        stats.transfer_bytes[kind] = (stats.transfer_bytes.get(kind, 0)
                                      + int(moved))
        stats.count[kind] = stats.count.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    model_flops: float                  # 6*N*D (train) / 2*N*D (serve)
    n_params: int
    n_params_active: int
    memory_per_device: Optional[float] = None   # from memory_analysis()
    attn_flops: float = 0.0             # causal-minimum attention FLOPs
    ideal_bytes: float = 0.0            # decode: weights+state stream floor

    # ---- three terms, in seconds ----
    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective.total_transfer / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu_flops(self) -> float:
        """MODEL_FLOPS + attention (standard MFU accounting)."""
        return self.model_flops + self.attn_flops

    @property
    def roofline_fraction(self) -> float:
        """useful-compute-time / achievable step time.  For decode cells
        the floor is BANDWIDTH (weights+state must stream per token), so
        the numerator is max(compute floor, bandwidth floor)."""
        t_star = self.mfu_flops / (self.n_devices * PEAK_FLOPS)
        t_bw = self.ideal_bytes / (self.n_devices * HBM_BW)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return max(t_star, t_bw) / t_bound if t_bound else 0.0

    @property
    def bw_roofline_fraction(self) -> Optional[float]:
        """Decode: how close the step is to the weight/state-streaming
        bandwidth floor (the serving-side roofline)."""
        if not self.ideal_bytes:
            return None
        t_bw = self.ideal_bytes / (self.n_devices * HBM_BW)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_bw / t_bound if t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_raw_bytes": self.collective.raw_bytes,
            "collective_transfer_bytes": self.collective.transfer_bytes,
            "collective_count": self.collective.count,
            "memory_per_device": self.memory_per_device,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "attn_flops": self.attn_flops,
            "ideal_bytes": self.ideal_bytes,
            "n_params": self.n_params,
            "n_params_active": self.n_params_active,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_flops_ratio": (self.mfu_flops
                                / (self.flops_per_device * self.n_devices)
                                if self.flops_per_device else 0.0),
            "roofline_fraction": self.roofline_fraction,
            "bw_roofline_fraction": self.bw_roofline_fraction,
        }

    def summary(self) -> str:
        c = self.collective
        return (f"[{self.arch} x {self.shape} x {self.mesh}] "
                f"t_comp={self.t_compute*1e3:.2f}ms "
                f"t_mem={self.t_memory*1e3:.2f}ms "
                f"t_coll={self.t_collective*1e3:.2f}ms "
                f"bound={self.bottleneck} "
                f"useful={self.useful_flops_ratio:.2%} "
                f"roofline={self.roofline_fraction:.2%} "
                f"coll_ops={sum(c.count.values())}")


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def model_flops_for(cfg, cell) -> float:
    """The brief's MODEL_FLOPS: 6*N*D (train) / 2*N*D (serve), N active."""
    n_active = cfg.n_active_params()
    tokens = cell.tokens if cell.kind != "decode" else cell.global_batch
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_active * tokens


def attn_flops_for(cfg, cell) -> float:
    """Causal-minimum attention matmul FLOPs (QK^T + PV), the extra term
    standard MFU accounting adds to 6*N*D — without it, 32k-prefill cells
    read as 'waste' when the compute is real attention work."""
    B, S = cell.global_batch, cell.seq_len
    H, hd = cfg.n_heads, cfg.hd
    if cfg.family == "ssm":
        return 0.0
    n_attn = cfg.n_layers
    if cfg.hybrid_group:
        n_attn = cfg.n_layers // cfg.hybrid_group    # 1 attn per group
    mult = {"train": 3.0, "prefill": 1.0}.get(cell.kind, 0.0)
    fl = mult * 2.0 * B * (S ** 2) * H * hd * n_attn  # causal: S^2 (not 2S^2)
    if cell.kind == "decode":
        fl = 4.0 * B * S * H * hd * n_attn
    if cfg.encdec:
        T = cfg.enc_seq_len if cell.kind != "train" else S
        enc = {"train": 6.0, "prefill": 0.0, "decode": 0.0}[cell.kind] \
            * B * (T ** 2) * H * hd * cfg.n_enc_layers
        cross_tokens = S if cell.kind != "decode" else 1
        cross = ({"train": 6.0, "prefill": 2.0, "decode": 2.0}[cell.kind]
                 * B * cross_tokens * T * H * hd * cfg.n_layers)
        fl += enc + cross
    return fl


def ideal_serve_bytes(cfg, cell) -> float:
    """Decode bandwidth floor: every generated token must stream the
    active weights + the live decode state through HBM once."""
    if cell.kind != "decode":
        return 0.0
    B, S = cell.global_batch, cell.seq_len
    wbytes = cfg.n_active_params() * 2              # bf16
    n_attn = cfg.n_layers
    if cfg.hybrid_group:
        n_attn = cfg.n_layers // cfg.hybrid_group
    if cfg.family == "ssm":
        n_attn = 0
    kv = n_attn * B * S * cfg.n_kv_heads * cfg.hd * 2 * 2
    ssm = 0.0
    if cfg.ssm is not None:
        n_ssm = (cfg.n_layers - n_attn) if cfg.hybrid_group else cfg.n_layers
        d_inner = cfg.ssm.expand * cfg.d_model
        Hm = d_inner // cfg.ssm.head_dim
        ssm = n_ssm * B * Hm * cfg.ssm.head_dim * cfg.ssm.d_state * 4
    if cfg.encdec:
        kv += cfg.n_layers * B * cfg.enc_seq_len * cfg.n_kv_heads \
            * cfg.hd * 2 * 2
    return wbytes + kv + ssm


def build(arch, shape, mesh_name, n_devices, compiled, cfg, cell,
          mem_per_device=None, extra=None) -> Roofline:
    """Roofline from the trip-count-aware HLO cost model (hlo_cost).

    ``cost_analysis()`` counts while-loop bodies once and is kept only as a
    cross-check field; the primary numbers come from walking the partitioned
    HLO with known_trip_count multiplicities."""
    from repro.analysis import hlo_cost
    hlo = compiled.as_text()
    rep = hlo_cost.analyze(hlo, n_devices)
    ca = cost_dict(compiled)
    stats = CollectiveStats(
        raw_bytes={k: int(v) for k, v in rep.coll_raw.items()},
        transfer_bytes={k: int(v) for k, v in rep.coll_transfer.items()},
        count={k: int(v) for k, v in rep.coll_count.items()})
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=rep.flops,
        bytes_per_device=rep.traffic_bytes,
        collective=stats,
        model_flops=model_flops_for(cfg, cell),
        attn_flops=attn_flops_for(cfg, cell),
        ideal_bytes=ideal_serve_bytes(cfg, cell),
        n_params=cfg.n_params(),
        n_params_active=cfg.n_active_params(),
        memory_per_device=mem_per_device,
    )
    if extra is not None:
        extra["cost_analysis_flops"] = float(ca.get("flops", 0.0))
        extra["cost_analysis_bytes"] = float(ca.get("bytes accessed", 0.0))
        extra["traffic_bytes_raw"] = rep.traffic_bytes_raw
        extra["top_collectives"] = rep.top_collectives[:12]
        extra["top_dots"] = rep.top_dots[:8]
        extra["top_traffic"] = rep.top_traffic[:12]
    return r
