"""Compile-time analysis: roofline terms from the dry-run artifact, and the
paper's power/energy model derived from them."""
