"""Request slot classes for the class-partitioned TABM pool.

The single-ring TABM (core/tabm.RingBuffer) sizes every slot to one
``max_tokens`` slab, so a 1-image thumbnail request pads into the same
slab as a 4-image full-resolution request and competes with it for the
same FIFO admission depth — exactly the modality-inflation cost the
multimodal-serving literature measures (vision token count varies by
orders of magnitude across requests, decode demand does not).

This module defines the *classes* that partition the pool:

* a **resolution bucket** is a per-image token count, taken from the
  arch's config (``ModelConfig.vision_token_buckets``; falls back to one
  bucket = ``vision_tokens``) — the paper's static-shape NPU discipline
  means resolutions are already quantized to a small bucket set;
* an **image-count bucket** is 1 or ``vision_max_images`` — single-image
  chat turns vs multi-image / tiled (anyres) requests;
* a :class:`SlotClass` is one (image bucket × resolution bucket) cell,
  owning its own ring capacity (``n_slots``) and admission depth
  (``max_ahead``; ``None`` = ring capacity, the
  ``core/scheduler.staging_budget`` default).

:func:`classify` maps a request's vision spec — total token count and
image count — to the smallest class that fits it, so every request pays
for exactly the slab shape it needs.  The pool wrapper that instantiates
one :class:`~repro.core.tabm.RingBuffer` per class lives in
``core/tabm.SlotClassPool``; battery-aware per-class depth scaling is
:meth:`~repro.core.tabm.SlotClassPool.admission_table` driven by
``core/power.Knobs.class_depth_scale``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig


class SlotClassError(ValueError):
    """A vision spec that no configured slot class can hold."""


@dataclass(frozen=True)
class SlotClass:
    """One request class of the partitioned TABM pool."""

    name: str
    n_images: int              # image-count bucket (inclusive upper bound)
    tokens_per_image: int      # resolution bucket (inclusive upper bound)
    n_slots: int               # ring capacity for this class
    max_ahead: Optional[int] = None    # admission depth; None = n_slots
                                       # (staging_budget's own default)

    @property
    def max_tokens(self) -> int:
        """The class-sized slab: what one ring slot of this class holds."""
        return self.n_images * self.tokens_per_image

    @property
    def sort_key(self) -> Tuple[int, int]:
        return (self.max_tokens, self.n_images)


def resolution_buckets(cfg: ModelConfig) -> Tuple[int, ...]:
    """Per-image token counts of the arch's resolution buckets, ascending.
    Falls back to a single full-resolution bucket (``vision_tokens``)."""
    if cfg.vision_token_buckets:
        return tuple(sorted(set(cfg.vision_token_buckets)))
    return (max(1, cfg.vision_tokens),)


def image_buckets(cfg: ModelConfig) -> Tuple[int, ...]:
    """Image-count buckets: single-image, plus the arch's multi-image cap."""
    if cfg.vision_max_images <= 1:
        return (1,)
    return (1, cfg.vision_max_images)


def build_slot_classes(cfg: ModelConfig, slots_per_class: int = 2
                       ) -> Dict[str, SlotClass]:
    """The arch's class table: image buckets × resolution buckets, ordered
    smallest slab first (the ordering battery-aware depth scaling uses —
    high-resolution classes shrink first)."""
    if not cfg.vlm:
        raise SlotClassError(f"{cfg.name}: slot classes are a vlm concept")
    classes = [
        SlotClass(name=f"{ni}img-{tpi}tok", n_images=ni,
                  tokens_per_image=tpi, n_slots=max(1, slots_per_class))
        for ni in image_buckets(cfg)
        for tpi in resolution_buckets(cfg)
    ]
    classes.sort(key=lambda c: c.sort_key)
    return {c.name: c for c in classes}


def shed_scales(names_ascending, scale: float) -> Dict[str, float]:
    """Per-class effective scale factors under one battery scale in [0, 1]:
    ``names_ascending`` is the class table in ascending slab order, the
    largest class shrinks fully by ``scale``, the smallest keeps 1.0, and
    intermediate classes interpolate linearly — high-resolution sheds
    first.  This is THE shed ordering, shared by staged-ahead depth
    scaling (``core/tabm.SlotClassPool.admission_table`` driven by
    ``Knobs.class_depth_scale``) and paged-KV block budgeting
    (``core/scheduler.kv_block_budgets`` driven by
    ``Knobs.class_kv_scale``), so battery pressure degrades staging and
    decode memory in the same class order."""
    s = min(1.0, max(0.0, scale))
    names = list(names_ascending)
    K = len(names)
    return {name: 1.0 - (1.0 - s) * (rank / (K - 1) if K > 1 else 0.0)
            for rank, name in enumerate(names)}


def classify(classes: Dict[str, SlotClass], n_tokens: int,
             n_images: int = 1) -> SlotClass:
    """Map a request's vision spec to the smallest class that holds it.

    ``n_tokens`` is the request's total vision token count; the per-image
    resolution is ``ceil(n_tokens / n_images)``.  Raises
    :class:`SlotClassError` when no class fits (more images or higher
    resolution than the config declares)."""
    if n_tokens <= 0 or n_images <= 0:
        raise SlotClassError(
            f"vision spec needs positive tokens/images, got "
            f"{n_tokens} tokens x {n_images} images")
    tpi = -(-n_tokens // n_images)             # ceil division
    fits = [c for c in classes.values()
            if c.n_images >= n_images and c.tokens_per_image >= tpi
            and c.max_tokens >= n_tokens]
    if not fits:
        raise SlotClassError(
            f"no slot class holds {n_tokens} tokens across {n_images} "
            f"image(s) (per-image {tpi}); classes: "
            f"{[c.name for c in classes.values()]}")
    return min(fits, key=lambda c: c.sort_key)


def classify_total(classes: Dict[str, SlotClass], n_tokens: int) -> SlotClass:
    """Class lookup by total token count only (image count unknown — the
    synchronous ``plan.run`` path, which sees the embeds after the fact)."""
    fits = [c for c in classes.values() if c.max_tokens >= n_tokens]
    if not fits:
        raise SlotClassError(
            f"no slot class holds {n_tokens} tokens; classes: "
            f"{[c.name for c in classes.values()]}")
    return min(fits, key=lambda c: c.sort_key)
