"""On-Demand Cascade Inference (paper §3.2, Fig. 2).

In Critical Conservation mode the system becomes event-triggered and
strictly sequential: each brick follows "load -> execute -> release" — it is
loaded, performs its task, is released, and passes only the *minimal* output
(a text string or an embedding vector) to the next stage: "a lightweight,
domino-like chain" whose peak memory is max(brick) instead of sum(bricks).

Implementation: brick params live host-side (numpy); ``run_once`` device_puts
one brick's params, applies it, then deletes the device buffers before the
next brick loads.  A high-water-mark tracker proves the max-not-sum claim
(benchmarks/fig8_power.py and tests/test_cascade.py assert it).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bricks import Brick, BrickGraph


def _nbytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size"):
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    return total


@dataclass
class CascadeEvent:
    brick: str
    phase: str                 # load | execute | release
    t: float
    resident_bytes: int


@dataclass
class CascadeTrace:
    events: List[CascadeEvent] = field(default_factory=list)
    peak_bytes: int = 0
    sum_bytes: int = 0         # what a monolithic load would have held

    def record(self, brick, phase, resident):
        self.events.append(CascadeEvent(brick, phase, time.time(), resident))
        self.peak_bytes = max(self.peak_bytes, resident)


class CascadeRunner:
    """Event-triggered sequential pipeline over a BrickGraph."""

    def __init__(self, graph: BrickGraph, host_params: Dict[str, Any]):
        """host_params: the full param pytree as HOST (numpy) arrays —
        cascade mode keeps nothing resident between events."""
        self.graph = graph
        self.host_params = jax.tree.map(np.asarray, host_params)
        self.cfg = graph.cfg

    def _load(self, brick: Brick):
        sub = brick.params_of(self.host_params)
        return jax.tree.map(jnp.asarray, sub)

    def run_once(self, inputs: Dict[str, Any],
                 trace: Optional[CascadeTrace] = None) -> Any:
        """One event-triggered inference pass: embed -> decoder -> head
        (plus frontend/projector/encoder bricks when the arch has them).
        Returns final logits."""
        trace = trace if trace is not None else CascadeTrace()
        trace.sum_bytes = _nbytes(self.host_params)
        resident = 0
        x: Any = None
        vision_embeds = None
        enc_out = None

        for brick in self.graph.bricks:
            dev_params = self._load(brick)
            resident += _nbytes(dev_params)
            trace.record(brick.name, "load", resident)

            if brick.kind == "frontend":
                out = inputs.get("vision_feats", inputs.get("src_embeds"))
            elif brick.kind == "projector":
                vision_embeds = brick.apply(dev_params, self.cfg,
                                            inputs["vision_feats"])
                out = vision_embeds
            elif brick.kind == "encoder":
                enc_out = brick.apply(dev_params, self.cfg,
                                      inputs["src_embeds"])
                out = enc_out
            elif brick.kind == "embed":
                tok = inputs.get("tokens", inputs.get("tgt_tokens"))
                x = brick.apply(dev_params, self.cfg, tok, vision_embeds)
                out = x
            elif brick.kind == "decoder":
                if self.cfg.encdec:
                    # enc-dec decoder consumes x from the embed brick
                    x = self._encdec_decoder(dev_params, x, enc_out)
                else:
                    x = brick.apply(dev_params, self.cfg, x)
                out = x
            else:  # head
                out = brick.apply(dev_params, self.cfg, x)
            out = jax.block_until_ready(out)
            trace.record(brick.name, "execute", resident)

            # release: only `out` survives to the next stage
            for leaf in jax.tree.leaves(dev_params):
                if hasattr(leaf, "delete"):
                    try:
                        leaf.delete()
                    except Exception:
                        pass
            resident -= _nbytes(dev_params)
            trace.record(brick.name, "release", resident)
            del dev_params
        return out, trace

    def _encdec_decoder(self, dev_params, x, enc_out):
        from repro.models import attention as attn
        from repro.models import mlp as mlp_mod
        from repro.models.common import apply_norm, apply_rope, \
            default_positions
        from repro.models.encdec import _dec_layer_full
        cfg = self.cfg
        B, S, _ = x.shape
        rope_fn = lambda t: apply_rope(t, default_positions(B, S),
                                       cfg.rope_theta)

        def body(xc, lp):
            xc, _ = _dec_layer_full(cfg, lp, xc, enc_out, rope_fn, False, 0)
            return xc, None

        x, _ = jax.lax.scan(body, x, dev_params["dec_layers"])
        return x
