"""On-Demand Cascade Inference (paper §3.2, Fig. 2).

In Critical Conservation mode the system becomes event-triggered and
strictly sequential: each brick follows "load -> execute -> release" — it is
loaded, performs its task, is released, and passes only the *minimal* output
(a text string or an embedding vector) to the next stage: "a lightweight,
domino-like chain" whose peak memory is max(brick) instead of sum(bricks).

The cascade is now a *backend strategy*, not an interpreter: it compiles
the BrickGraph with :func:`repro.core.plan.compile_plan` lowering every
brick through the transient ``HostBackend`` (``residency="one-brick"`` is
the same lowering) — brick params live host-side (numpy) and every
``run_once`` loads one brick, applies it through the same jit-cached
callable the serving engine uses, then deletes the device buffers before
the next brick loads.  There is no per-kind dispatch here; the dataflow is
the bricks' declared ports.  The high-water-mark trace proves the
max-not-sum claim (benchmarks/fig8_power.py and tests/test_cascade.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.bricks import BrickGraph
from repro.core.plan import PlanEvent, PlanTrace, compile_plan

# historical names, still the public API of this module
CascadeEvent = PlanEvent
CascadeTrace = PlanTrace


class CascadeRunner:
    """Event-triggered sequential pipeline over a BrickGraph: a thin
    HostBackend (transient, load->execute->release) lowering of the
    shared ExecutionPlan."""

    def __init__(self, graph: BrickGraph, host_params: Dict[str, Any]):
        """host_params: the full param pytree — held HOST-side (numpy) by
        the plan; cascade mode keeps nothing resident between events."""
        self.graph = graph
        self.cfg = graph.cfg
        self.plan = compile_plan(graph, host_params, backend="host",
                                 residency="one-brick")

    def run_once(self, inputs: Dict[str, Any],
                 trace: Optional[CascadeTrace] = None):
        """One event-triggered inference pass through every brick.
        Returns (final logits, residency trace)."""
        return self.plan.run(inputs, trace=trace)
