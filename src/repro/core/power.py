"""Battery-aware execution (paper §3.2 "Power-efficiency Strategy").

The three-state PMU-driven policy, verbatim from the paper:

  (i)   Unconstrained Performance  (B > T_high): full capacity, aggressive
        parallel offloading.
  (ii)  Proportional Throttling    (T_low < B <= T_high): graceful
        degradation with alpha = (B - T_low) / (T_high - T_low) linearly
        interpolating camera frame rate and memory read/write rate.
  (iii) Critical Conservation      (B <= T_low): switch to the On-Demand
        Cascade (sequential load->execute->release, core/cascade.py).

TPU adaptation: "camera FPS / memory clocks" become the serving knobs we
actually have — admission rate (requests/s), max batch, and submesh width —
scaled by the same alpha.  The PMU is simulated from the energy model
(analysis/energy.py) since the container has no hardware counters.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


class PowerState(enum.Enum):
    UNCONSTRAINED = "unconstrained"
    THROTTLED = "throttled"
    CRITICAL = "critical"


@dataclass
class PMU:
    """Simulated power-management unit: integrates modeled joules into a
    battery state-of-charge, the signal the policy arbitrates on."""

    battery_mah: float = 2000.0
    volts: float = 3.7
    level: float = 1.0                       # state of charge, 0..1
    history: List[Tuple[float, float]] = field(default_factory=list)
    _t: float = 0.0

    @property
    def capacity_j(self) -> float:
        return self.battery_mah / 1000.0 * self.volts * 3600.0

    def drain(self, joules: float, dt: float = 0.0):
        self.level = max(0.0, self.level - joules / self.capacity_j)
        self._t += dt
        self.history.append((self._t, joules / max(dt, 1e-9) if dt else 0.0))

    def sample_watts(self) -> float:
        return self.history[-1][1] if self.history else 0.0


@dataclass(frozen=True)
class Knobs:
    """Execution knobs one policy state implies."""
    max_batch: int
    admission_rate: float        # fraction of offered requests admitted
    frame_rate_hz: float         # camera-equivalent input rate
    mem_clock_scale: float       # paper's memory read/write rate scale
    submesh_width: float         # fraction of the pod's "model" axis to use
    cascade: bool                # critical mode: one-shot sequential
    # re-lowering hook: backend registry name (core/backends) the holder of
    # an ExecutionPlan should relower static-shape (encoder-side) bricks
    # to, or None to keep/restore the compiled placement.  Deep THROTTLED
    # demotes to the transient HostBackend — encoder weights leave the
    # accelerator between events, trading latency for resident memory and
    # accelerator energy exactly like the paper's proportional throttling
    # of the camera/memory path.  The engine applies it via plan.relower().
    backend_demotion: Optional[str] = None
    # class-partitioned TABM admission hook: scale factor for per-class
    # staged-ahead depth (core/tabm.SlotClassPool.admission_table).
    # THROTTLED shrinks the *high-resolution* classes' depth first (the
    # largest slab scales fully by this factor, the thumbnail class keeps
    # full depth), so expensive multi-image vision staging is the first
    # load shed while cheap requests keep flowing; CRITICAL gates the
    # large classes entirely (scale 0).  Restored to 1.0 when charge
    # recovers — mirrors backend_demotion.
    class_depth_scale: float = 1.0
    # batched-staging hook: how many same-class requests the engine may
    # hand a class's producer thread as ONE microbatch (one batched
    # projector call + one strided slab commit).  Scaled down FIRST under
    # THROTTLED — losing batch amortization costs energy-per-stage but
    # keeps every class's staging depth, so the pipeline degrades to
    # one-at-a-time staging before it starts shedding whole classes
    # (class_depth_scale): batch is floored at 1 by alpha = 0.5 while the
    # depth scale is still at 0.5.  CRITICAL stages strictly one request
    # at a time.
    max_stage_batch: int = 1
    # paged-KV admission hook: scale factor for per-class KV *block*
    # budgets (core/scheduler.kv_block_budgets over the engine's
    # PagedKVCache).  Same high-resolution-first shed order as
    # class_depth_scale (core/slot_classes.shed_scales): under THROTTLED
    # the hi-res classes' share of the paged decode pool shrinks first,
    # so expensive long-context KV grants are shed while thumbnail
    # requests keep admitting; CRITICAL zeroes the large classes' share.
    class_kv_scale: float = 1.0


@dataclass
class PowerPolicy:
    t_high: float = 0.60
    t_low: float = 0.20
    full_batch: int = 128
    full_fps: float = 30.0
    full_stage_batch: int = 4          # staging microbatch at full charge

    def state(self, battery: float) -> PowerState:
        if battery > self.t_high:
            return PowerState.UNCONSTRAINED
        if battery > self.t_low:
            return PowerState.THROTTLED
        return PowerState.CRITICAL

    def alpha(self, battery: float) -> float:
        """The paper's scaling factor, clamped to [0, 1]."""
        a = (battery - self.t_low) / (self.t_high - self.t_low)
        return min(1.0, max(0.0, a))

    def knobs(self, battery: float) -> Knobs:
        st = self.state(battery)
        if st is PowerState.UNCONSTRAINED:
            return Knobs(self.full_batch, 1.0, self.full_fps, 1.0, 1.0,
                         cascade=False,
                         max_stage_batch=self.full_stage_batch)
        if st is PowerState.THROTTLED:
            a = self.alpha(battery)
            # batch shrinks BEFORE depth sheds: the stage microbatch
            # scales by (2a - 1), hitting 1 at alpha 0.5 while
            # class_depth_scale (= a) is still 0.5 — amortization is the
            # cheapest thing to give up, whole classes the last
            return Knobs(max(1, int(self.full_batch * a)),
                         admission_rate=a,
                         frame_rate_hz=max(1.0, self.full_fps * a),
                         mem_clock_scale=max(0.25, a),
                         submesh_width=max(0.25, a),
                         cascade=False,
                         backend_demotion="host" if a < 0.5 else None,
                         class_depth_scale=a,
                         max_stage_batch=max(1, int(
                             self.full_stage_batch * max(0.0, 2 * a - 1))),
                         class_kv_scale=a)
        return Knobs(1, admission_rate=0.0, frame_rate_hz=0.0,
                     mem_clock_scale=0.25, submesh_width=0.25, cascade=True,
                     backend_demotion="host", class_depth_scale=0.0,
                     max_stage_batch=1, class_kv_scale=0.0)


@dataclass
class BatteryAwareExecutor:
    """Glue: reads the PMU, exposes the knobs + the scheduler objective.

    Objective flips from latency to energy as charge drops — the paper's
    'arbitrates the trade-off between performance and longevity'."""

    pmu: PMU
    policy: PowerPolicy = field(default_factory=PowerPolicy)

    def current(self) -> Tuple[PowerState, Knobs, str]:
        b = self.pmu.level
        st = self.policy.state(b)
        objective = "latency" if st is PowerState.UNCONSTRAINED else "energy"
        return st, self.policy.knobs(b), objective
