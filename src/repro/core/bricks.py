"""Model decomposition into "bricks" (paper §3.1).

NANOMIND's first insight: LMMs are inherently modular — vision encoder,
projector, multimodal embedding, language decoder, audio encoder — and the
modules can be *decoupled and executed independently*, each on the hardware
that suits it.  A :class:`Brick` is one such unit: it owns a subset of the
parameter pytree, exposes a pure apply function, and carries the metadata
the scheduler needs (compute/memory footprints, static-shape discipline,
quantization label).

``decompose(cfg)`` builds the BrickGraph for any assigned arch:

    vlm:     vision_frontend* -> projector -> embed -> decoder -> head
    audio:   audio_frontend* -> encoder -> embed -> decoder -> head
    lm:      embed -> decoder -> head          (*frontends are stubs)

Bricks are the unit of: placement (core/scheduler), zero-copy hand-off
(core/tabm), sequential low-power execution (core/cascade), and hybrid
quantization (core/quantize policies use brick names as path prefixes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Brick:
    """One independently executable module."""

    name: str
    kind: str                       # frontend | encoder | projector | embed
                                    # | decoder | head
    param_keys: Tuple[str, ...]     # top-level params entries this brick owns
    apply: Callable                 # (params_slice, cfg, *inputs) -> outputs
    static_shape: bool = False      # paper §NPU: fixed input shapes only
    quant_label: str = "bf16"       # default per-brick precision (Fig. 7)
    flops_per_token: float = 0.0    # scheduler cost model inputs
    param_bytes: int = 0

    def params_of(self, params: Dict[str, Any]) -> Dict[str, Any]:
        return {k: params[k] for k in self.param_keys if k in params}


@dataclass
class BrickGraph:
    """Linear chain of bricks (the LMM pipelines are chains; the graph type
    still records explicit edges so the scheduler/TABM can treat producer
    -> consumer pairs uniformly)."""

    cfg: ModelConfig
    bricks: List[Brick]

    @property
    def edges(self) -> List[Tuple[str, str]]:
        return [(a.name, b.name) for a, b in zip(self.bricks, self.bricks[1:])]

    def brick(self, name: str) -> Brick:
        for b in self.bricks:
            if b.name == name:
                return b
        raise KeyError(name)

    def names(self) -> List[str]:
        return [b.name for b in self.bricks]


# ---------------------------------------------------------------------------
# brick apply functions (thin wrappers over the model substrate)
# ---------------------------------------------------------------------------

def _apply_projector(p, cfg, vision_feats):
    vp = p["vis_proj"]
    v = jax.nn.gelu(jnp.einsum("bnf,fd->bnd",
                               vision_feats.astype(cfg.compute_dtype),
                               vp["w1"]))
    return jnp.einsum("bnd,de->bne", v, vp["w2"])


def _apply_embed(p, cfg, tokens, vision_embeds=None):
    x = p["embed"][tokens]
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds, x[:, vision_embeds.shape[1]:]],
                            axis=1)
    return x


def _apply_decoder(p, cfg, x, positions=None):
    from repro.models import decoder as dec
    from repro.models.model import make_rope_fn
    from repro.models.common import default_positions, default_mrope_positions
    B, S, _ = x.shape
    pos = default_positions(B, S) if positions is None else positions
    mrope = default_mrope_positions(B, S) if cfg.rope == "mrope" else None
    rope_fn = make_rope_fn(cfg, pos, mrope)
    x, _, _ = dec.stack_forward(p["layers"], cfg, x, rope_fn, causal=True)
    return x


def _apply_head(p, cfg, x):
    from repro.models.model import _head
    # head brick owns final_norm (+ lm_head or the tied embed table)
    return _head(p, cfg, x)


def _apply_audio_encoder(p, cfg, src_embeds):
    from repro.models.encdec import encode
    return encode(p, cfg, src_embeds)


def _brick_flops(cfg: ModelConfig, kind: str) -> float:
    """Per-token matmul FLOPs (2 * params touched), scheduler cost input."""
    from repro.models.model import count_params_analytic
    n = count_params_analytic(cfg, active_only=True)
    emb = cfg.padded_vocab * cfg.d_model
    body = n - emb * (1 if cfg.tie_embeddings else 2)
    return {"embed": 0.0,                      # gather, no matmul
            "head": 2.0 * emb,
            "decoder": 2.0 * body,
            "projector": 2.0 * (cfg.vision_feat_dim * cfg.d_model
                                + cfg.d_model * cfg.d_model),
            "encoder": 2.0 * body * (cfg.n_enc_layers
                                     / max(1, cfg.n_layers)),
            "frontend": 0.0}.get(kind, 0.0)


def _bytes(cfg, keys_params: int) -> int:
    return keys_params * 2                     # bf16


def decompose(cfg: ModelConfig) -> BrickGraph:
    """The paper's model decomposition for any assigned arch."""
    bricks: List[Brick] = []

    def add(name, kind, keys, fn, static=False, quant="bf16"):
        bricks.append(Brick(name, kind, tuple(keys), fn, static_shape=static,
                            quant_label=quant,
                            flops_per_token=_brick_flops(cfg, kind)))

    if cfg.vlm:
        # frontend is a STUB per the assignment: input_specs() provides
        # precomputed patch features; the projector onward is real.
        add("vision_frontend", "frontend", (), lambda p, c, f: f,
            static=True, quant="fp16")
        add("projector", "projector", ("vis_proj",), _apply_projector,
            static=True, quant="fp16")
    if cfg.encdec:
        add("audio_frontend", "frontend", (), lambda p, c, f: f,
            static=True, quant="fp16")
        add("audio_encoder", "encoder",
            ("enc_layers", "enc_final_norm"), _apply_audio_encoder,
            static=True, quant="fp16")
    add("embedding", "embed", ("embed",), _apply_embed, quant="fp16")
    add("decoder", "decoder",
        ("layers",) if not cfg.encdec else ("dec_layers",),
        _apply_decoder, quant="q4f16")
    head_keys = ["final_norm"]
    if not cfg.tie_embeddings:
        head_keys.append("lm_head")
    else:
        head_keys.append("embed")             # tied: head reads the table
    add("head", "head", head_keys, _apply_head, quant="q4f16")
    return BrickGraph(cfg, bricks)


def brick_param_bytes(graph: BrickGraph, params) -> Dict[str, int]:
    """Actual per-brick weight bytes (after any quantization)."""
    from repro.core.quantize import tree_bytes
    return {b.name: tree_bytes(b.params_of(params)) for b in graph.bricks}
