"""Model decomposition into "bricks" (paper §3.1).

NANOMIND's first insight: LMMs are inherently modular — vision encoder,
projector, multimodal embedding, language decoder, audio encoder — and the
modules can be *decoupled and executed independently*, each on the hardware
that suits it.  A :class:`Brick` is one such unit: it owns a subset of the
parameter pytree, exposes a pure apply function over named ports, and
carries the metadata the scheduler needs (compute/memory footprints,
static-shape discipline, quantization label).

``decompose(cfg)`` builds the BrickGraph for any assigned arch:

    vlm:     vision_frontend* -> projector -> embed -> decoder -> head
    audio:   audio_frontend* -> encoder -> embed -> decoder -> head
    lm:      embed -> decoder -> head          (*frontends are stubs)

Every brick has one uniform entry point — ``apply(params_slice, cfg, ctx)``
where ``ctx`` maps the brick's declared input :class:`Port` names to arrays
— so callers never dispatch on ``brick.kind``.  The dataflow between bricks
is explicit in the port declarations; :mod:`repro.core.plan` compiles the
chain into bound per-brick callables (the one runtime behind the serving
engine, the cascade runner, and the scheduler's Placement).

Bricks are the unit of: placement (core/scheduler), zero-copy hand-off
(core/tabm), sequential low-power execution (core/cascade), and hybrid
quantization (core/quantize policies use brick names as path prefixes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Port:
    """A typed dataflow endpoint of a brick.

    ``dtype_kind``: "float" | "int" — validated when values bind at runtime.
    ``optional``: the brick runs without it (e.g. a text-only request through
    a vlm chain has no ``vision_embeds``)."""

    name: str
    dtype_kind: str = "float"
    optional: bool = False


@dataclass(frozen=True)
class Brick:
    """One independently executable module."""

    name: str
    kind: str                       # frontend | encoder | projector | embed
                                    # | decoder | head
    param_keys: Tuple[str, ...]     # top-level params entries this brick owns
    apply: Callable                 # (params_slice, cfg, ctx) -> out array
    in_ports: Tuple[Port, ...] = ()
    out_port: Port = Port("out")
    static_shape: bool = False      # paper §NPU: fixed input shapes only
    quant_label: str = "bf16"       # default per-brick precision (Fig. 7)
    flops_per_token: float = 0.0    # scheduler cost model inputs
    param_bytes: int = 0

    def params_of(self, params: Dict[str, Any]) -> Dict[str, Any]:
        return {k: params[k] for k in self.param_keys if k in params}


@dataclass
class BrickGraph:
    """Linear chain of bricks (the LMM pipelines are chains; the graph type
    still records explicit edges so the scheduler/TABM can treat producer
    -> consumer pairs uniformly)."""

    cfg: ModelConfig
    bricks: List[Brick]

    @property
    def edges(self) -> List[Tuple[str, str]]:
        return [(a.name, b.name) for a, b in zip(self.bricks, self.bricks[1:])]

    def brick(self, name: str) -> Brick:
        # dict lookup, rebuilt only when the bricks list is replaced
        # (populate_brick_bytes and tests reassign graph.bricks wholesale)
        if self.__dict__.get("_index_src") is not self.bricks:
            self.__dict__["_index"] = {b.name: b for b in self.bricks}
            self.__dict__["_index_src"] = self.bricks
        try:
            return self.__dict__["_index"][name]
        except KeyError:
            raise KeyError(name) from None

    def names(self) -> List[str]:
        return [b.name for b in self.bricks]


# ---------------------------------------------------------------------------
# brick apply functions (thin wrappers over the model substrate)
# ---------------------------------------------------------------------------

def _apply_vision_frontend(p, cfg, ctx):
    # STUB per the assignment: input_specs() provides precomputed patch
    # features; the projector onward is real.
    return ctx["vision_feats"]


def _apply_projector(p, cfg, ctx):
    vp = p["vis_proj"]
    v = jax.nn.gelu(jnp.einsum("bnf,fd->bnd",
                               ctx["patches"].astype(cfg.compute_dtype),
                               vp["w1"]))
    return jnp.einsum("bnd,de->bne", v, vp["w2"])


def _apply_embed(p, cfg, ctx):
    tokens = ctx["tgt_tokens"] if cfg.encdec else ctx["tokens"]
    x = p["embed"][tokens]
    vision_embeds = ctx.get("vision_embeds")
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype),
                             x[:, vision_embeds.shape[1]:]], axis=1)
    return x


def _apply_decoder(p, cfg, ctx):
    from repro.models import decoder as dec
    from repro.models.model import make_rope_fn
    from repro.models.common import default_positions, default_mrope_positions
    x = ctx["hidden"]
    B, S, _ = x.shape
    pos = default_positions(B, S)
    mrope = default_mrope_positions(B, S) if cfg.rope == "mrope" else None
    rope_fn = make_rope_fn(cfg, pos, mrope)
    x, _, _ = dec.stack_forward(p["layers"], cfg, x, rope_fn, causal=True)
    return x


def _apply_encdec_decoder(p, cfg, ctx):
    from repro.models.common import apply_rope, default_positions
    from repro.models.encdec import _dec_layer_full
    x, enc_out = ctx["hidden"], ctx["enc_out"]
    B, S, _ = x.shape
    rope_fn = lambda t: apply_rope(t, default_positions(B, S), cfg.rope_theta)

    def body(xc, lp):
        xc, _ = _dec_layer_full(cfg, lp, xc, enc_out, rope_fn, False, 0)
        return xc, None

    x, _ = jax.lax.scan(body, x, p["dec_layers"])
    return x


def _apply_head(p, cfg, ctx):
    from repro.models.model import _head
    # head brick owns final_norm (+ lm_head or the tied embed table)
    return _head(p, cfg, ctx["hidden"])


def _apply_audio_frontend(p, cfg, ctx):
    return ctx["src_embeds"]


def _apply_audio_encoder(p, cfg, ctx):
    from repro.models.encdec import encode
    return encode(p, cfg, ctx["audio_frames"])


def _brick_flops(cfg: ModelConfig, kind: str) -> float:
    """Per-token matmul FLOPs (2 * params touched), scheduler cost input."""
    from repro.models.model import count_params_analytic
    n = count_params_analytic(cfg, active_only=True)
    emb = cfg.padded_vocab * cfg.d_model
    body = n - emb * (1 if cfg.tie_embeddings else 2)
    return {"embed": 0.0,                      # gather, no matmul
            "head": 2.0 * emb,
            "decoder": 2.0 * body,
            "projector": 2.0 * (cfg.vision_feat_dim * cfg.d_model
                                + cfg.d_model * cfg.d_model),
            "encoder": 2.0 * body * (cfg.n_enc_layers
                                     / max(1, cfg.n_layers)),
            "frontend": 0.0}.get(kind, 0.0)


def _bytes(cfg, keys_params: int) -> int:
    return keys_params * 2                     # bf16


def decompose(cfg: ModelConfig) -> BrickGraph:
    """The paper's model decomposition for any assigned arch."""
    bricks: List[Brick] = []

    def add(name, kind, keys, fn, ins, out, static=False, quant="bf16"):
        bricks.append(Brick(name, kind, tuple(keys), fn,
                            in_ports=tuple(ins), out_port=out,
                            static_shape=static, quant_label=quant,
                            flops_per_token=_brick_flops(cfg, kind)))

    if cfg.vlm:
        add("vision_frontend", "frontend", (), _apply_vision_frontend,
            ins=(Port("vision_feats"),), out=Port("patches"),
            static=True, quant="fp16")
        add("projector", "projector", ("vis_proj",), _apply_projector,
            ins=(Port("patches"),), out=Port("vision_embeds"),
            static=True, quant="fp16")
    if cfg.encdec:
        add("audio_frontend", "frontend", (), _apply_audio_frontend,
            ins=(Port("src_embeds"),), out=Port("audio_frames"),
            static=True, quant="fp16")
        add("audio_encoder", "encoder",
            ("enc_layers", "enc_final_norm"), _apply_audio_encoder,
            ins=(Port("audio_frames"),), out=Port("enc_out"),
            static=True, quant="fp16")
    tok_port = Port("tgt_tokens" if cfg.encdec else "tokens", "int")
    embed_ins = [tok_port]
    if cfg.vlm:
        embed_ins.append(Port("vision_embeds", optional=True))
    add("embedding", "embed", ("embed",), _apply_embed,
        ins=embed_ins, out=Port("hidden"), quant="fp16")
    if cfg.encdec:
        add("decoder", "decoder", ("dec_layers",), _apply_encdec_decoder,
            ins=(Port("hidden"), Port("enc_out")), out=Port("hidden"),
            quant="q4f16")
    else:
        add("decoder", "decoder", ("layers",), _apply_decoder,
            ins=(Port("hidden"),), out=Port("hidden"), quant="q4f16")
    head_keys = ["final_norm"]
    if not cfg.tie_embeddings:
        head_keys.append("lm_head")
    else:
        head_keys.append("embed")             # tied: head reads the table
    add("head", "head", head_keys, _apply_head,
        ins=(Port("hidden"),), out=Port("logits"), quant="q4f16")
    return BrickGraph(cfg, bricks)


def brick_param_bytes(graph: BrickGraph, params) -> Dict[str, int]:
    """Actual per-brick weight bytes (after any quantization)."""
    from repro.core.quantize import tree_bytes
    return {b.name: tree_bytes(b.params_of(params)) for b in graph.bricks}
