"""Transport — the serialized edge layer for disaggregated fleets.

The paper's zero-copy TABM hand-off assumed producer and consumer share
one process and one device pool.  At fleet scale ("Cost-Efficient
Multimodal LLM Inference via Cross-Tier GPU Heterogeneity", PAPERS.md)
vision/prefill and decode want *different* hardware pools scaled
independently, so the hand-off must cross a process or machine boundary.
This module is that boundary as a first-class API, mirroring the
``BACKENDS`` registry in :mod:`repro.core.backends`:

* :class:`Transport` — the protocol: duplex message send/recv over a
  checksummed binary wire format, plus ``make_edge`` (how a compiled
  plan's cross-accelerator edges route when the plan is bound to this
  transport) and a ``link_bw`` row the scheduler's split pricing reads
  (``core/scheduler.schedule_split``).
* :data:`TRANSPORTS` / :func:`resolve_transport` — the registry:
  ``"inproc"`` (byte queues between two threads), ``"pipe"`` (OS pipes
  across fork/exec), ``"socket"`` (TCP localhost or LAN).
* :class:`RemotePrefill` — the wire unit: one request's committed TABM
  slab plus its prefilled :class:`~repro.serving.kv_cache.PagedKVCache`
  payload — the *granted* blocks only, never a whole ``max_len`` lane —
  with the scalar admission metadata (rid, prompt, first token, block
  grant, slot class) a decode fleet needs to admit it directly into its
  own paged pool.

Wire format (stdlib only — never pickle, so corruption yields a typed
:class:`TransportError` instead of arbitrary code paths)::

    MAGIC "TBM1" | rid i64 | header_len u32 |
    header JSON | crc32(header) u32 |
    payload bytes (concatenated buffers; lengths in the header) |
    crc32(payload) u32

The request id sits in the fixed prefix, *before* anything that can be
corrupted: a frame whose payload fails its checksum still identifies the
owning request (``TransportError.rid``, ``recoverable=True``) and the
stream stays aligned — the decode fleet fails exactly that request and
keeps serving.  A bad magic, a truncated read, or a corrupt header
(whose buffer lengths can no longer be trusted) is a stream-level
failure (``recoverable=False``).

Every array crosses as raw bytes with its dtype/shape in the header —
lossless, which is what makes disaggregated decode bit-identical to the
single-process engine (tests/test_transport.py, launch/serve_disagg.py).

:class:`SubmeshPipe` (the original intra-pod ICI edge) lives here now:
it is the degenerate transport — same-process, sharding-preserving,
nothing serialized — and ``core/scheduler`` re-exports it for
compatibility.
"""
from __future__ import annotations

import json
import os
import queue
import socket as _socket
import struct
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class TransportError(RuntimeError):
    """A wire-format or channel failure.

    ``rid`` is the owning request when the frame prefix survived (so the
    caller can fail exactly that request); ``recoverable`` says whether
    the stream is still frame-aligned (payload checksum mismatch: the
    frame was fully consumed, keep reading) or dead (truncation, bad
    magic, corrupt header: lengths can no longer be trusted)."""

    def __init__(self, msg: str, *, rid: Optional[int] = None,
                 recoverable: bool = False):
        super().__init__(msg)
        self.rid = rid
        self.recoverable = recoverable


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------

MAGIC = b"TBM1"
_PREFIX = struct.Struct("<4sqI")       # magic, rid, header_len
_CRC = struct.Struct("<I")


def _crc(data) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def _np_dtype(name: str) -> np.dtype:
    """dtype by *name* ("bfloat16", "float32", ...): extended dtypes like
    bfloat16 stringify to an opaque void str ("<V2"), so frames carry the
    name, and decoding registers ml_dtypes when numpy alone cannot
    resolve it (a decode-fleet process may not have imported jax yet)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        try:
            return np.dtype(getattr(ml_dtypes, name))
        except (AttributeError, TypeError):
            raise TransportError(f"frame names unknown dtype {name!r}") \
                from None


def encode_frame(kind: str, meta: Dict[str, Any],
                 arrays: Sequence[np.ndarray] = (),
                 rid: int = -1) -> bytes:
    """One message as one frame: JSON header (kind + meta + per-buffer
    dtype/shape/length descriptors) followed by the raw array bytes,
    each section checksummed."""
    bufs = [np.ascontiguousarray(a) for a in arrays]
    header = json.dumps({
        "kind": kind, "meta": meta,
        "bufs": [{"dtype": b.dtype.name, "shape": list(b.shape),
                  "len": int(b.nbytes)} for b in bufs],
    }).encode()
    payload = b"".join(b.tobytes() for b in bufs)
    return b"".join([
        _PREFIX.pack(MAGIC, rid, len(header)),
        header, _CRC.pack(_crc(header)),
        payload, _CRC.pack(_crc(payload)),
    ])


def decode_frame(read: Callable[[int], bytes]
                 ) -> Tuple[str, Dict[str, Any], List[np.ndarray], int]:
    """Parse one frame from a ``read(n) -> exactly-n-bytes`` callable
    (which raises :class:`TransportError` on truncation).  Returns
    ``(kind, meta, arrays, rid)``; raises :class:`TransportError` typed
    per the module docstring's failure taxonomy."""
    magic, rid, header_len = _PREFIX.unpack(read(_PREFIX.size))
    if magic != MAGIC:
        raise TransportError(f"bad frame magic {magic!r} (stream "
                             f"desynchronized or not a transport peer)")
    header = read(header_len)
    (want,) = _CRC.unpack(read(_CRC.size))
    if _crc(header) != want:
        # the header carries the buffer lengths: with it corrupt the
        # frame boundary is unknowable, so the stream is dead
        raise TransportError(
            f"corrupt frame header for rid {rid} (checksum mismatch)",
            rid=rid if rid >= 0 else None)
    try:
        h = json.loads(header)
        descs = h["bufs"]
        total = sum(int(d["len"]) for d in descs)
    except (ValueError, KeyError, TypeError) as e:
        raise TransportError(f"unparseable frame header for rid {rid}: "
                             f"{e}", rid=rid if rid >= 0 else None) from e
    payload = read(total)
    (want,) = _CRC.unpack(read(_CRC.size))
    if _crc(payload) != want:
        # the frame was fully consumed (lengths were good), so the
        # stream stays aligned: fail only the owning request
        raise TransportError(
            f"corrupt frame payload for rid {rid} (checksum mismatch)",
            rid=rid if rid >= 0 else None, recoverable=True)
    arrays, off = [], 0
    for d in descs:
        n = int(d["len"])
        dt = _np_dtype(d["dtype"])
        arrays.append(np.frombuffer(payload, dtype=dt,
                                    count=n // dt.itemsize,
                                    offset=off).reshape(d["shape"]))
        off += n
    return h["kind"], h.get("meta", {}), arrays, rid


# ---------------------------------------------------------------------------
# the wire unit
# ---------------------------------------------------------------------------

@dataclass
class RemotePrefill:
    """One prefilled request, ready for remote admission.

    ``kv`` holds, per cache group position, the flat leaf list of the
    prefill-written state: paged (attention) positions ship ``(L, nb,
    block_size, ...)`` — the first ``nb`` *written* blocks of the grant,
    never the whole ``max_len`` lane — and slot-state (SSM / linear
    attention) positions ship the request's ``(L, 1, ...)`` row.  The
    tree structure is NOT serialized: both fleets run the same config,
    so the importer re-derives it from its own pool's treedef
    (:meth:`repro.serving.kv_cache.PagedKVCache.import_blocks`).

    ``slab`` is the committed TABM slab (trimmed to its true token
    count): decode itself reads only the imported KV, but the slab rides
    along so the hand-off is self-contained — the decode fleet holds
    everything needed to re-prefill or audit the request if its blocks
    are later lost (failure semantics, docs/ARCHITECTURE.md)."""

    rid: int
    prompt: np.ndarray                     # int32 prompt token ids
    first_token: int                       # picked from the prefill logits
    max_new_tokens: int
    blocks_granted: int                    # decode-side grant size
    paged: Tuple[bool, ...]                # per-position layout flags
    kv: List[List[np.ndarray]]             # per-position flat leaves
    slot_class: Optional[str] = None
    slab: Optional[np.ndarray] = None      # committed TABM slab, trimmed
    prompt_len: int = 0

    def __post_init__(self):
        if not self.prompt_len:
            self.prompt_len = int(len(self.prompt))

    def kv_wire_bytes(self) -> int:
        """Bytes of paged KV actually crossing the wire — the quantity
        asserted against the whole-lane baseline
        (``PagedKVCache.slot_lane_bytes``)."""
        return sum(leaf.nbytes
                   for pos, leaves in enumerate(self.kv) if self.paged[pos]
                   for leaf in leaves)

    def to_wire(self) -> Tuple[str, Dict[str, Any], List[np.ndarray]]:
        meta = {"rid": self.rid, "first_token": int(self.first_token),
                "max_new_tokens": int(self.max_new_tokens),
                "blocks_granted": int(self.blocks_granted),
                "slot_class": self.slot_class,
                "prompt_len": int(self.prompt_len),
                "paged": list(self.paged),
                "kv_layout": [len(leaves) for leaves in self.kv],
                "has_slab": self.slab is not None}
        arrays: List[np.ndarray] = [np.asarray(self.prompt, np.int32)]
        if self.slab is not None:
            arrays.append(self.slab)
        for leaves in self.kv:
            arrays.extend(leaves)
        return "prefill", meta, arrays

    @classmethod
    def from_wire(cls, meta: Dict[str, Any],
                  arrays: List[np.ndarray]) -> "RemotePrefill":
        try:
            it = iter(arrays)
            prompt = next(it)
            slab = next(it) if meta["has_slab"] else None
            kv = [[next(it) for _ in range(n)] for n in meta["kv_layout"]]
            return cls(rid=int(meta["rid"]), prompt=prompt,
                       first_token=int(meta["first_token"]),
                       max_new_tokens=int(meta["max_new_tokens"]),
                       blocks_granted=int(meta["blocks_granted"]),
                       paged=tuple(bool(p) for p in meta["paged"]),
                       kv=kv, slot_class=meta.get("slot_class"),
                       slab=slab, prompt_len=int(meta["prompt_len"]))
        except (KeyError, StopIteration, TypeError, ValueError) as e:
            raise TransportError(
                f"malformed prefill frame for rid {meta.get('rid')}: {e}",
                rid=meta.get("rid"), recoverable=True) from e


# ---------------------------------------------------------------------------
# the Transport protocol
# ---------------------------------------------------------------------------

class Transport:
    """Duplex typed-message channel between a prefill and a decode fleet.

    Subclasses implement the byte movement (``_send_bytes`` /
    ``_recv_exact``); the base class owns framing, the message API, and
    the plan-edge routing.  ``link_bw`` is the scheduler's split-pricing
    row — what one byte crossing THIS transport costs in the chain DP
    (``core/scheduler.schedule_split``), mirroring how each backend's
    substrate row prices its compute."""

    name: str = "base"
    #: modeled wire bandwidth (bytes/s) for the scheduler's split pricing
    link_bw: float = 8e9
    #: a serializing transport's plan edges round-trip the wire codec
    serializes: bool = False

    def __init__(self):
        self._send_lock = threading.Lock()
        self._recv_lock = threading.Lock()
        self.sent_frames = 0
        self.sent_bytes = 0
        self.send_seconds = 0.0

    # -- byte movement (subclass responsibility) ----------------------------
    def _send_bytes(self, data: bytes) -> None:
        raise NotImplementedError

    def _recv_exact(self, n: int) -> bytes:
        raise NotImplementedError

    def close(self) -> None:
        pass

    # -- message api --------------------------------------------------------
    def send(self, kind: str, meta: Optional[Dict[str, Any]] = None,
             arrays: Sequence[np.ndarray] = (), rid: int = -1) -> int:
        """Frame and send one message; returns the frame's wire bytes.
        Thread-safe (one lock per direction): frames from concurrent
        senders interleave whole, never torn."""
        frame = encode_frame(kind, meta or {}, arrays, rid=rid)
        with self._send_lock:
            t0 = time.perf_counter()
            self._send_bytes(frame)
            self.send_seconds += time.perf_counter() - t0
            self.sent_frames += 1
            self.sent_bytes += len(frame)
        return len(frame)

    def measured_link_bw(self, min_bytes: int = 1 << 16
                         ) -> Optional[float]:
        """Observed wire bandwidth (bytes/s) over every frame sent so
        far, or None below ``min_bytes`` of evidence.  This is the
        measured-not-modeled counterpart of the static ``link_bw`` row:
        ``CostCalibration.observe_link`` folds it into the table that
        ``core/scheduler.schedule_split`` blends over the modeled wire
        (a socket that benchmarks slower than its class row pushes the
        split toward fewer crossings)."""
        if self.sent_bytes < min_bytes or self.send_seconds <= 0.0:
            return None
        return self.sent_bytes / self.send_seconds

    def send_prefill(self, rp: RemotePrefill) -> int:
        kind, meta, arrays = rp.to_wire()
        return self.send(kind, meta, arrays, rid=rp.rid)

    def recv(self) -> Tuple[str, Dict[str, Any], List[np.ndarray], int]:
        """Receive one message: ``(kind, meta, arrays, rid)``.  Raises
        :class:`TransportError` per the failure taxonomy — a
        ``recoverable`` error consumed its whole frame, so the caller
        may keep receiving."""
        with self._recv_lock:
            return decode_frame(self._recv_exact)

    # -- plan-edge routing --------------------------------------------------
    def make_edge(self, src_accel, dst_accel, backend) -> Optional[Callable]:
        """The inbound-transfer factory for a plan bound to this
        transport: delegate placement to the backend (where the value
        must land), and — on serializing transports — round-trip the
        value through the wire codec first, so the format is proven
        transparent to plan dataflow (logits bit-identical across
        transports, not just decode tokens)."""
        inner = backend.make_edge(src_accel, dst_accel)
        if not self.serializes:
            return inner
        return _codec_edge(inner)


def _codec_edge(inner: Optional[Callable]) -> Callable:
    """Wrap a backend edge with an encode->decode pass through the exact
    wire codec messages use.  The host round-trip is the point: this is
    what the value would survive on a real pipe/socket crossing."""
    def edge(v):
        host = np.asarray(v)
        _, _, (back,), _ = decode_frame(
            _BytesReader(encode_frame("edge", {}, [host])).read)
        return back if inner is None else inner(back)
    return edge


class _BytesReader:
    """``read(n)`` over an in-memory frame, with the same truncation
    contract the fd/socket readers provide."""

    def __init__(self, data: bytes):
        self._view = memoryview(data)
        self._off = 0

    def read(self, n: int) -> bytes:
        if self._off + n > len(self._view):
            raise TransportError(
                f"truncated frame: wanted {n} bytes, "
                f"{len(self._view) - self._off} left")
        out = self._view[self._off:self._off + n].tobytes()
        self._off += n
        return out


# ---------------------------------------------------------------------------
# concrete transports
# ---------------------------------------------------------------------------

class InProcTransport(Transport):
    """Two fleets in one process (or the degenerate single-host multi-GPU
    case): frames cross a pair of byte queues between threads.  Messages
    are STILL serialized — the wire format is exercised on every send —
    but plan edges stay direct device transfers (``serializes=False``):
    in-process, the zero-copy hand-off IS the transport."""

    name = "inproc"
    link_bw = 64e9

    def __init__(self):
        super().__init__()
        self._tx: "queue.Queue[Optional[bytes]]" = queue.Queue()
        self._rx: "queue.Queue[Optional[bytes]]" = self._tx  # loopback
        self._buf = b""
        self._closed = False

    @classmethod
    def pair(cls) -> Tuple["InProcTransport", "InProcTransport"]:
        """Cross-wired duplex pair: a.send -> b.recv and vice versa."""
        a, b = cls(), cls()
        a._rx, b._rx = b._tx, a._tx
        return a, b

    def _send_bytes(self, data: bytes) -> None:
        if self._closed:
            raise TransportError("send on a closed inproc transport")
        self._tx.put(bytes(data))

    def _recv_exact(self, n: int) -> bytes:
        while len(self._buf) < n:
            frame = self._rx.get()
            if frame is None:
                raise TransportError(
                    f"truncated stream: peer closed with {len(self._buf)} "
                    f"of {n} wanted bytes buffered")
            self._buf += frame
        out, self._buf = self._buf[:n], self._buf[n:]
        return out

    def close(self) -> None:
        self._closed = True
        self._tx.put(None)              # wakes a peer blocked in recv


class PipeTransport(Transport):
    """Inter-process transport over OS pipes: the parent spawns the
    decode fleet as a subprocess and hands it the fd pair
    (``launch/serve_disagg.py --role decode --recv-fd N --send-fd M``)."""

    name = "pipe"
    link_bw = 2e9
    serializes = True

    def __init__(self, recv_fd: Optional[int], send_fd: Optional[int]):
        super().__init__()
        self._recv_fd = recv_fd
        self._send_fd = send_fd

    @classmethod
    def pair(cls) -> Tuple["PipeTransport", "PipeTransport"]:
        """Duplex pair over two pipes (same process; the subprocess case
        passes the raw fds through ``subprocess.Popen(pass_fds=...)``)."""
        a2b_r, a2b_w = os.pipe()
        b2a_r, b2a_w = os.pipe()
        return cls(b2a_r, a2b_w), cls(a2b_r, b2a_w)

    def _send_bytes(self, data: bytes) -> None:
        if self._send_fd is None:
            raise TransportError("pipe transport has no send fd")
        view = memoryview(data)
        while view:
            try:
                n = os.write(self._send_fd, view)
            except OSError as e:
                raise TransportError(f"pipe send failed: {e}") from e
            view = view[n:]

    def _recv_exact(self, n: int) -> bytes:
        if self._recv_fd is None:
            raise TransportError("pipe transport has no recv fd")
        chunks, got = [], 0
        while got < n:
            try:
                chunk = os.read(self._recv_fd, n - got)
            except OSError as e:
                raise TransportError(f"pipe recv failed: {e}") from e
            if not chunk:
                raise TransportError(
                    f"truncated stream: pipe closed with {got} of {n} "
                    f"wanted bytes read")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def close(self) -> None:
        for fd in (self._send_fd, self._recv_fd):
            if fd is not None:
                try:
                    os.close(fd)
                except OSError:
                    pass
        self._send_fd = self._recv_fd = None


class SocketTransport(Transport):
    """TCP transport: the fleet boundary as a real network hop — same
    codec, connectable across machines (the driver uses localhost)."""

    name = "socket"
    link_bw = 1e9
    serializes = True

    def __init__(self, sock: "_socket.socket"):
        super().__init__()
        self._sock = sock
        sock.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)

    @classmethod
    def listen(cls, host: str = "127.0.0.1", port: int = 0
               ) -> Tuple["_socket.socket", int]:
        srv = _socket.socket()
        srv.setsockopt(_socket.SOL_SOCKET, _socket.SO_REUSEADDR, 1)
        srv.bind((host, port))
        srv.listen(1)
        return srv, srv.getsockname()[1]

    @classmethod
    def accept(cls, srv: "_socket.socket",
               timeout: Optional[float] = 60.0) -> "SocketTransport":
        srv.settimeout(timeout)
        conn, _ = srv.accept()
        return cls(conn)

    @classmethod
    def connect(cls, host: str, port: int,
                timeout: Optional[float] = 60.0) -> "SocketTransport":
        return cls(_socket.create_connection((host, port), timeout=timeout))

    def _send_bytes(self, data: bytes) -> None:
        try:
            self._sock.sendall(data)
        except OSError as e:
            raise TransportError(f"socket send failed: {e}") from e

    def _recv_exact(self, n: int) -> bytes:
        chunks, got = [], 0
        while got < n:
            try:
                chunk = self._sock.recv(n - got)
            except OSError as e:
                raise TransportError(f"socket recv failed: {e}") from e
            if not chunk:
                raise TransportError(
                    f"truncated stream: socket closed with {got} of {n} "
                    f"wanted bytes read")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# registry — mirrors core/backends.BACKENDS
# ---------------------------------------------------------------------------

TRANSPORTS: Dict[str, type] = {
    "inproc": InProcTransport,
    "pipe": PipeTransport,
    "socket": SocketTransport,
}


def register_transport(cls: type) -> type:
    """Add a custom transport to the registry (a class, not an instance:
    transports are stateful connections, instantiated per fleet pair)."""
    TRANSPORTS[cls.name] = cls
    return cls


def resolve_transport(spec) -> type:
    """Registry-name or class -> transport class (mirror of
    ``backends.resolve_backend``, minus instantiation: connections are
    built by the driver via ``pair()`` / ``listen`` + ``connect``)."""
    if isinstance(spec, type) and issubclass(spec, Transport):
        return spec
    try:
        return TRANSPORTS[spec]
    except (KeyError, TypeError):
        raise TransportError(f"unknown transport {spec!r}; registered: "
                             f"{sorted(TRANSPORTS)}") from None


# ---------------------------------------------------------------------------
# the intra-pod degenerate case (moved from core/scheduler)
# ---------------------------------------------------------------------------

class SubmeshPipe:
    """Producer/consumer hand-off between two submeshes: a sharding-
    preserving device_put — data moves NPU-slice -> GPU-slice over ICI
    without a host round trip (the paper's 'bypassing CPU for buffer
    writes').  The degenerate transport: same process, nothing
    serialized; ``core/scheduler`` re-exports it."""

    def __init__(self, src, dst, spec):
        import jax
        from jax.sharding import NamedSharding
        self.src, self.dst = src, dst
        self.dst_sharding = NamedSharding(dst.mesh, spec)
        self._put = jax.device_put

    def transfer(self, x):
        return self._put(x, self.dst_sharding)
