"""Hybrid per-brick quantization (paper §3.2 "Quantization").

NANOMIND's key quantization idea is *hybrid* precision: because the model is
decomposed into bricks, each brick gets its own bit-width (vision encoder
FP16/INT8, decoder W4A16 or W2A16, embedding FP16).  This module provides

* :class:`QuantSpec` — bits (2/4/8), group size, symmetric group-wise scheme;
* :class:`QTensor` — a pytree-registered packed tensor (int32 words holding
  32/bits codes + per-group scales) that flows through jit/pjit/shardings;
* :func:`quantize` / :func:`dequantize` — round-trip with the max-abs
  group-wise scale (the GGUF/K-quant-style scheme the paper builds on);
* :func:`quantize_tree` / :func:`dequantize_tree` — per-brick application
  driven by a :class:`QuantPolicy` (the paper's ``em-fp16 vis-fp16 dec-q4f16``
  label format);
* weight-memory accounting used by the scheduler's cost model and the
  Fig. 5 memory benchmark.

Packing layout: codes are packed along the **last** axis, ``32 // bits``
values per int32 word, with per-group scales over contiguous groups of the
last axis.  XLA fuses ``dequantize`` into the consuming matmul (the W4A16
"unpack + rescale in-register" pattern); the explicit fused MXU kernel for
the hot GEMMs is :mod:`repro.kernels.dequant_gemm`.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "QuantSpec", "QTensor", "quantize", "dequantize", "quantize_tree",
    "dequantize_tree", "QuantPolicy", "PROFILES", "tree_bytes",
]


@dataclass(frozen=True)
class QuantSpec:
    """Group-wise symmetric quantization spec."""

    bits: int                  # 2 | 4 | 8
    group_size: int = 64       # values per scale group (along last axis)
    scale_dtype: str = "float32"

    def __post_init__(self):
        assert self.bits in (2, 4, 8), self.bits
        assert 32 % self.bits == 0

    @property
    def per_word(self) -> int:
        return 32 // self.bits

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1   # 1, 7, 127

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))      # -2, -8, -128


@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    """Packed quantized tensor.  Pytree: children = (codes, scales)."""

    codes: jnp.ndarray          # int32, shape (..., K // per_word)
    scales: jnp.ndarray         # shape (..., K // group_size)
    spec: QuantSpec             # static
    shape: Tuple[int, ...]      # original logical shape (static)
    dtype: Any                  # original dtype (static)

    def tree_flatten(self):
        return (self.codes, self.scales), (self.spec, self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def nbytes(self) -> int:
        return int(self.codes.size * 4 + self.scales.size
                   * jnp.dtype(self.spec.scale_dtype).itemsize)

    def __repr__(self):
        return (f"QTensor(w{self.spec.bits}, shape={self.shape}, "
                f"g={self.spec.group_size})")


def _pad_last(x, multiple: int):
    k = x.shape[-1]
    pad = (-k) % multiple
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, k


def quantize(w: jnp.ndarray, spec: QuantSpec) -> QTensor:
    """Group-wise symmetric quantization along the last axis."""
    orig_shape, orig_dtype = w.shape, w.dtype
    wf = w.astype(jnp.float32)
    wf, k = _pad_last(wf, max(spec.group_size, spec.per_word))
    kp = wf.shape[-1]
    g = spec.group_size
    grp = wf.reshape(*wf.shape[:-1], kp // g, g)
    amax = jnp.max(jnp.abs(grp), axis=-1, keepdims=True)
    scale = amax / spec.qmax
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(grp / safe), spec.qmin, spec.qmax).astype(jnp.int32)
    q = q.reshape(*wf.shape[:-1], kp)
    # pack: per_word codes -> one int32 (two's-complement field of `bits`)
    pw = spec.per_word
    mask = (1 << spec.bits) - 1
    qu = jnp.bitwise_and(q, mask)                     # unsigned field
    qu = qu.reshape(*wf.shape[:-1], kp // pw, pw)
    shifts = (jnp.arange(pw, dtype=jnp.int32) * spec.bits)
    words = jnp.sum(jnp.left_shift(qu, shifts), axis=-1).astype(jnp.int32)
    scales = scale[..., 0].astype(spec.scale_dtype)
    return QTensor(words, scales, spec, orig_shape, orig_dtype)


def unpack_codes(codes: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """int32 words -> signed integer codes (..., K) in int32."""
    pw = spec.per_word
    shifts = (jnp.arange(pw, dtype=jnp.int32) * spec.bits)
    field = jnp.right_shift(codes[..., None], shifts)
    field = jnp.bitwise_and(field, (1 << spec.bits) - 1)
    # sign-extend the `bits`-wide field
    sign = 1 << (spec.bits - 1)
    q = jnp.where(field >= sign, field - (1 << spec.bits), field)
    return q.reshape(*codes.shape[:-1], codes.shape[-1] * pw)


def dequantize(qt: QTensor) -> jnp.ndarray:
    q = unpack_codes(qt.codes, qt.spec).astype(jnp.float32)
    g = qt.spec.group_size
    kp = q.shape[-1]
    q = q.reshape(*q.shape[:-1], kp // g, g)
    w = q * qt.scales.astype(jnp.float32)[..., None]
    w = w.reshape(*q.shape[:-2], kp)[..., :qt.shape[-1]]
    return w.astype(qt.dtype)


# ---------------------------------------------------------------------------
# per-brick policies (the paper's Module–Quantization label format, Fig. 7)
# ---------------------------------------------------------------------------

# label -> spec; fp16/bf16 mean "leave unquantized"
_LABEL_SPECS: Dict[str, Optional[QuantSpec]] = {
    "fp16": None,
    "bf16": None,
    "q8f16": QuantSpec(8),
    "q4f16": QuantSpec(4),
    "q2f16": QuantSpec(2),
}


@dataclass(frozen=True)
class QuantPolicy:
    """Maps brick-name patterns to quantization labels.

    ``rules`` are (regex, label) pairs matched against pytree key-paths or
    brick names, first match wins.  The paper's configurations, e.g.
    ``em-fp16 | vis-fp16 | dec-q4f16``, are expressed as profiles below.
    """

    name: str
    rules: Tuple[Tuple[str, str], ...]
    min_size: int = 1 << 14      # don't quantize tiny leaves (norms, biases)

    def label_for(self, path: str) -> str:
        for pat, label in self.rules:
            if re.search(pat, path):
                return label
        return "bf16"

    def spec_for(self, path: str) -> Optional[QuantSpec]:
        return _LABEL_SPECS[self.label_for(path)]


_LABEL_SPECS["q4f16-g32"] = QuantSpec(4, group_size=32)

PROFILES: Dict[str, QuantPolicy] = {
    # the paper's headline config: FP16 vision, W4A16 decoder (Fig. 6/7)
    "nanomind-default": QuantPolicy("nanomind-default", (
        (r"vis|projector", "fp16"),
        (r"embed", "fp16"),
        (r"layers|dec|lm_head", "q4f16"),
    )),
    # pod-serving variant: group 32 so scale groups align with a 16-way
    # tensor-parallel shard of every assigned d_ff/d_model (EXPERIMENTS.md
    # §Perf, deepseek decode iteration: group 64 straddles the shard
    # boundary at d_ff=22016 and forces a full regather)
    "nanomind-serve": QuantPolicy("nanomind-serve", (
        (r"vis|projector", "fp16"),
        (r"embed", "fp16"),
        (r"layers|dec|lm_head", "q4f16-g32"),
    )),
    # ablations from Fig. 7
    "all-fp16": QuantPolicy("all-fp16", ()),
    "all-q4": QuantPolicy("all-q4", ((r".", "q4f16"),)),
    "vis-q4": QuantPolicy("vis-q4", (
        (r"vis|projector", "q4f16"), (r"embed", "fp16"),
        (r"layers|dec|lm_head", "q4f16"),
    )),
    "dec-q2": QuantPolicy("dec-q2", (
        (r"vis|projector|embed", "fp16"),
        (r"layers|dec|lm_head", "q2f16"),
    )),
    "dec-q8": QuantPolicy("dec-q8", (
        (r"vis|projector|embed", "fp16"),
        (r"layers|dec|lm_head", "q8f16"),
    )),
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def quantize_tree(params, policy: QuantPolicy):
    """Quantize eligible leaves of a param pytree per the policy."""
    def visit(path, leaf):
        if not isinstance(leaf, jnp.ndarray) or leaf.ndim < 2:
            return leaf
        if leaf.size < policy.min_size:
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        spec = policy.spec_for(_path_str(path))
        if spec is None:
            return leaf
        return quantize(leaf, spec)

    return jax.tree_util.tree_map_with_path(visit, params)


def dequantize_tree(params):
    """Inverse of :func:`quantize_tree`; inside jit XLA fuses the dequant
    into each consumer (W4A16 in-register unpack)."""
    return jax.tree.map(
        lambda l: dequantize(l) if isinstance(l, QTensor) else l,
        params, is_leaf=lambda l: isinstance(l, QTensor))


def tree_bytes(params) -> int:
    """Weight bytes after quantization (Fig. 5 memory accounting)."""
    total = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda l: isinstance(l, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes
        elif hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    return total
