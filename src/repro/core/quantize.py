"""Hybrid per-brick quantization (paper §3.2 "Quantization").

NANOMIND's key quantization idea is *hybrid* precision: because the model is
decomposed into bricks, each brick gets its own bit-width (vision encoder
FP16/INT8, decoder W4A16 or W2A16, embedding FP16).  This module provides

* :class:`QuantSpec` — bits (2/4/8), group size, symmetric group-wise scheme;
* :class:`QTensor` — a pytree-registered packed tensor (int32 words holding
  32/bits codes + per-group scales) that flows through jit/pjit/shardings;
* :func:`quantize` / :func:`dequantize` — round-trip with the max-abs
  group-wise scale (the GGUF/K-quant-style scheme the paper builds on);
* :func:`quantize_tree` / :func:`dequantize_tree` — per-brick application
  driven by a :class:`QuantPolicy` (the paper's ``em-fp16 vis-fp16 dec-q4f16``
  label format);
* weight-memory accounting used by the scheduler's cost model and the
  Fig. 5 memory benchmark.

Packing layout: codes are packed along the **last** axis, ``32 // bits``
values per int32 word, with per-group scales over contiguous groups of the
last axis.  XLA fuses ``dequantize`` into the consuming matmul (the W4A16
"unpack + rescale in-register" pattern); the explicit fused MXU kernel for
the hot GEMMs is :mod:`repro.kernels.dequant_gemm`.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "QuantSpec", "QTensor", "quantize", "dequantize", "quantize_tree",
    "dequantize_tree", "QuantPolicy", "PROFILES", "tree_bytes",
    "prune_weights", "parse_label",
]


@dataclass(frozen=True)
class QuantSpec:
    """Group-wise symmetric quantization spec.

    ``scale_search > 1`` turns on MSE-optimal scale refinement: instead of
    the plain max-abs scale, each group tries ``scale_search`` shrunken
    candidates in ``[scale_shrink, 1.0] * amax/qmax`` and keeps the one
    minimizing the group's round-trip squared error (the K-quant refinement;
    clipping the odd outlier buys finer resolution for the bulk).  The
    max-abs scale is always a candidate, so exactly-representable groups
    still round-trip bit-exactly.
    """

    bits: int                  # 2 | 4 | 8
    group_size: int = 64       # values per scale group (along last axis)
    scale_dtype: str = "float32"
    scale_search: int = 8      # MSE scale-grid size; <=1 -> plain max-abs
    scale_shrink: float = 0.75  # smallest candidate as a fraction of max-abs

    def __post_init__(self):
        assert self.bits in (2, 4, 8), self.bits
        assert 32 % self.bits == 0
        assert 0.0 < self.scale_shrink <= 1.0

    @property
    def per_word(self) -> int:
        return 32 // self.bits

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1   # 1, 7, 127

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))      # -2, -8, -128


@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    """Packed quantized tensor.  Pytree: children = (codes, scales)."""

    codes: jnp.ndarray          # int32, shape (..., K // per_word)
    scales: jnp.ndarray         # shape (..., K // group_size)
    spec: QuantSpec             # static
    shape: Tuple[int, ...]      # original logical shape (static)
    dtype: Any                  # original dtype (static)

    def tree_flatten(self):
        return (self.codes, self.scales), (self.spec, self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def nbytes(self) -> int:
        return int(self.codes.size * 4 + self.scales.size
                   * jnp.dtype(self.spec.scale_dtype).itemsize)

    def __repr__(self):
        return (f"QTensor(w{self.spec.bits}, shape={self.shape}, "
                f"g={self.spec.group_size})")


def _pad_last(x, multiple: int):
    k = x.shape[-1]
    pad = (-k) % multiple
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, k


def _mse_scale(grp: jnp.ndarray, scale: jnp.ndarray,
               spec: QuantSpec) -> jnp.ndarray:
    """Per-group MSE-optimal scale over a shrink grid.

    grp (..., G, g) fp32 groups; scale (..., G, 1) the max-abs scale.
    Candidates run shrink -> 1.0 so a zero-error max-abs group (already
    exactly representable) wins its ties via the final argmin order below.
    """
    fr = jnp.linspace(spec.scale_shrink, 1.0, spec.scale_search,
                      dtype=jnp.float32)
    cand = scale[..., None] * fr                        # (..., G, 1, n)
    safe = jnp.where(cand == 0, 1.0, cand)
    q = jnp.clip(jnp.round(grp[..., None] / safe), spec.qmin, spec.qmax)
    err = jnp.sum((q * cand - grp[..., None]) ** 2, axis=-2)   # (..., G, n)
    # prefer the LARGEST candidate among exact ties (index of last min):
    # flip so argmin lands on fr=1.0 first, then map the index back.
    best = (fr.shape[0] - 1) - jnp.argmin(err[..., ::-1], axis=-1)
    return jnp.take_along_axis(cand[..., 0, :], best[..., None], axis=-1)


def quantize(w: jnp.ndarray, spec: QuantSpec) -> QTensor:
    """Group-wise symmetric quantization along the last axis."""
    orig_shape, orig_dtype = w.shape, w.dtype
    wf = w.astype(jnp.float32)
    wf, k = _pad_last(wf, max(spec.group_size, spec.per_word))
    kp = wf.shape[-1]
    g = spec.group_size
    grp = wf.reshape(*wf.shape[:-1], kp // g, g)
    amax = jnp.max(jnp.abs(grp), axis=-1, keepdims=True)
    scale = amax / spec.qmax
    if spec.scale_search > 1:
        scale = _mse_scale(grp, scale, spec)
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(grp / safe), spec.qmin, spec.qmax).astype(jnp.int32)
    q = q.reshape(*wf.shape[:-1], kp)
    # pack: per_word codes -> one int32 (two's-complement field of `bits`)
    pw = spec.per_word
    mask = (1 << spec.bits) - 1
    qu = jnp.bitwise_and(q, mask)                     # unsigned field
    qu = qu.reshape(*wf.shape[:-1], kp // pw, pw)
    shifts = (jnp.arange(pw, dtype=jnp.int32) * spec.bits)
    words = jnp.sum(jnp.left_shift(qu, shifts), axis=-1).astype(jnp.int32)
    scales = scale[..., 0].astype(spec.scale_dtype)
    return QTensor(words, scales, spec, orig_shape, orig_dtype)


def unpack_codes(codes: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """int32 words -> signed integer codes (..., K) in int32."""
    pw = spec.per_word
    shifts = (jnp.arange(pw, dtype=jnp.int32) * spec.bits)
    field = jnp.right_shift(codes[..., None], shifts)
    field = jnp.bitwise_and(field, (1 << spec.bits) - 1)
    # sign-extend the `bits`-wide field
    sign = 1 << (spec.bits - 1)
    q = jnp.where(field >= sign, field - (1 << spec.bits), field)
    return q.reshape(*codes.shape[:-1], codes.shape[-1] * pw)


def dequantize(qt: QTensor) -> jnp.ndarray:
    q = unpack_codes(qt.codes, qt.spec).astype(jnp.float32)
    g = qt.spec.group_size
    kp = q.shape[-1]
    q = q.reshape(*q.shape[:-1], kp // g, g)
    w = q * qt.scales.astype(jnp.float32)[..., None]
    w = w.reshape(*q.shape[:-2], kp)[..., :qt.shape[-1]]
    return w.astype(qt.dtype)


# ---------------------------------------------------------------------------
# activation-aware magnitude pruning (EdgeMM-style semi-structured sparsity)
# ---------------------------------------------------------------------------


def prune_weights(w: jnp.ndarray, sparsity: float,
                  act_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Zero the lowest-scoring ``sparsity`` fraction of each last-axis row.

    Score is Wanda-style ``|W| * act_scale`` — ``act_scale`` is a per-input-
    feature activation magnitude (shape broadcastable to the last axis, e.g.
    the RMS of calibration activations).  Without it the score degrades to
    plain magnitude.  Rows are thresholded independently so every output
    keeps its strongest inputs; composes with :func:`quantize` (prune first,
    then group-quantize the survivors)."""
    if sparsity <= 0.0:
        return w
    assert 0.0 < sparsity < 1.0, sparsity
    wf = w.astype(jnp.float32)
    score = jnp.abs(wf)
    if act_scale is not None:
        score = score * jnp.abs(jnp.asarray(act_scale, jnp.float32))
    thresh = jnp.quantile(score, sparsity, axis=-1, keepdims=True)
    return jnp.where(score > thresh, wf, 0.0).astype(w.dtype)


# ---------------------------------------------------------------------------
# per-brick policies (the paper's Module–Quantization label format, Fig. 7)
# ---------------------------------------------------------------------------

# label -> spec; fp16/bf16 mean "leave unquantized"
_LABEL_SPECS: Dict[str, Optional[QuantSpec]] = {
    "fp16": None,
    "bf16": None,
    "q8f16": QuantSpec(8),
    "q4f16": QuantSpec(4),
    "q2f16": QuantSpec(2),
}

# composite labels append "-sp<pct>" for activation-aware pruning before
# quantization, e.g. "q4f16-sp50" = prune 50% then W4A16
_SP_RE = re.compile(r"^(?P<base>.+?)-sp(?P<pct>\d{1,2})$")


def parse_label(label: str) -> Tuple[Optional[QuantSpec], float]:
    """'q4f16-g32-sp50' -> (QuantSpec(4, 32), 0.50); plain -> (spec, 0.0)."""
    sparsity = 0.0
    m = _SP_RE.match(label)
    if m:
        sparsity = int(m.group("pct")) / 100.0
        label = m.group("base")
    return _LABEL_SPECS[label], sparsity


@dataclass(frozen=True)
class QuantPolicy:
    """Maps brick-name patterns to quantization labels.

    ``rules`` are (regex, label) pairs matched against pytree key-paths or
    brick names, first match wins.  The paper's configurations, e.g.
    ``em-fp16 | vis-fp16 | dec-q4f16``, are expressed as profiles below.
    """

    name: str
    rules: Tuple[Tuple[str, str], ...]
    min_size: int = 1 << 14      # don't quantize tiny leaves (norms, biases)

    def label_for(self, path: str) -> str:
        for pat, label in self.rules:
            if re.search(pat, path):
                return label
        return "bf16"

    def spec_for(self, path: str) -> Optional[QuantSpec]:
        return parse_label(self.label_for(path))[0]


_LABEL_SPECS["q4f16-g32"] = QuantSpec(4, group_size=32)

PROFILES: Dict[str, QuantPolicy] = {
    # the paper's headline config: FP16 vision, W4A16 decoder (Fig. 6/7)
    "nanomind-default": QuantPolicy("nanomind-default", (
        (r"vis|projector", "fp16"),
        (r"embed", "fp16"),
        (r"layers|dec|lm_head", "q4f16"),
    )),
    # pod-serving variant: group 32 so scale groups align with a 16-way
    # tensor-parallel shard of every assigned d_ff/d_model (EXPERIMENTS.md
    # §Perf, deepseek decode iteration: group 64 straddles the shard
    # boundary at d_ff=22016 and forces a full regather)
    "nanomind-serve": QuantPolicy("nanomind-serve", (
        (r"vis|projector", "fp16"),
        (r"embed", "fp16"),
        (r"layers|dec|lm_head", "q4f16-g32"),
    )),
    # ablations from Fig. 7
    "all-fp16": QuantPolicy("all-fp16", ()),
    "all-q4": QuantPolicy("all-q4", ((r".", "q4f16"),)),
    "vis-q4": QuantPolicy("vis-q4", (
        (r"vis|projector", "q4f16"), (r"embed", "fp16"),
        (r"layers|dec|lm_head", "q4f16"),
    )),
    "dec-q2": QuantPolicy("dec-q2", (
        (r"vis|projector|embed", "fp16"),
        (r"layers|dec|lm_head", "q2f16"),
    )),
    "dec-q8": QuantPolicy("dec-q8", (
        (r"vis|projector|embed", "fp16"),
        (r"layers|dec|lm_head", "q8f16"),
    )),
    # EdgeMM-style activation-aware 50% sparsity stacked under W4A16: the
    # pruned rows re-quantize tighter (zeros shrink group max-abs) and the
    # NPU substrates credit the skipped MACs via SUBSTRATES sparse rows
    "nanomind-sparse": QuantPolicy("nanomind-sparse", (
        (r"vis|projector|embed", "fp16"),
        (r"layers|dec|lm_head", "q4f16-g32-sp50"),
    )),
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def quantize_tree(params, policy: QuantPolicy, act_scales=None):
    """Quantize (and optionally prune) eligible leaves per the policy.

    ``act_scales`` maps path substrings to per-input-feature activation
    magnitudes for :func:`prune_weights`; leaves whose label carries an
    ``-sp<pct>`` suffix are pruned before quantization (magnitude-only when
    no activation statistics match)."""
    def visit(path, leaf):
        if not isinstance(leaf, jnp.ndarray) or leaf.ndim < 2:
            return leaf
        if leaf.size < policy.min_size:
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        p = _path_str(path)
        spec, sparsity = parse_label(policy.label_for(p))
        if sparsity > 0.0:
            act = None
            if act_scales:
                for pat, scale in act_scales.items():
                    if pat in p:
                        act = scale
                        break
            leaf = prune_weights(leaf, sparsity, act)
        if spec is None:
            return leaf
        return quantize(leaf, spec)

    return jax.tree_util.tree_map_with_path(visit, params)


def dequantize_tree(params):
    """Inverse of :func:`quantize_tree`; inside jit XLA fuses the dequant
    into each consumer (W4A16 in-register unpack)."""
    return jax.tree.map(
        lambda l: dequantize(l) if isinstance(l, QTensor) else l,
        params, is_leaf=lambda l: isinstance(l, QTensor))


def tree_bytes(params) -> int:
    """Weight bytes after quantization (Fig. 5 memory accounting)."""
    total = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda l: isinstance(l, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes
        elif hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    return total
