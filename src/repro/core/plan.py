"""ExecutionPlan — the one brick runtime (paper §3.1–3.2 made executable).

This module compiles ``(BrickGraph, Placement, TABM ring, SubmeshPipes)``
into *bound, jit-cached per-brick callables* with typed input/output ports.
It is the single execution path behind the serving engine, the cascade
runner, and the scheduler — the three previously divergent interpreters of
a BrickGraph.

Paper-term → API mapping:

* **Model decomposition (§3.1)** — the :class:`~repro.core.bricks.BrickGraph`
  chain with per-brick :class:`~repro.core.bricks.Port` declarations.  The
  plan validates the wiring at compile time (every required input port is
  either produced upstream or named an external input) and type-checks port
  values (int tokens vs float features) when they bind.
* **Module-level offloading (§3.2)** — a ``Placement`` from
  :func:`repro.core.scheduler.schedule` binds each brick to an
  :class:`~repro.core.scheduler.Accelerator`, and each accelerator names a
  :class:`~repro.core.backends.Backend` — the substrate the brick lowers
  to.  ``compile_plan`` consults the backend table (never ``accel.mesh``
  branches): ``SubmeshBackend`` device_puts weights onto the submesh and
  wires :class:`~repro.core.scheduler.SubmeshPipe` edges (ICI, never the
  host); ``DeviceBackend`` commits weights to one device;
  ``HostBackend`` keeps them host-side and loads per execution.  The same
  Placement therefore executes identically on any substrate, and
  :meth:`ExecutionPlan.relower` moves one brick to a cheaper backend at
  runtime (the battery policy's THROTTLED hook).
* **Embeddings zero-copy transfer / TABM (§3.2)** — the edge whose producer
  emits ``vision_embeds`` routes through a
  :class:`~repro.core.tabm.RingBuffer` (or a class-partitioned
  :class:`~repro.core.tabm.SlotClassPool`, one class-sized ring per
  image-count × resolution bucket): :meth:`ExecutionPlan.produce` runs
  the upstream (encoder-side) stages and commits into a slot (donation =
  the TPU zero-copy), :meth:`ExecutionPlan.consume` binds the oldest READY
  slot for the decoder side, and a full ring stalls the producer — the
  backpressure signal the engine's admission loop obeys, per class, so a
  FULL high-resolution class never blocks thumbnail staging.
* **On-demand cascade (§3.2, Fig. 2)** — ``residency="one-brick"`` lowers
  every brick through the transient ``HostBackend``: params host-side,
  each brick load → execute → release, recording a :class:`PlanTrace`
  that proves peak memory is max(brick) not sum(bricks).
  ``residency="resident"`` (default) binds all brick params once for
  serving.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import Backend, BACKENDS, resolve_backend
from repro.core.bricks import Brick, BrickGraph, Port
from repro.core.tabm import SlotClassPool


class PlanError(RuntimeError):
    pass


def _nbytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size"):
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# trace (the cascade's residency evidence; cheap enough to always record)
# ---------------------------------------------------------------------------

@dataclass
class PlanEvent:
    brick: str
    phase: str                 # load | execute | release
    t: float
    resident_bytes: int


@dataclass
class PlanTrace:
    events: List[PlanEvent] = field(default_factory=list)
    peak_bytes: int = 0
    sum_bytes: int = 0         # what a monolithic load would have held

    def record(self, brick, phase, resident):
        self.events.append(PlanEvent(brick, phase, time.time(), resident))
        self.peak_bytes = max(self.peak_bytes, resident)


# ---------------------------------------------------------------------------
# compiled steps
# ---------------------------------------------------------------------------

@dataclass
class PlanStep:
    """One brick bound to its backend, accelerator, params, and callable."""

    brick: Brick
    fn: Callable                       # jitted (params, ctx) -> out
    params: Any                        # backend-bound tree (device | host)
    backend: Backend                   # the lowering substrate
    accel: Optional[object] = None     # scheduler.Accelerator or None
    inbound: Dict[str, Callable] = field(default_factory=dict)
    # inbound: port name -> transfer fn applied when the value was produced
    # on a different accelerator (backend.make_edge: SubmeshPipe.transfer,
    # committed device_put, or host pull)


class ExecutionPlan:
    """Bound, executable form of a BrickGraph.

    Built by :func:`compile_plan`; see the module docstring for the paper
    mapping.  The three consumers:

    * ``plan.run(inputs)`` — one full forward pass (logits), used by tests,
      examples, and the cascade runner.
    * ``plan.produce / consume / release`` — the TABM edge split into its
      producer/consumer halves, used by the serving engine so vision
      encoding and decoder admission decouple through the ring.
    * ``plan.brick_params(name)`` — the placement-bound weights, used by
      launchers that keep specialized compiled fns (cached prefill/decode).
    """

    def __init__(self, graph: BrickGraph, steps: List[PlanStep], *,
                 residency: str, tabm=None, tabm_producer: Optional[int] = None,
                 tabm_transfer: Optional[Callable] = None,
                 input_ports: Tuple[Port, ...] = (), probe=None):
        self.graph = graph
        self.cfg = graph.cfg
        self.steps = steps
        self.residency = residency
        self.tabm = tabm
        self._tabm_producer = tabm_producer
        self._tabm_transfer = tabm_transfer
        self.input_ports = input_ports
        # optional telemetry WallProbe: per-brick wall-time spans recorded
        # by run()/produce_many() (host clocks only — on async resident
        # backends a span measures dispatch, a calibrated lower bound;
        # transient backends sync, so theirs is true wall time)
        self.probe = probe
        self._params = None            # full tree, kept for relower()
        # "what a monolithic load would have held": each top-level param
        # entry once — tied-embedding archs share "embed" between the
        # embedding and head bricks and must not count it twice
        merged: Dict[str, Any] = {}
        for s in steps:
            merged.update(s.params)
        self._sum_bytes = _nbytes(merged)
        self._resident_bytes = self._resident_baseline()

    def _resident_baseline(self) -> int:
        """Bytes held by resident-backend steps between executions (tied
        params counted once); transient (host) steps contribute zero.
        Cached as ``_resident_bytes``; recomputed only by relower()."""
        merged: Dict[str, Any] = {}
        for s in self.steps:
            if s.backend.resident:
                merged.update(s.params)
        return _nbytes(merged)

    # -- introspection ------------------------------------------------------
    def brick_params(self, name: str) -> Any:
        for s in self.steps:
            if s.brick.name == name:
                return s.params
        raise KeyError(name)

    def backend_of(self, name: str) -> Backend:
        for s in self.steps:
            if s.brick.name == name:
                return s.backend
        raise KeyError(name)

    def describe(self) -> str:
        rows = []
        for s in self.steps:
            ins = ",".join(p.name + ("?" if p.optional else "")
                           for p in s.brick.in_ports)
            acc = s.accel.name if s.accel is not None else "-"
            rows.append(f"{s.brick.name}({ins})->{s.brick.out_port.name}"
                        f"@{acc}/{s.backend.name}")
        return " | ".join(rows)

    # -- re-lowering (the battery policy's THROTTLED hook) ------------------
    def relower(self, brick_name: str, backend) -> PlanStep:
        """Re-lower one brick to a different backend at runtime: re-bind
        its params and swap in the (shared, jit-cached) executable for
        that substrate.  The step is replaced atomically, so a concurrent
        ``produce`` on the staging thread sees either the old or the new
        step, never a half-built one.  Routing (accel identity, inbound
        transfers) is preserved — re-lowering changes where the brick's
        *weights and compute* live, not the graph wiring."""
        be = resolve_backend(backend)
        for i, s in enumerate(self.steps):
            if s.brick.name != brick_name:
                continue
            if s.backend is be:
                return s
            if self._params is None:
                raise PlanError("plan kept no full param tree; relower "
                                "is only available on compile_plan output")
            new = PlanStep(
                brick=s.brick, fn=be.compile_fn(s.brick, self.cfg),
                params=be.bind_params(s.brick, self._params, s.accel),
                backend=be, accel=s.accel, inbound=s.inbound)
            self.steps[i] = new        # atomic swap under the GIL
            self._resident_bytes = self._resident_baseline()
            return new
        raise KeyError(brick_name)

    # -- execution ----------------------------------------------------------
    @staticmethod
    def _check_port(port: Port, value):
        kind = jnp.asarray(value).dtype.kind if not hasattr(value, "dtype") \
            else jnp.dtype(value.dtype).kind
        want = "iu" if port.dtype_kind == "int" else "fV"
        if kind not in want + ("b" if port.dtype_kind == "int" else ""):
            raise PlanError(f"port {port.name!r} expects {port.dtype_kind} "
                            f"values, got dtype kind {kind!r}")

    def _gather(self, step: PlanStep, env, env_src):
        ctx = {}
        for port in step.brick.in_ports:
            if port.name not in env or env[port.name] is None:
                if port.optional:
                    continue
                raise PlanError(f"brick {step.brick.name!r} missing required "
                                f"input port {port.name!r}")
            v = env[port.name]
            self._check_port(port, v)
            src = env_src.get(port.name)
            if src is not step.accel and port.name in step.inbound:
                v = step.inbound[port.name](v)
            ctx[port.name] = v
        return ctx

    def _load(self, step: PlanStep):
        return step.backend.load(step.brick, step.params)

    def run(self, inputs: Dict[str, Any],
            trace: Optional[PlanTrace] = None) -> Tuple[Any, PlanTrace]:
        """One full inference pass through every brick.  Returns the final
        brick's output (logits) and the residency trace.  When a TABM ring
        is attached, the vision_embeds edge really goes through a slot
        (commit -> bind -> release), so the ring lifecycle is exercised on
        every pass."""
        trace = trace if trace is not None else PlanTrace()
        trace.sum_bytes = max(trace.sum_bytes, self._sum_bytes)
        resident = self._resident_bytes
        env: Dict[str, Any] = dict(inputs)
        env_src: Dict[str, Any] = {k: None for k in env}
        out = None
        ring_slot = None
        for i, step in enumerate(self.steps):
            transient = not step.backend.resident
            dev_params = self._load(step)
            if transient:
                resident += _nbytes(dev_params)
            trace.record(step.brick.name, "load", resident)

            t0 = time.perf_counter()
            ctx = self._gather(step, env, env_src)
            out = step.fn(dev_params, ctx)
            if transient:
                # deliberate residency trace point: the sync makes the
                # brick's device-memory high-water mark observable
                out = jax.block_until_ready(out)  # replint: disable=host-sync
            trace.record(step.brick.name, "execute", resident)
            if self.probe is not None:
                # a full pass is a prefill; bricks up to the TABM edge
                # are the staging side of it
                phase = ("stage" if self._tabm_producer is not None
                         and i <= self._tabm_producer else "prefill")
                ntok = (int(out.shape[1]) if getattr(out, "ndim", 0) >= 2
                        else 0)
                self.probe.record(step.brick.name, phase,
                                  time.perf_counter() - t0, tokens=ntok)

            if self.tabm is not None and i == self._tabm_producer:
                out, ring, slot = self._through_ring(out)
                ring_slot = (ring, slot)
            env[step.brick.out_port.name] = out
            env_src[step.brick.out_port.name] = step.accel

            if transient:
                # release: only `out` survives to the next stage
                step.backend.unload(dev_params)
                resident -= _nbytes(dev_params)
            trace.record(step.brick.name, "release", resident)
            del dev_params
        if ring_slot is not None:
            ring_slot[0].release(ring_slot[1])
        return out, trace

    def _through_ring(self, out):
        """Synchronous TABM crossing inside run(): commit the producer's
        output to a slot, immediately bind it back as the consumer view.
        With a class-partitioned pool the slab is picked by the embeds'
        token count (the request's class), so run() exercises the same
        class-sized ring the engine would.  A failed commit aborts the
        write — the slot must never be left in STAGING (same contract as
        produce())."""
        if out.shape[0] != 1:
            raise PlanError("TABM slots hold one request's embeds (batch 1)")
        if isinstance(self.tabm, SlotClassPool):
            ring = self.tabm.ring(self.tabm.classify_total(out.shape[1]))
        else:
            ring = self.tabm
        slot = ring.acquire_write()
        if slot is None:
            raise PlanError("TABM ring full inside a synchronous run(); "
                            "a prior consumer never released its slot")
        try:
            v = out if self._tabm_transfer is None \
                else self._tabm_transfer(out)
            ring.commit_write(slot, v[0])
        except Exception:
            ring.abort_write(slot)
            raise
        got = ring.acquire_read()
        assert got is not None
        s, view, n = got
        return view[None, :n], ring, s

    # -- TABM edge, split for the engine's producer/consumer decoupling -----
    def _tabm_ring(self, slot_class: Optional[str]):
        """Resolve the ring a TABM operation targets: the single ring, or
        the named class ring of a class-partitioned pool."""
        if self.tabm is None:
            raise PlanError("plan compiled without a TABM ring")
        if isinstance(self.tabm, SlotClassPool):
            if slot_class is None:
                raise PlanError("class-partitioned TABM pool: pass "
                                "slot_class= (see core/slot_classes)")
            return self.tabm.ring(slot_class)
        if slot_class is not None:
            raise PlanError(f"slot_class={slot_class!r} given but the "
                            f"plan's TABM is a single ring")
        return self.tabm

    def tabm_capacity(self, slot_class: Optional[str] = None) -> int:
        """Slot capacity of the targeted ring — the hard ceiling on one
        microbatch (``produce_many`` of more slots can never fit)."""
        return self._tabm_ring(slot_class).n_slots

    def produce(self, inputs: Dict[str, Any], *,
                slot_class: Optional[str] = None, block: bool = False,
                timeout: Optional[float] = None) -> Optional[int]:
        """Producer half: acquire a ring slot, run the stages upstream of
        the TABM edge (vision encode -> projector), commit.  Returns the
        slot id, or None when the ring is FULL — the caller must stall and
        retry (backpressure), never bypass the ring.

        This is the K=1 case of :meth:`produce_many` — same slab padding,
        same abort-on-error contract, one slot."""
        slots = self.produce_many([inputs], slot_class=slot_class,
                                  block=block, timeout=timeout)
        return None if slots is None else slots[0]

    def produce_many(self, batch_of_inputs: List[Dict[str, Any]], *,
                     slot_class: Optional[str] = None, block: bool = False,
                     timeout: Optional[float] = None
                     ) -> Optional[List[int]]:
        """Batched producer half: acquire K FIFO-contiguous ring slots,
        run the upstream stages (vision encode -> projector) as ONE
        batched jit call over the whole microbatch, and commit a single
        strided slab covering all K slots.  Returns the slot ids in
        request order, or None when the ring cannot hold the microbatch
        (the caller stalls — all-or-nothing backpressure, never a partial
        commit).

        Each element of ``batch_of_inputs`` is one request's
        ``{"vision_feats": (1, t_i, f)}``; requests are padded to the
        target ring's slab width (``max_tokens`` — all K must share a
        slot class), so one compiled executable serves every microbatch
        of the class, and each slot's true length rides in the ring's
        per-slot token counts (the consumer binds ``view[:n]``, so pad
        rows are never read — the per-request mask).  The upstream bricks
        are token-wise (frontend stub, projector), so padded rows cannot
        perturb real rows and K=1 produces bit-identical embeds to the
        unbatched path.

        With a class-partitioned pool, ``slot_class`` names the class
        ring (the engine passes the class it grouped the microbatch by);
        left None, it is inferred from the largest vision_feats token
        count in the batch.  ``block=True`` parks the calling thread
        until K slots free from the ring head — where the engine's
        per-class StagingWorker stalls, off the decode loop.

        Error contract: if any upstream brick raises, ALL K acquired
        slots are aborted back to EMPTY (``abort_many`` — abort-all-on-
        failure, the write pointer rewinds past the whole run) before the
        exception propagates; the caller owns surfacing the error on the
        originating requests."""
        if self.tabm is None:
            raise PlanError("plan compiled without a TABM ring")
        if not batch_of_inputs:
            raise PlanError("produce_many needs at least one request")
        feats = []
        for inputs in batch_of_inputs:
            extra = set(inputs) - {"vision_feats"}
            if extra:
                raise PlanError(f"produce_many batches the vision_feats "
                                f"port only; got extra inputs {sorted(extra)}")
            f = inputs.get("vision_feats")
            if f is None:
                raise PlanError("produce_many needs vision_feats for "
                                "every request in the microbatch")
            if f.shape[0] != 1:
                raise PlanError("TABM slots hold one request's embeds "
                                "(batch 1 per request)")
            feats.append(f)
        if slot_class is None and isinstance(self.tabm, SlotClassPool):
            slot_class = self.tabm.classify_total(
                max(int(f.shape[1]) for f in feats))
        ring = self._tabm_ring(slot_class)
        lengths = [int(f.shape[1]) for f in feats]
        for n in lengths:
            if n > ring.max_tokens:
                raise PlanError(f"{n} vision tokens > slot capacity "
                                f"{ring.max_tokens} of the target ring")
        slots = ring.acquire_write_many(len(feats), block=block,
                                        timeout=timeout)
        if slots is None:
            return None
        try:
            # pad every request into the class slab and stack: one
            # (K, slab, f) batch through encoder+projector, one jit call
            slab = ring.max_tokens
            stacked = np.zeros((len(feats), slab, feats[0].shape[-1]),
                               feats[0].dtype)
            for b, f in enumerate(feats):
                # deliberate host-side slab packing: requests arrive as
                # host arrays; one device upload follows (jnp.asarray)
                stacked[b, : lengths[b]] = np.asarray(f[0])  # replint: disable=host-sync
            env: Dict[str, Any] = {"vision_feats": jnp.asarray(stacked)}
            env_src: Dict[str, Any] = {k: None for k in env}
            out = None
            for step in self.steps[: self._tabm_producer + 1]:
                transient = not step.backend.resident
                dev_params = self._load(step)
                t0 = time.perf_counter()
                ctx = self._gather(step, env, env_src)
                out = step.fn(dev_params, ctx)
                if transient:
                    # deliberate residency trace point (see run())
                    out = jax.block_until_ready(out)  # replint: disable=host-sync
                    step.backend.unload(dev_params)
                env[step.brick.out_port.name] = out
                env_src[step.brick.out_port.name] = step.accel
                if self.probe is not None:
                    self.probe.record(step.brick.name, "stage",
                                      time.perf_counter() - t0,
                                      tokens=len(feats) * slab)
            if out.shape[0] != len(feats):
                raise PlanError(f"projector returned batch {out.shape[0]} "
                                f"for a {len(feats)}-request microbatch")
            if out.shape[1] != slab:
                # the committed per-slot lengths are the INPUT token
                # counts — valid only while the upstream bricks are
                # token-count-preserving; a resampling projector must
                # fail loudly here, not stage misaligned views
                raise PlanError(
                    f"upstream bricks changed the token count "
                    f"({slab} -> {out.shape[1]}); produce_many requires "
                    f"token-count-preserving staging bricks")
            v = out if self._tabm_transfer is None else self._tabm_transfer(out)
            ring.commit_many(slots, v, lengths)
        except Exception:
            ring.abort_many(slots)
            raise
        return slots

    def consume(self, *, slot_class: Optional[str] = None,
                block: bool = False, timeout: Optional[float] = None):
        """Consumer half: bind the oldest READY slot (of ``slot_class``'s
        ring when the pool is class-partitioned).  Returns
        (slot, view, n_tokens) or None when nothing is ready (with
        ``block=True``: only on timeout or a closed ring)."""
        return self._tabm_ring(slot_class).acquire_read(block=block,
                                                        timeout=timeout)

    def wait_ready(self, slot: int, timeout: Optional[float] = None, *,
                   slot_class: Optional[str] = None) -> bool:
        """Block until `slot` is committed — the decode loop's per-slot
        (and per-class) ready wait, replacing inline staging."""
        return self._tabm_ring(slot_class).wait_ready(slot, timeout)

    def addref(self, slot: int, gen: int, *,
               slot_class: Optional[str] = None) -> bool:
        """Pin an already-consumed TABM slot for one more bucket-matched
        consumer (refcounted READY-slot sharing; see
        :meth:`repro.core.tabm.RingBuffer.addref`).  False = the slot was
        recycled, the caller must stage its own copy."""
        return self._tabm_ring(slot_class).addref(slot, gen)

    def shared_view(self, slot: int, gen: int, *,
                    slot_class: Optional[str] = None):
        """(view, n_tokens) of a shared consumed slot, seqlock-validated
        against ``gen`` — None when the slot moved on."""
        return self._tabm_ring(slot_class).shared_view(slot, gen)

    def release(self, slot: int, *, slot_class: Optional[str] = None):
        self._tabm_ring(slot_class).release(slot)


# ---------------------------------------------------------------------------
# compiler
# ---------------------------------------------------------------------------

def _backend_for(brick_name: str, accel, *, override, placement_backends,
                 residency: str) -> Backend:
    """The backend table lookup, in priority order: an explicit
    compile_plan ``backend=`` override (global or per-brick dict) >
    ``residency="one-brick"`` (every brick through the transient
    HostBackend) > the Placement's carried backend name > the
    accelerator's profile / the default (see backends.resolve_backend)."""
    if override is not None:
        spec = override.get(brick_name) if isinstance(override, dict) \
            else override
        if spec is not None:
            be = resolve_backend(spec, accel)
            if residency == "one-brick" and be.resident:
                raise PlanError(
                    f"residency='one-brick' needs a transient backend, "
                    f"but brick {brick_name!r} was overridden to the "
                    f"resident {be.name!r} backend")
            return be
    if residency == "one-brick":
        return BACKENDS["host"]
    if placement_backends and brick_name in placement_backends:
        return resolve_backend(placement_backends[brick_name], accel)
    return resolve_backend(None, accel)


def compile_plan(graph: BrickGraph, params, *, placement=None, accels=None,
                 tabm=None, residency: str = "resident",
                 backend=None, probe=None, transport=None) -> ExecutionPlan:
    """Compile a BrickGraph (+ optional Placement and TABM ring) into an
    :class:`ExecutionPlan`.

    placement: a :class:`~repro.core.scheduler.Placement` or a raw
        ``{brick_name: accel_name}`` dict; requires ``accels``.  A
        Placement's ``backends`` map (filled by ``schedule()`` from each
        accelerator's ``backend`` profile field) picks each brick's
        lowering substrate.
    accels: the accelerator list the placement names refer to.
    tabm: a :class:`~repro.core.tabm.RingBuffer` or class-partitioned
        :class:`~repro.core.tabm.SlotClassPool` for the vision_embeds
        edge (the paper's zero-copy hand-off).
    residency: "resident" (serving: params bound once) | "one-brick"
        (cascade: every brick lowered through the transient HostBackend —
        load -> execute -> release, host-side between events).
    backend: override the backend table — a registry name
        (``"submesh" | "device" | "host"``), a
        :class:`~repro.core.backends.Backend` instance, or a per-brick
        ``{brick_name: spec}`` dict.  The same graph + placement lowers
        to any substrate; see docs/ARCHITECTURE.md "Backend lowering".
    probe: a :class:`~repro.telemetry.probes.WallProbe` that run() /
        produce_many() record per-brick wall-time spans into (the
        telemetry ledger's dynamic population path); None = no probing.
    transport: a :class:`~repro.core.transport.Transport` instance the
        plan's cross-accelerator edges are bound to.  None (default) =
        direct backend edges, exactly the pre-transport behavior; a
        serializing transport routes every such edge through its wire
        codec (``Transport.make_edge``), proving the format transparent
        to plan dataflow — the disaggregated drivers pass their live
        fleet connection here.
    """
    if residency not in ("resident", "one-brick"):
        raise PlanError(f"unknown residency {residency!r}")
    assignment = getattr(placement, "assignment", placement)
    placement_backends = getattr(placement, "backends", None)
    by_name = {a.name: a for a in (accels or [])}
    if assignment:
        missing = [b.name for b in graph.bricks if b.name not in assignment]
        if missing:
            raise PlanError(f"placement misses bricks: {missing}")
        unknown = sorted(set(assignment.values()) - set(by_name))
        if unknown:
            raise PlanError(f"placement names unknown accelerators: {unknown}")

    # wiring validation + external input discovery
    produced: Dict[str, Brick] = {}
    externals: List[Port] = []
    for b in graph.bricks:
        for p in b.in_ports:
            if p.name not in produced and not p.optional \
                    and all(e.name != p.name for e in externals):
                externals.append(p)
        produced[b.out_port.name] = b

    steps: List[PlanStep] = []
    src_accel: Dict[str, Any] = {}                 # port -> producing accel
    edges: Dict[Tuple[str, str, str], Any] = {}    # (src, dst, backend) -> fn
    for b in graph.bricks:
        accel = by_name[assignment[b.name]] if assignment else None
        be = _backend_for(b.name, accel, override=backend,
                          placement_backends=placement_backends,
                          residency=residency)
        inbound: Dict[str, Callable] = {}
        if accel is not None:
            for p in b.in_ports:
                src = src_accel.get(p.name)
                if src is accel:
                    continue
                # keyed on the backend *instance*: two distinct instances
                # sharing a registry name (e.g. DeviceBackends pinned to
                # different devices) must not reuse each other's transfer
                key = (src.name if src is not None else "-",
                       accel.name, id(be))
                if key not in edges:
                    edges[key] = (be.make_edge(src, accel)
                                  if transport is None
                                  else transport.make_edge(src, accel, be))
                if edges[key] is not None:
                    inbound[p.name] = edges[key]
        steps.append(PlanStep(
            brick=b, fn=be.compile_fn(b, graph.cfg),
            params=be.bind_params(b, params, accel),
            backend=be, accel=accel, inbound=inbound))
        src_accel[b.out_port.name] = accel

    # the TABM edge: the brick producing vision_embeds hands off through the
    # ring; the transfer (if the consumer sits on another submesh/device)
    # happens producer-side so the pool can live consumer-side
    tabm_producer = tabm_transfer = None
    if tabm is not None:
        for i, s in enumerate(steps):
            if s.brick.out_port.name == "vision_embeds":
                tabm_producer = i
                break
        if tabm_producer is None:
            raise PlanError("tabm ring given but no brick produces "
                            "'vision_embeds'")
        nxt = steps[tabm_producer + 1] if tabm_producer + 1 < len(steps) \
            else None
        if nxt is not None and "vision_embeds" in nxt.inbound:
            tabm_transfer = nxt.inbound.pop("vision_embeds")

    plan = ExecutionPlan(graph, steps, residency=residency, tabm=tabm,
                         tabm_producer=tabm_producer,
                         tabm_transfer=tabm_transfer,
                         input_ports=tuple(externals), probe=probe)
    plan.pipes = edges
    plan._params = params
    return plan
