"""Token-Aware Buffer Manager (TABM) — the paper's zero-copy hand-off
(§3.2 "Embeddings Zero-Copy Transfer in Unified Memory").

NANOMIND's TABM manages a shared ring-buffer pool in unified DRAM: the NPU
encoder (producer) writes embeddings directly into a slot which the GPU
decoder (consumer) binds as input — no CPU staging copy.  Slot lifecycle:

    FREE -> ALLOCATED_FOR_WRITE -> READY_TO_READ -> ALLOCATED_FOR_READ -> FREE

TPU adaptation (DESIGN.md §2): "unified DRAM" becomes device-resident HBM;
"zero-copy" becomes **buffer donation** — ``write_slot`` donates the pool
array, so XLA aliases the update in place (one dynamic-update-slice, no
fresh allocation), and the consumer binds the slot as a dynamic-slice view
that fuses into its first matmul.  Between *submeshes* the hand-off is a
sharding-preserving device_put (pure ICI, never through the host) — see
core/scheduler.SubmeshPipe.

The control plane (this class) is host-side Python — exactly like the
paper's lightweight CPU runtime: it never touches token data, only slot
states, and provides the scheduling signals (occupancy) the power policy
reads.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

FREE = 0
ALLOCATED_FOR_WRITE = 1
READY_TO_READ = 2
ALLOCATED_FOR_READ = 3

_STATE_NAMES = {FREE: "FREE", ALLOCATED_FOR_WRITE: "ALLOCATED_FOR_WRITE",
                READY_TO_READ: "READY_TO_READ",
                ALLOCATED_FOR_READ: "ALLOCATED_FOR_READ"}

_VALID = {FREE: {ALLOCATED_FOR_WRITE},
          ALLOCATED_FOR_WRITE: {READY_TO_READ, FREE},
          READY_TO_READ: {ALLOCATED_FOR_READ},
          ALLOCATED_FOR_READ: {FREE}}


class TABMError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# device ops (data plane) — donation = the TPU zero-copy
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _write_slot(pool: jnp.ndarray, slot: jnp.ndarray,
                embeds: jnp.ndarray) -> jnp.ndarray:
    """pool (n_slots, max_tokens, d) <- embeds (tokens, d) at `slot`.

    The pool is DONATED: XLA writes in place (alias), the paper's
    'NPU writes embeddings directly into a buffer slot'.  The slot's padded
    tail is zeroed by construction (fresh zeros buffer), so no dead
    valid-length argument rides through the jitted signature — the host
    control plane tracks n_tokens in ``self.tokens``."""
    t, d = embeds.shape
    padded = jnp.zeros((pool.shape[1], d), pool.dtype)
    padded = jax.lax.dynamic_update_slice(padded, embeds.astype(pool.dtype),
                                          (0, 0))
    return jax.lax.dynamic_update_slice(pool, padded[None],
                                        (slot, 0, 0))


@jax.jit
def _read_slot(pool: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """Bind a slot as consumer input.  Under jit this dynamic-slice fuses
    into the consumer's first op — no copy materializes."""
    return jax.lax.dynamic_slice(
        pool, (slot, 0, 0), (1, pool.shape[1], pool.shape[2]))[0]


# ---------------------------------------------------------------------------
# control plane
# ---------------------------------------------------------------------------

@dataclass
class RingBuffer:
    """One TABM pool: device array + host-side slot state machine."""

    n_slots: int
    max_tokens: int
    dim: int
    dtype: str = "bfloat16"
    sharding: Optional[jax.sharding.NamedSharding] = None

    def __post_init__(self):
        pool = jnp.zeros((self.n_slots, self.max_tokens, self.dim),
                         jnp.dtype(self.dtype))
        if self.sharding is not None:
            pool = jax.device_put(pool, self.sharding)
        self.pool = pool
        self.states: List[int] = [FREE] * self.n_slots
        self.tokens: List[int] = [0] * self.n_slots
        self._write_ptr = 0
        self._read_ptr = 0
        self.stats = {"writes": 0, "reads": 0, "stalls": 0}

    # -- state machine ------------------------------------------------------
    def _transition(self, slot: int, to: int):
        frm = self.states[slot]
        if to not in _VALID[frm]:
            raise TABMError(
                f"slot {slot}: illegal {_STATE_NAMES[frm]} -> "
                f"{_STATE_NAMES[to]}")
        self.states[slot] = to

    def acquire_write(self) -> Optional[int]:
        """Producer asks for a slot; None = ring full (producer must stall —
        the paper's producer/consumer smoothing signal)."""
        slot = self._write_ptr
        if self.states[slot] != FREE:
            self.stats["stalls"] += 1
            return None
        self._transition(slot, ALLOCATED_FOR_WRITE)
        self._write_ptr = (slot + 1) % self.n_slots
        return slot

    def commit_write(self, slot: int, embeds: jnp.ndarray):
        """Zero-copy write (donated pool) then mark READY_TO_READ."""
        if self.states[slot] != ALLOCATED_FOR_WRITE:
            raise TABMError(f"commit on slot {slot} in "
                            f"{_STATE_NAMES[self.states[slot]]}")
        n = embeds.shape[0]
        if n > self.max_tokens:
            raise TABMError(f"{n} tokens > slot capacity {self.max_tokens}")
        self.pool = _write_slot(self.pool, jnp.asarray(slot), embeds)
        self.tokens[slot] = n
        self._transition(slot, READY_TO_READ)
        self.stats["writes"] += 1

    def abort_write(self, slot: int):
        """Producer abandons an acquired slot.  FIFO ring: only the most
        recently acquired slot can abort, and the write pointer rewinds to
        it — otherwise a later commit would land ahead of the read pointer
        and wedge the ring (reads stuck on a FREE slot)."""
        if self.states[slot] == ALLOCATED_FOR_WRITE \
                and (slot + 1) % self.n_slots != self._write_ptr:
            raise TABMError(f"abort_write out of order: slot {slot} is not "
                            f"the most recent acquire")
        self._transition(slot, FREE)
        self._write_ptr = slot

    def acquire_read(self) -> Optional[Tuple[int, jnp.ndarray, int]]:
        """Consumer takes the oldest READY slot: (slot, view, n_tokens)."""
        slot = self._read_ptr
        if self.states[slot] != READY_TO_READ:
            return None
        self._transition(slot, ALLOCATED_FOR_READ)
        self._read_ptr = (slot + 1) % self.n_slots
        view = _read_slot(self.pool, jnp.asarray(slot))
        self.stats["reads"] += 1
        return slot, view, self.tokens[slot]

    def release(self, slot: int):
        """Consumer returns a slot.  Only legal from ALLOCATED_FOR_READ —
        a producer abandoning a write must use abort_write."""
        if self.states[slot] != ALLOCATED_FOR_READ:
            raise TABMError(f"release on slot {slot} in "
                            f"{_STATE_NAMES[self.states[slot]]}")
        self._transition(slot, FREE)
        self.tokens[slot] = 0

    # -- signals ------------------------------------------------------------
    @property
    def occupancy(self) -> float:
        busy = sum(s != FREE for s in self.states)
        return busy / self.n_slots

    def ready_count(self) -> int:
        return sum(s == READY_TO_READ for s in self.states)

    @property
    def nbytes(self) -> int:
        return self.pool.size * self.pool.dtype.itemsize
