"""Token-Aware Buffer Manager (TABM) — the paper's zero-copy hand-off
(§3.2 "Embeddings Zero-Copy Transfer in Unified Memory"), now a
*thread-safe* producer/consumer ring so the vision encoder really runs
concurrently with decode (docs/TABM.md has the full contract).

NANOMIND's TABM manages a shared ring-buffer pool in unified DRAM: the NPU
encoder (producer) writes embeddings directly into a slot which the GPU
decoder (consumer) binds as input — no CPU staging copy.  Slot lifecycle:

    EMPTY -> STAGING -> READY -> CONSUMED -> EMPTY

(the paper's FREE / ALLOCATED_FOR_WRITE / READY_TO_READ /
ALLOCATED_FOR_READ; the old names remain importable aliases).

TPU adaptation (DESIGN.md §2): "unified DRAM" becomes device-resident HBM;
"zero-copy" becomes **buffer donation** — ``commit_write`` donates the pool
array, so XLA aliases the update in place (one dynamic-update-slice, no
fresh allocation), and the consumer binds the slot as a dynamic-slice view
that fuses into its first matmul.  Between *submeshes* the hand-off is a
sharding-preserving device_put (pure ICI, never through the host) — see
core/scheduler.SubmeshPipe.

Concurrency model (the async producer/consumer engine, serving/engine.py):

* every state transition happens under one ``threading.Condition``; device
  ops on the pool (``_write_slot`` donation, ``_read_slot`` bind) also run
  under it, because donation invalidates the previous pool buffer and a
  concurrent reader must never dispatch against a donated array;
* ``acquire_write(block=True)`` stalls the *producer thread* on a FULL
  ring — the paper's backpressure signal — instead of making the engine's
  admission loop poll; ``close()`` wakes every blocked thread for shutdown;
* ``acquire_write_many`` / ``commit_many`` / ``abort_many`` are the
  strided-slab forms: K FIFO-contiguous slots acquired all-or-nothing,
  written by ONE donated scatter (per-slot lengths and ready events
  preserved), aborted as a whole run on failure — the batched staging
  pipeline's ring contract (docs/TABM.md § Strided slab commits);
* :meth:`wait_ready` is the per-slot ready wait: the consumer blocks on
  exactly the slot it is waiting for (engine prefill binds slot k without
  scanning the ring), and is woken — with a False result — if that slot's
  write is aborted or the ring closes;
* a seqlock-style **generation counter** per slot increments on every
  transition: a consumer that captured ``(view, gen)`` at ``acquire_read``
  can assert with :meth:`view_valid` that its zero-copy view still belongs
  to its request and the slot was not recycled underneath it (the same
  counter lets ``wait_ready`` distinguish this lifecycle's commit from a
  later request's).

The control plane (this class) is host-side Python — exactly like the
paper's lightweight CPU runtime: it never touches token data, only slot
states, and provides the scheduling signals (occupancy, staged-ahead
depth) the power policy and admission read.
"""
from __future__ import annotations

import functools
import threading
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

EMPTY = 0
STAGING = 1
READY = 2
CONSUMED = 3

# legacy names (paper §3.2 wording) — same state machine
FREE = EMPTY
ALLOCATED_FOR_WRITE = STAGING
READY_TO_READ = READY
ALLOCATED_FOR_READ = CONSUMED

_STATE_NAMES = {EMPTY: "EMPTY", STAGING: "STAGING", READY: "READY",
                CONSUMED: "CONSUMED"}

_VALID = {EMPTY: {STAGING},
          STAGING: {READY, EMPTY},
          READY: {CONSUMED},
          CONSUMED: {EMPTY}}


class TABMError(RuntimeError):
    pass


class TABMClosed(TABMError):
    """Raised/signalled when the ring was closed while a thread waited."""


# ---------------------------------------------------------------------------
# device ops (data plane) — donation = the TPU zero-copy
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _write_slot(pool: jnp.ndarray, slot: jnp.ndarray,
                embeds: jnp.ndarray) -> jnp.ndarray:
    """pool (n_slots, max_tokens, d) <- embeds (tokens, d) at `slot`.

    The pool is DONATED: XLA writes in place (alias), the paper's
    'NPU writes embeddings directly into a buffer slot'.  The slot's padded
    tail is zeroed by construction (fresh zeros buffer), so no dead
    valid-length argument rides through the jitted signature — the host
    control plane tracks n_tokens in ``self.tokens``."""
    t, d = embeds.shape
    padded = jnp.zeros((pool.shape[1], d), pool.dtype)
    padded = jax.lax.dynamic_update_slice(padded, embeds.astype(pool.dtype),
                                          (0, 0))
    return jax.lax.dynamic_update_slice(pool, padded[None],
                                        (slot, 0, 0))


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_slab(pool: jnp.ndarray, slots: jnp.ndarray,
                embeds: jnp.ndarray, lengths: jnp.ndarray) -> jnp.ndarray:
    """pool (n_slots, max_tokens, d) <- embeds (K, T, d) at rows `slots` —
    ONE strided scatter covering the whole microbatch (K slots written in
    a single donated device op, the batched form of :func:`_write_slot`).
    Each row's tail beyond its true length is zeroed, preserving the
    padded-tail-is-zero invariant of the K=1 write."""
    k, t, d = embeds.shape
    slab = jnp.zeros((k, pool.shape[1], d), pool.dtype)
    slab = jax.lax.dynamic_update_slice(slab, embeds.astype(pool.dtype),
                                        (0, 0, 0))
    mask = jnp.arange(pool.shape[1])[None, :, None] < lengths[:, None, None]
    return pool.at[slots].set(jnp.where(mask, slab, 0))


@jax.jit
def _read_slot(pool: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """Bind a slot as consumer input.  Under jit this dynamic-slice fuses
    into the consumer's first op — no copy materializes."""
    return jax.lax.dynamic_slice(
        pool, (slot, 0, 0), (1, pool.shape[1], pool.shape[2]))[0]


# ---------------------------------------------------------------------------
# control plane
# ---------------------------------------------------------------------------

@dataclass
class RingBuffer:
    """One TABM pool: device array + thread-safe host-side slot machine."""

    n_slots: int
    max_tokens: int
    dim: int
    dtype: str = "bfloat16"
    sharding: Optional[jax.sharding.NamedSharding] = None

    def __post_init__(self):
        pool = jnp.zeros((self.n_slots, self.max_tokens, self.dim),
                         jnp.dtype(self.dtype))
        if self.sharding is not None:
            pool = jax.device_put(pool, self.sharding)
        self.pool = pool
        self.states: List[int] = [EMPTY] * self.n_slots
        self.tokens: List[int] = [0] * self.n_slots
        # seqlock-style: +1 on every transition; captured at acquire_read
        # so a zero-copy view can be validated against slot recycling
        self.generation: List[int] = [0] * self.n_slots
        self._write_ptr = 0
        self._read_ptr = 0
        # consumer refcount per slot: acquire_read pins with 1, addref
        # pins further bucket-matched sharers; release drops the slot back
        # to EMPTY only at zero, so one staged embedding can feed >1
        # prefill (prefix/repeated-image reuse)
        self.refs: List[int] = [0] * self.n_slots
        self._cond = threading.Condition()
        self._closed = False
        self.stats = {"writes": 0, "reads": 0, "stalls": 0, "aborts": 0,
                      "slab_commits": 0, "shares": 0}

    # -- state machine (always called with self._cond held) -----------------
    def _transition(self, slot: int, to: int):
        """Caller must hold ``self._cond`` (enforced by replint
        lock-discipline: every call site is checked)."""
        frm = self.states[slot]
        if to not in _VALID[frm]:
            raise TABMError(
                f"slot {slot}: illegal {_STATE_NAMES[frm]} -> "
                f"{_STATE_NAMES[to]}")
        self.states[slot] = to
        self.generation[slot] += 1

    def acquire_write(self, block: bool = False,
                      timeout: Optional[float] = None) -> Optional[int]:
        """Producer asks for a slot; None = ring full (producer must stall —
        the paper's producer/consumer smoothing signal).

        ``block=True`` parks the calling thread until the head slot frees
        (the async engine's StagingWorker stalls *here*, off the step
        loop); returns None only on timeout or :meth:`close`."""
        with self._cond:
            if self.states[self._write_ptr] != EMPTY:
                self.stats["stalls"] += 1
            if block:
                ok = self._cond.wait_for(
                    lambda: self._closed
                    or self.states[self._write_ptr] == EMPTY,
                    timeout)
                if not ok or self._closed:
                    return None
            slot = self._write_ptr
            if self.states[slot] != EMPTY:
                return None
            self._transition(slot, STAGING)
            self._write_ptr = (slot + 1) % self.n_slots
            return slot

    def commit_write(self, slot: int, embeds: jnp.ndarray):
        """Zero-copy write (donated pool) then mark READY."""
        with self._cond:
            if self.states[slot] != STAGING:
                raise TABMError(f"commit on slot {slot} in "
                                f"{_STATE_NAMES[self.states[slot]]}")
            n = embeds.shape[0]
            if n > self.max_tokens:
                raise TABMError(
                    f"{n} tokens > slot capacity {self.max_tokens}")
            # donation invalidates the old pool buffer — must not race a
            # concurrent _read_slot dispatch, hence inside the lock
            self.pool = _write_slot(self.pool, jnp.asarray(slot), embeds)
            self.tokens[slot] = n
            self._transition(slot, READY)
            self.stats["writes"] += 1
            self._cond.notify_all()

    def abort_write(self, slot: int):
        """Producer abandons an acquired slot (staging failed or the engine
        is shutting down).  FIFO ring: only the most recently acquired slot
        can abort, and the write pointer rewinds to it — otherwise a later
        commit would land ahead of the read pointer and wedge the ring
        (reads stuck on an EMPTY slot)."""
        with self._cond:
            if self.states[slot] != STAGING:
                raise TABMError(f"abort_write on slot {slot} in "
                                f"{_STATE_NAMES[self.states[slot]]} — only "
                                f"a STAGING slot can abort (consumers use "
                                f"release)")
            if (slot + 1) % self.n_slots != self._write_ptr:
                raise TABMError(
                    f"abort_write out of order: slot {slot} is not "
                    f"the most recent acquire")
            self._transition(slot, EMPTY)
            self.tokens[slot] = 0
            self._write_ptr = slot
            self.stats["aborts"] += 1
            self._cond.notify_all()

    # -- strided multi-slot producer ops (the batched staging pipeline) -----
    def _head_run_free(self, k: int) -> bool:
        """True when the k slots from the write pointer are all EMPTY.
        FIFO invariant: EMPTY slots form one contiguous run starting at
        the write pointer, so this is *the* k-slot availability check."""
        return all(self.states[(self._write_ptr + i) % self.n_slots] == EMPTY
                   for i in range(k))

    def acquire_write_many(self, k: int, block: bool = False,
                           timeout: Optional[float] = None
                           ) -> Optional[List[int]]:
        """Producer asks for k FIFO-contiguous slots at once — the write
        side of one strided slab commit.  All-or-nothing: either the whole
        run from the write pointer is EMPTY (each slot moves to STAGING,
        in order) or None is returned (ring cannot hold the microbatch
        yet — the caller stalls, exactly like the K=1 backpressure).

        ``block=True`` parks the calling thread until k slots free from
        the head (or timeout / :meth:`close`).  ``k`` may not exceed the
        ring capacity — a microbatch that can never fit is a caller bug,
        not backpressure."""
        if k < 1 or k > self.n_slots:
            raise TABMError(f"cannot acquire {k} slots from a "
                            f"{self.n_slots}-slot ring")
        with self._cond:
            if not self._head_run_free(k):
                self.stats["stalls"] += 1
            if block:
                ok = self._cond.wait_for(
                    lambda: self._closed or self._head_run_free(k), timeout)
                if not ok or self._closed:
                    return None
            if not self._head_run_free(k):
                return None
            slots = []
            for _ in range(k):
                slot = self._write_ptr
                self._transition(slot, STAGING)
                self._write_ptr = (slot + 1) % self.n_slots
                slots.append(slot)
            return slots

    def _check_slab_run(self, slots: List[int], op: str):
        """Slab ops cover one contiguous FIFO run of STAGING slots."""
        if not slots:
            raise TABMError(f"{op} with no slots")
        for a, b in zip(slots, slots[1:]):
            if (a + 1) % self.n_slots != b:
                raise TABMError(f"{op} slots {slots} are not one "
                                f"contiguous FIFO run")
        for slot in slots:
            if self.states[slot] != STAGING:
                raise TABMError(f"{op} on slot {slot} in "
                                f"{_STATE_NAMES[self.states[slot]]}")

    def commit_many(self, slots: List[int], embeds: jnp.ndarray,
                    lengths: Optional[List[int]] = None):
        """One strided slab write covering the whole microbatch: embeds
        (K, T, d) lands in the K acquired slots as a single donated
        scatter (:func:`_write_slab`), then every slot flips to READY —
        each bump of its generation wakes that slot's :meth:`wait_ready`
        waiters individually, so per-slot ready semantics are identical
        to K sequential commits.  ``lengths`` carries each request's true
        token count (default: T for all)."""
        with self._cond:
            k = len(slots)
            if embeds.ndim != 3 or embeds.shape[0] != k:
                raise TABMError(f"slab embeds {embeds.shape} do not cover "
                                f"{k} slots")
            lengths = [int(embeds.shape[1])] * k if lengths is None \
                else [int(n) for n in lengths]
            if len(lengths) != k:
                raise TABMError(f"{len(lengths)} lengths for {k} slots")
            self._check_slab_run(slots, "commit_many")
            if embeds.shape[1] > self.max_tokens:
                raise TABMError(f"{embeds.shape[1]} tokens > slot capacity "
                                f"{self.max_tokens}")
            for n in lengths:
                if n > embeds.shape[1]:
                    raise TABMError(f"length {n} > slab width "
                                    f"{embeds.shape[1]}")
            # donation invalidates the old pool buffer — same lock
            # discipline as commit_write
            self.pool = _write_slab(self.pool,
                                    jnp.asarray(slots, jnp.int32), embeds,
                                    jnp.asarray(lengths, jnp.int32))
            for slot, n in zip(slots, lengths):
                self.tokens[slot] = n
                self._transition(slot, READY)
            self.stats["writes"] += k
            if k > 1:
                self.stats["slab_commits"] += 1
            self._cond.notify_all()

    def abort_many(self, slots: List[int]):
        """Abort-all-on-failure for a slab acquisition: the whole run goes
        back to EMPTY and the write pointer rewinds to its first slot.
        Same FIFO invariant as :meth:`abort_write` — the run must be the
        most recent acquisition, or a later commit could land ahead of
        the read pointer and wedge the ring."""
        with self._cond:
            self._check_slab_run(slots, "abort_many")
            if (slots[-1] + 1) % self.n_slots != self._write_ptr:
                raise TABMError(
                    f"abort_many out of order: slots {slots} are not the "
                    f"most recent acquisition")
            for slot in reversed(slots):
                self._transition(slot, EMPTY)
                self.tokens[slot] = 0
            self._write_ptr = slots[0]
            self.stats["aborts"] += len(slots)
            self._cond.notify_all()

    def acquire_read(self, block: bool = False,
                     timeout: Optional[float] = None
                     ) -> Optional[Tuple[int, jnp.ndarray, int]]:
        """Consumer takes the oldest READY slot: (slot, view, n_tokens)."""
        with self._cond:
            if block:
                ok = self._cond.wait_for(
                    lambda: self._closed
                    or self.states[self._read_ptr] == READY,
                    timeout)
                if not ok or self._closed:
                    return None
            slot = self._read_ptr
            if self.states[slot] != READY:
                return None
            self._transition(slot, CONSUMED)
            self._read_ptr = (slot + 1) % self.n_slots
            self.refs[slot] = 1
            view = _read_slot(self.pool, jnp.asarray(slot))
            self.stats["reads"] += 1
            return slot, view, self.tokens[slot]

    def addref(self, slot: int, gen: int) -> bool:
        """Pin an already-CONSUMED slot for one more bucket-matched
        consumer (the seqlock generation captured by the first consumer
        must still match, i.e. the slot was not recycled).  Each addref
        must be paired with one :meth:`release`; the slot returns to
        EMPTY only when every holder has released.  Returns False when
        the slot moved on — the caller stages its own copy instead."""
        with self._cond:
            if self.states[slot] != CONSUMED or self.generation[slot] != gen:
                return False
            self.refs[slot] += 1
            self.stats["shares"] += 1
            return True

    def shared_view(self, slot: int, gen: int
                    ) -> Optional[Tuple[jnp.ndarray, int]]:
        """Zero-copy (view, n_tokens) of a CONSUMED slot for a sharing
        holder (:meth:`addref`), or None when the slot was recycled
        (generation mismatch) — the read-side twin of acquire_read that
        does not advance the FIFO read pointer."""
        with self._cond:
            if self.states[slot] != CONSUMED or self.generation[slot] != gen:
                return None
            return (_read_slot(self.pool, jnp.asarray(slot)),
                    self.tokens[slot])

    def release(self, slot: int):
        """Consumer returns a slot.  Only legal from CONSUMED — a producer
        abandoning a write must use abort_write.  With sharing
        (:meth:`addref`) each release drops one reference; the slot stays
        CONSUMED — generation untouched, other holders' views still
        seqlock-valid — until the last holder releases."""
        with self._cond:
            if self.states[slot] != CONSUMED:
                raise TABMError(f"release on slot {slot} in "
                                f"{_STATE_NAMES[self.states[slot]]}")
            self.refs[slot] -= 1
            if self.refs[slot] > 0:
                return
            self.refs[slot] = 0
            self._transition(slot, EMPTY)
            self.tokens[slot] = 0
            self._cond.notify_all()

    # -- per-slot waiting / seqlock validation ------------------------------
    def wait_ready(self, slot: int, timeout: Optional[float] = None) -> bool:
        """Block until `slot` is committed (READY or beyond).  The engine's
        consumer half waits here — on the exact slot its request owns —
        instead of polling the ring.

        Returns False on timeout, on :meth:`close`, or when the slot's
        current lifecycle ends without a commit (the producer aborted) —
        detected via the generation counter, so a waiter can never hang on
        a slot that will no longer become READY, nor mistake a later
        request's commit (after abort + recycle) for its own.  Call with
        the slot in STAGING or later."""
        with self._cond:
            st = self.states[slot]
            if st in (READY, CONSUMED):
                return True
            if st != STAGING:
                return False                   # no live write to wait on
            g0 = self.generation[slot]         # this lifecycle's STAGING gen
            self._cond.wait_for(
                lambda: self._closed or self.generation[slot] != g0,
                timeout)                       # any transition ends the wait
            # committed in THIS lifecycle — not a later request's commit
            # after an abort recycled the slot (generation arithmetic:
            # commit bumps to g0+1, a subsequent consume to g0+2)
            return (not self._closed
                    and ((self.states[slot] == READY
                          and self.generation[slot] == g0 + 1)
                         or (self.states[slot] == CONSUMED
                             and self.generation[slot] == g0 + 2)))

    def slot_generation(self, slot: int) -> int:
        with self._cond:
            return self.generation[slot]

    def view_valid(self, slot: int, gen: int) -> bool:
        """Seqlock check: a view captured at acquire_read (generation `gen`)
        is valid while the slot is still CONSUMED at that generation — i.e.
        it was not released/recycled for a later request."""
        with self._cond:
            return self.states[slot] == CONSUMED \
                and self.generation[slot] == gen

    # -- shutdown / drain ---------------------------------------------------
    def close(self):
        """Wake every thread blocked in acquire_write/acquire_read; they
        return None.  Idempotent; part of the engine drain protocol."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    def drain(self) -> int:
        """Release every READY and CONSUMED slot in FIFO order so the ring
        ends fully EMPTY (engine shutdown with staged-but-unconsumed
        slots).  STAGING slots are the producer's to abort — draining with
        one still staging means the worker was not joined first."""
        drained = 0
        with self._cond:
            if any(s == STAGING for s in self.states):
                raise TABMError("drain with a slot still STAGING — join the "
                                "producer thread before draining")
            # consumed-but-unreleased slots belong to requests that will
            # never prefill; recycle them
            for slot in range(self.n_slots):
                if self.states[slot] == CONSUMED:
                    self._transition(slot, EMPTY)
                    self.tokens[slot] = 0
                    self.refs[slot] = 0        # outstanding shares are void
                    drained += 1
            while self.states[self._read_ptr] == READY:
                slot = self._read_ptr
                self._transition(slot, CONSUMED)
                self._transition(slot, EMPTY)
                self.tokens[slot] = 0
                self.refs[slot] = 0
                self._read_ptr = (slot + 1) % self.n_slots
                drained += 1
            self._cond.notify_all()
        return drained

    # -- signals ------------------------------------------------------------
    @property
    def occupancy(self) -> float:
        busy = sum(s != EMPTY for s in self.states)
        return busy / self.n_slots

    def ready_count(self) -> int:
        return sum(s == READY for s in self.states)

    def staged_ahead(self) -> int:
        """Slots the producer holds ahead of the consumer (STAGING+READY) —
        the admission-depth signal core/scheduler.staging_budget reads."""
        return sum(s in (STAGING, READY) for s in self.states)

    @property
    def nbytes(self) -> int:
        return self.pool.size * self.pool.dtype.itemsize


# ---------------------------------------------------------------------------
# class-partitioned pool (core/slot_classes defines the classes)
# ---------------------------------------------------------------------------

class SlotClassPool:
    """Class-partitioned TABM: one :class:`RingBuffer` per request class.

    The single-ring pool pads every request into one ``max_tokens`` slab
    and admits against one FIFO depth, so a 1-image thumbnail competes
    with (and is starved behind) a 4-image full-resolution request.  The
    pool partitions both resources by :class:`~repro.core.slot_classes.
    SlotClass` (image-count bucket × resolution bucket, from the arch
    config):

    * each class ring's ``max_tokens`` is the class slab — a thumbnail
      slot is thumbnail-sized, and an oversized commit into the wrong
      class raises :class:`TABMError` exactly like ring overflow;
    * each class has its own admission depth (``max_ahead``), charged per
      class at hand-off (``core/scheduler.class_staging_budgets``), so a
      FULL high-resolution ring stalls only its own class's producer;
    * :meth:`admission_table` scales depths for the battery policy
      (``Knobs.class_depth_scale``): the highest-resolution class shrinks
      first and most, the smallest class keeps full depth.

    Class rings **materialize lazily** on first use (:meth:`ring`): the
    cross product of image × resolution buckets describes what traffic
    *may* arrive, and only the classes that actually do arrive allocate a
    device pool — single-image traffic never pays for the 4-image
    full-resolution slab.  The aggregate signal surface (``states`` /
    ``stats`` / ``occupancy`` / ``staged_ahead`` / ``drain`` / ``close``)
    matches RingBuffer so existing consumers of the single ring keep
    reading one pool; aggregates cover the materialized rings (an
    unmaterialized ring is trivially EMPTY and holds zero bytes)."""

    def __init__(self, classes, dim: int, dtype: str = "bfloat16",
                 sharding=None):
        ordered = sorted(classes.values(), key=lambda c: c.sort_key)
        self.classes = {c.name: c for c in ordered}
        self.dim, self.dtype, self.sharding = dim, dtype, sharding
        self._rings: "dict[str, RingBuffer]" = {}
        self._closed = False

    @classmethod
    def from_config(cls, cfg, dim: Optional[int] = None,
                    slots_per_class: int = 2, dtype: str = "bfloat16",
                    sharding=None) -> "SlotClassPool":
        from repro.core.slot_classes import build_slot_classes
        return cls(build_slot_classes(cfg, slots_per_class),
                   dim=dim or cfg.d_model, dtype=dtype, sharding=sharding)

    # -- class lookup -------------------------------------------------------
    def names(self) -> List[str]:
        return list(self.classes)

    @property
    def rings(self) -> "dict[str, RingBuffer]":
        """The rings materialized so far (classes traffic has touched)."""
        return dict(self._rings)

    def ring(self, name: str) -> RingBuffer:
        """The class's ring, materialized on first use (lazy: a class no
        request ever lands in allocates no device pool)."""
        if name not in self.classes:
            raise TABMError(f"unknown slot class {name!r}; classes: "
                            f"{list(self.classes)}")
        if name not in self._rings:
            c = self.classes[name]
            r = RingBuffer(n_slots=c.n_slots, max_tokens=c.max_tokens,
                           dim=self.dim, dtype=self.dtype,
                           sharding=self.sharding)
            if self._closed:               # pool already shut down: the
                r.close()                  # new ring is born closed
            self._rings[name] = r
        return self._rings[name]

    def class_nbytes(self, name: str) -> int:
        """Analytic pool bytes of one class ring (whether or not it has
        materialized)."""
        c = self.classes[name]
        return c.n_slots * c.max_tokens * self.dim \
            * jnp.dtype(self.dtype).itemsize

    def classify(self, n_tokens: int, n_images: int = 1) -> str:
        from repro.core.slot_classes import classify
        return classify(self.classes, n_tokens, n_images).name

    def classify_total(self, n_tokens: int) -> str:
        from repro.core.slot_classes import classify_total
        return classify_total(self.classes, n_tokens).name

    def ring_for_tokens(self, n_tokens: int, n_images: int = 1
                        ) -> RingBuffer:
        return self.ring(self.classify(n_tokens, n_images))

    # -- strided multi-slot ops, per class ----------------------------------
    def acquire_write_many(self, name: str, k: int, block: bool = False,
                           timeout: Optional[float] = None
                           ) -> Optional[List[int]]:
        """k FIFO-contiguous slots of `name`'s class ring — the write side
        of one same-class strided slab commit (see RingBuffer)."""
        return self.ring(name).acquire_write_many(k, block, timeout)

    def commit_many(self, name: str, slots: List[int], embeds: jnp.ndarray,
                    lengths: Optional[List[int]] = None):
        return self.ring(name).commit_many(slots, embeds, lengths)

    def abort_many(self, name: str, slots: List[int]):
        return self.ring(name).abort_many(slots)

    # -- admission (the per-class {slot_class: (ring, max_ahead)} table) ----
    def max_ahead(self, name: str) -> int:
        c = self.classes[name]
        # class n_slots == ring capacity by construction; reading the spec
        # (not the ring) keeps unmaterialized classes unmaterialized
        return c.max_ahead if c.max_ahead is not None else c.n_slots

    def admission_table(self, depth_scale: float = 1.0
                        ) -> "dict[str, Tuple[Optional[RingBuffer], int]]":
        """``{slot_class: (ring, max_ahead)}`` under a battery depth scale.
        The ring element is None while the class is unmaterialized (lazy:
        nothing can be staged ahead in a ring that does not exist yet).

        ``depth_scale`` (``core/power.Knobs.class_depth_scale``, 1.0 when
        unconstrained) shrinks admission depth *high-resolution-first*:
        classes are ranked by slab size, the largest class scales fully by
        ``depth_scale`` (down to 0 — fully gated), intermediate classes
        proportionally less, and the smallest class keeps its full depth,
        so thumbnails keep flowing while the battery drains."""
        from repro.core.slot_classes import shed_scales
        table = {}
        for name, eff in shed_scales(self.classes, depth_scale).items():
            base = self.max_ahead(name)
            table[name] = (self._rings.get(name),
                           max(0, min(base, int(base * eff))))
        return table

    # -- aggregate signal surface (RingBuffer-compatible) -------------------
    @property
    def n_slots(self) -> int:
        """Total slot capacity across all classes (static — independent of
        which class rings have materialized)."""
        return sum(c.n_slots for c in self.classes.values())

    @property
    def states(self) -> List[int]:
        """Slot states of the materialized rings (an unmaterialized class
        contributes nothing — all its slots are trivially EMPTY)."""
        return [s for r in self._rings.values() for s in r.states]

    @property
    def stats(self) -> "dict[str, int]":
        agg = {"writes": 0, "reads": 0, "stalls": 0, "aborts": 0,
               "slab_commits": 0, "shares": 0}
        for r in self._rings.values():
            for k in agg:
                agg[k] += r.stats[k]
        return agg

    @property
    def occupancy(self) -> float:
        busy = sum(s != EMPTY for s in self.states)
        return busy / max(1, self.n_slots)

    def ready_count(self) -> int:
        return sum(r.ready_count() for r in self._rings.values())

    def staged_ahead(self) -> int:
        return sum(r.staged_ahead() for r in self._rings.values())

    @property
    def nbytes(self) -> int:
        """Allocated pool bytes — only materialized class rings count,
        which is the memory win over one eagerly-sized maximal ring."""
        return sum(r.nbytes for r in self._rings.values())

    # -- shutdown / per-class drain protocol --------------------------------
    def close(self):
        """Close every materialized class ring — wakes all per-class
        producer threads parked in ``acquire_write`` (engine shutdown
        fan-out).  Classes materialized afterwards are born closed."""
        self._closed = True
        for r in self._rings.values():
            r.close()

    @property
    def closed(self) -> bool:
        return self._closed

    def drain(self) -> int:
        """Per-class drain: every materialized class ring releases its
        READY/CONSUMED slots back to EMPTY.  Same precondition as the
        single ring, per class — a STAGING slot belongs to that class's
        live producer, so all per-class producer threads must be joined
        first."""
        staging = [n for n, r in self._rings.items()
                   if any(s == STAGING for s in r.states)]
        if staging:
            raise TABMError(f"drain with class(es) {staging} still STAGING "
                            f"— join the per-class producer threads first")
        return sum(r.drain() for r in self._rings.values())
