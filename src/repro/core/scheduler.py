"""Cross-accelerator, module-level scheduler (paper §3.2).

NANOMIND's central mechanism: map each brick to the compute unit whose
characteristics match it — "NPUs excel at low-bit tensor ops but are
inefficient for floating-point workloads; GPUs are far better at large-scale
parallel floating-point".  Two instantiations share one cost model:

* **Edge profile** (the paper's RK3566): NPU / GPU / CPU accelerators with
  the paper's constraints — the NPU only takes *static-shape* bricks
  (§NPU: recompiling on shape change is impractical) and prefers low-bit;
  the CPU is the fallback.  Used by the Fig. 5/6/8 benchmarks.

* **Pod profile** (this repo's target): a TPU pod is silicon-homogeneous,
  so accelerator heterogeneity becomes *profile heterogeneity* —
  :func:`make_virtual_accelerators` slices the pod's "model" axis into
  submeshes (encoder slice ≙ NPU, decoder slice ≙ GPU) each with its own
  quantization/static-shape profile.  Hand-off between submeshes is a
  sharding-preserving device_put (pure ICI; never through the host) —
  the TABM edge at pod scale.

Placement is exact chain dynamic programming over the BrickGraph (the
pipelines are chains): dp[i][acc] = best cost of placing brick i on acc,
including the edge-transfer term.  The objective (latency | energy) comes
from the battery policy (core/power.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.energy import (EDGE_CPU, EDGE_GPU, EDGE_NPU,
                                   EnergyProfile, TPU_V5E, step_energy,
                                   step_time)
from repro.core.backends import bit_efficiency, substrate_backend
from repro.core.bricks import Brick, BrickGraph
from repro.telemetry.calibration import CostCalibration


@dataclass(frozen=True)
class Accelerator:
    """A compute unit the scheduler can place a brick on.

    Both the cost model (:meth:`throughput_scale`) and backend resolution
    (:meth:`backend_name`) read the shared substrate table in
    ``core/backends.py`` — one row per energy profile ties the unit's
    per-bit-width throughput to the backend (and thus kernel mode) it
    lowers through, so the scheduler can never price a unit the lowering
    contradicts."""

    name: str
    profile: EnergyProfile
    static_only: bool = False          # paper §NPU: static graphs only
    dynamic_ok: bool = True
    mesh: Optional[object] = None      # submesh (pod mode)
    width: float = 1.0                 # fraction of a full unit
    backend: Optional[str] = None      # lowering substrate (core/backends
                                       # registry name); None = from the
                                       # substrate table / inferred

    def throughput_scale(self, quant_label: str) -> float:
        return bit_efficiency(self.profile.name, quant_label) * self.width

    def backend_name(self) -> str:
        """The backend this accelerator lowers bricks through: its
        explicit profile field, else the shared substrate table row of
        its energy profile, else submesh when it carries a mesh, else
        host (the paper's edge units are emulated on a pinned CPU
        thread — see core/backends.py)."""
        if self.backend:
            return self.backend
        sub = substrate_backend(self.profile.name)
        if sub is not None and not (sub == "submesh" and self.mesh is None):
            return sub
        return "submesh" if self.mesh is not None else "host"


def edge_accelerators() -> List[Accelerator]:
    """The paper's RK3566: NPU (static, low-bit), Mali GPU, Cortex CPU.

    Backends come from the shared substrate table (core/backends.py): the
    NPU and CPU lower through the thread-pinned HostBackend (the
    container has no such silicon; host threads emulate it, reference
    kernels only); the GPU lowers through the DeviceBackend (committed
    default-device streams)."""
    return [
        Accelerator("npu", EDGE_NPU, static_only=True, dynamic_ok=False),
        Accelerator("gpu", EDGE_GPU),
        Accelerator("cpu", EDGE_CPU),
    ]


def make_virtual_accelerators(mesh, fractions=(0.25, 0.75)
                              ) -> List[Accelerator]:
    """Slice the pod's "model" axis into profile-heterogeneous submeshes.

    fractions: (encoder_frac, decoder_frac) of the model axis.  The encoder
    slice runs static-shape low-bit bricks (≙ NPU); the decoder slice runs
    the W4A16 TP decode (≙ GPU)."""
    from jax.sharding import Mesh
    axis = mesh.axis_names.index("model")
    n = mesh.devices.shape[axis]
    cut = max(1, int(round(n * fractions[0])))
    sl_enc = [slice(None)] * mesh.devices.ndim
    sl_dec = [slice(None)] * mesh.devices.ndim
    sl_enc[axis] = slice(0, cut)
    sl_dec[axis] = slice(cut, n)
    enc_mesh = Mesh(mesh.devices[tuple(sl_enc)], mesh.axis_names)
    dec_mesh = Mesh(mesh.devices[tuple(sl_dec)], mesh.axis_names)
    scale = lambda f: dataclasses.replace(
        TPU_V5E, peak_flops=TPU_V5E.peak_flops * f,
        hbm_bw=TPU_V5E.hbm_bw * f)
    return [
        Accelerator("enc-submesh", scale(cut / n), static_only=True,
                    dynamic_ok=False, mesh=enc_mesh, width=cut / n),
        Accelerator("dec-submesh", scale((n - cut) / n), mesh=dec_mesh,
                    width=(n - cut) / n),
    ]


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

@dataclass
class BrickCost:
    latency_s: float
    energy_j: float
    feasible: bool = True


def brick_cost(brick: Brick, acc: Accelerator, n_tokens: int,
               mem_clock_scale: float = 1.0, batch: int = 1,
               calibration: Optional[CostCalibration] = None) -> BrickCost:
    """Roofline latency + modeled energy of ONE call over a microbatch of
    ``batch`` requests (``n_tokens`` each) on one unit.

    Batch-awareness is the staging pipeline's amortization: compute
    scales with the microbatch (``batch * n_tokens`` tokens) but the
    brick's weight traffic is charged ONCE per call — ``batch``
    independent calls would pay the weight stream ``batch`` times, so
    for memory-bound bricks (exactly the projector/prefill side the TABM
    slab batches) ``brick_cost(..., batch=K).latency_s`` is well below
    ``K * brick_cost(...).latency_s``.

    ``calibration`` is the measured-not-modeled feedback edge
    (telemetry/calibration.py): when the table holds a sample for this
    (brick, profile) — falling back to the brick's profile-agnostic
    key — the measured per-token seconds (and joules, when observed)
    override the model with sample-count weight ``n / (n + prior)``:
    empty table -> pure model, a well-observed brick -> pure
    measurement.  Infeasible stays infeasible regardless — no
    observation can put a dynamic brick on a static-only unit."""
    if not brick.static_shape and acc.static_only:
        return BrickCost(float("inf"), float("inf"), feasible=False)
    flops = brick.flops_per_token * n_tokens * max(1, batch)
    wbytes = max(brick.param_bytes, 1)
    scale = acc.throughput_scale(brick.quant_label)
    p = acc.profile
    eff = dataclasses.replace(
        p, peak_flops=p.peak_flops * max(scale, 1e-9),
        hbm_bw=p.hbm_bw * mem_clock_scale)
    t = step_time(eff, flops, wbytes)
    e = step_energy(eff, flops, wbytes, 0.0, wall_s=t)
    if calibration is not None:
        s = calibration.sample(brick.name, p.name)
        if s is not None and s.tokens > 0:
            w = calibration.weight(s.n)
            units = n_tokens * max(1, batch)
            t = (1.0 - w) * t + w * s.seconds_per_token * units
            if s.joules > 0:
                e = (1.0 - w) * e + w * s.joules_per_token * units
    return BrickCost(t, e)


def transfer_cost(bytes_moved: int, src: Accelerator, dst: Accelerator
                  ) -> Tuple[float, float]:
    """Edge hand-off: zero when staying put (TABM zero-copy); ICI/DMA
    otherwise."""
    if src.name == dst.name:
        return 0.0, 0.0
    bw = min(src.profile.link_bw, dst.profile.link_bw)
    t = bytes_moved / bw
    e = bytes_moved * (src.profile.e_link + dst.profile.e_link) / 2
    return t, e


# ---------------------------------------------------------------------------
# placement (exact chain DP)
# ---------------------------------------------------------------------------

@dataclass
class Placement:
    assignment: Dict[str, str]
    latency_s: float
    energy_j: float
    per_brick: Dict[str, BrickCost] = field(default_factory=dict)
    # brick -> backend registry name (core/backends), carried from each
    # accelerator's profile so compile_plan lowers through the same
    # substrate the cost model priced
    backends: Dict[str, str] = field(default_factory=dict)

    def __str__(self):
        cells = " | ".join(f"{b}->{a}" for b, a in self.assignment.items())
        return (f"Placement[{cells}] lat={self.latency_s*1e3:.2f}ms "
                f"E={self.energy_j:.3f}J")


def edge_bytes(graph: BrickGraph, n_tokens: int) -> int:
    """Activation bytes crossing a brick edge: (tokens, d_model) bf16."""
    return n_tokens * graph.cfg.d_model * 2


def schedule(graph: BrickGraph, accels: List[Accelerator], n_tokens: int,
             objective: str = "latency", mem_clock_scale: float = 1.0,
             batch: int = 1,
             calibration: Optional[CostCalibration] = None) -> Placement:
    """Exact DP over the brick chain.

    dp[i][a] = best objective of bricks[0..i] with brick i on accel a.
    ``batch`` prices every brick (and edge) for a microbatch of that many
    requests — the staging pipeline's unit of work — so a placement can
    be optimized for the batched regime, where weight traffic amortizes
    (``brick_cost``) and the latency/energy balance between units shifts
    toward the compute-bound ones.  ``calibration`` threads measured
    per-brick costs into every cell (see :func:`brick_cost`), so the DP
    places from observation when samples exist — a brick the table
    shows slower-than-modeled on one unit migrates off it."""
    bricks = graph.bricks
    nA = len(accels)
    costs = [[brick_cost(b, a, n_tokens, mem_clock_scale, batch=batch,
                         calibration=calibration)
              for a in accels] for b in bricks]
    xfer = edge_bytes(graph, n_tokens) * max(1, batch)

    def metric(c: BrickCost, t_extra: float, e_extra: float) -> float:
        if objective == "energy":
            return c.energy_j + e_extra
        return c.latency_s + t_extra

    INF = float("inf")
    dp = [[INF] * nA for _ in bricks]
    back: List[List[int]] = [[-1] * nA for _ in bricks]
    for a in range(nA):
        if costs[0][a].feasible:
            dp[0][a] = metric(costs[0][a], 0.0, 0.0)
    for i in range(1, len(bricks)):
        for a in range(nA):
            if not costs[i][a].feasible:
                continue
            for pa in range(nA):
                if dp[i - 1][pa] == INF:
                    continue
                tt, te = transfer_cost(xfer, accels[pa], accels[a])
                cand = dp[i - 1][pa] + metric(costs[i][a], tt, te)
                if cand < dp[i][a]:
                    dp[i][a] = cand
                    back[i][a] = pa

    last = int(np.argmin(dp[-1]))
    if dp[-1][last] == INF:
        raise RuntimeError("no feasible placement")
    order = [last]
    for i in range(len(bricks) - 1, 0, -1):
        order.append(back[i][order[-1]])
    order.reverse()

    assignment = {b.name: accels[a].name for b, a in zip(bricks, order)}
    backends = {b.name: accels[a].backend_name()
                for b, a in zip(bricks, order)}
    lat = e = 0.0
    per = {}
    prev = None
    for i, (b, a) in enumerate(zip(bricks, order)):
        c = costs[i][a]
        per[b.name] = c
        lat += c.latency_s
        e += c.energy_j
        if prev is not None and prev != a:
            tt, te = transfer_cost(xfer, accels[prev], accels[a])
            lat, e = lat + tt, e + te
        prev = a
    return Placement(assignment, lat, e, per, backends=backends)


def populate_brick_bytes(graph: BrickGraph, params) -> None:
    """Fill Brick.param_bytes from real (possibly quantized) params."""
    from repro.core.bricks import brick_param_bytes
    sizes = brick_param_bytes(graph, params)
    graph.bricks = [dataclasses.replace(b, param_bytes=sizes[b.name])
                    for b in graph.bricks]


# ---------------------------------------------------------------------------
# admission-depth hook (the async TABM producer/consumer pipeline)
# ---------------------------------------------------------------------------

def staged_ahead_depth(ring) -> int:
    """How far the producer has run ahead of the consumer: slots STAGING or
    READY in the TABM ring.  Distinct from ``ring.occupancy`` — a CONSUMED
    slot still occupies the ring but is *behind* the consumer, so it says
    nothing about how much staged work the decoder has banked."""
    return ring.staged_ahead()


def staging_budget(ring, in_flight: int, max_ahead: Optional[int] = None
                   ) -> int:
    """How many more requests the engine may hand to the staging worker.

    ``in_flight``: requests already handed over but not yet committed (the
    worker's queue + the one it is staging).  ``max_ahead`` caps total
    staged-ahead depth; default = ring size (the producer would block on
    FULL beyond that anyway, and a bounded hand-off queue keeps shutdown
    cancellation cheap).  This is the admission check the async engine
    uses instead of raw ring occupancy; the class-partitioned pool
    applies it per class via :func:`class_staging_budgets`."""
    cap = ring.n_slots if max_ahead is None else max_ahead
    return max(0, cap - staged_ahead_depth(ring) - in_flight)


def class_staging_budgets(pool, in_flight: Dict[str, int],
                          depth_scale: float = 1.0,
                          stage_batch: Optional[int] = None
                          ) -> Dict[str, int]:
    """Per-class admission budgets over a class-partitioned TABM pool.

    ``staging_budget`` grown into a table: the pool's
    ``admission_table(depth_scale)`` yields ``{slot_class: (ring,
    max_ahead)}`` — each class's own ring and its battery-scaled depth
    (``core/power.Knobs.class_depth_scale`` shrinks the high-resolution
    classes first) — and each class is charged its own budget, so a FULL
    or throttled high-resolution class never starves thumbnail admission.
    ``in_flight``: per-class hand-over counts from the engine's staging
    worker.  A class whose ring has not materialized yet (lazy pool:
    no request of that class has ever staged) has zero staged-ahead
    depth by definition.

    ``stage_batch`` makes the charge *microbatch-aware*: the engine hands
    each class's round of requests to its producer thread as ONE
    microbatch (one strided slab commit, one batched projector call), so
    a round's budget is capped at one microbatch — the class is charged a
    microbatch per round, not ``K`` independent admissions, and the
    hand-off can never outrun what one ``produce_many`` commits.
    ``Knobs.max_stage_batch`` scales it down under battery throttling
    (batch shrinks before depth sheds)."""
    budgets = {}
    for name, (ring, cap) in pool.admission_table(depth_scale).items():
        flight = in_flight.get(name, 0)
        if ring is None:                       # unmaterialized: EMPTY ring
            budget = max(0, cap - flight)
        else:
            budget = staging_budget(ring, flight, max_ahead=cap)
        if stage_batch is not None and stage_batch > 0:
            budget = min(budget, stage_batch)
        budgets[name] = budget
    return budgets


def kv_block_budgets(pool, total_blocks: int,
                     used: Dict[Optional[str], int],
                     kv_scale: float = 1.0,
                     energy_pressure: float = 1.0) -> Dict[str, int]:
    """Per-class paged-KV *block* budgets — staged-ahead depth charging
    applied to decode memory.

    The engine's :class:`~repro.serving.kv_cache.PagedKVCache` grants
    each admitted request a run of fixed-size KV blocks; this table says
    how many MORE blocks each slot class may be granted right now.  Each
    class's cap is its share of the whole block pool under
    ``core/power.Knobs.class_kv_scale``, shed high-resolution-first in
    exactly the staged-ahead order (``core/slot_classes.shed_scales``):
    at scale 1.0 every class may use the full pool (free-block count is
    the only bound), under THROTTLED the largest class's cap shrinks
    fully by the scale while the thumbnail class keeps the whole pool —
    so long-context hi-res KV grants are the first decode-side load
    shed, mirroring how ``class_staging_budgets`` sheds staging depth.

    ``used``: blocks currently granted per class
    (``PagedKVCache.used_blocks``); classes absent from it hold none.

    ``energy_pressure`` is the telemetry feedback
    (``CostCalibration.energy_pressure``): the measured-over-modeled
    decode J/token ratio.  Decode running hotter than the model priced
    (> 1) tightens the effective scale, so hi-res KV grants shed EARLIER
    than the battery knob alone would — the paged pool reacts to
    observed energy, not just predicted charge."""
    from repro.core.slot_classes import shed_scales
    eff_scale = kv_scale / max(1.0, energy_pressure)
    budgets = {}
    for name, eff in shed_scales(pool.classes, eff_scale).items():
        cap = max(0, min(total_blocks, int(total_blocks * eff)))
        budgets[name] = max(0, cap - used.get(name, 0))
    return budgets


# ---------------------------------------------------------------------------
# pod-mode hand-off (the TABM edge between submeshes)
# ---------------------------------------------------------------------------

# SubmeshPipe moved to core/transport.py (it is the degenerate — same
# process, nothing serialized — member of the Transport family);
# re-exported here because SubmeshBackend.make_edge and older callers
# import it from the scheduler.
from repro.core.transport import SubmeshPipe  # noqa: E402,F401


# ---------------------------------------------------------------------------
# disaggregated fleets (prefill fleet + decode fleet over a Transport)
# ---------------------------------------------------------------------------

def fleet_accelerators(transport, n_devices: int = 2,
                       calibration: Optional[CostCalibration] = None
                       ) -> List[Accelerator]:
    """The two-fleet disaggregated topology as scheduler rows.

    "Cost-Efficient Multimodal LLM Inference via Cross-Tier GPU
    Heterogeneity" (PAPERS.md): vision encode + batched prefill are
    compute-bound, decode is memory-bound — opposite ideal hardware, so
    each side gets its own pool.  The prefill fleet is compute-rich and
    ``static_only`` (it takes the static-shape vision/projector/prefill
    bricks; the dynamic decode bricks *cannot* land there, so the cut is
    guaranteed); the decode fleet keeps full memory bandwidth but a
    fraction of the FLOPs (cheap decode workers).  Both rows' profiles
    carry ``link_bw = transport.link_bw`` so every cross-fleet edge the
    chain DP prices is a real serialized wire crossing — the placement
    responds to the transport (``core/transport.TRANSPORTS``), not to an
    assumed ICI.  When ``calibration`` holds a link observation for this
    transport (``CostCalibration.observe_link``, fed from
    ``Transport.measured_link_bw``) the measured bytes/s blends over the
    static class row — a wire that clocks slower than its class pushes
    the split toward fewer crossings.

    The fleets lower through per-ordinal device backends
    (``"device:0"`` / ``"device:1"``) — a multi-GPU box is the
    degenerate single-host two-fleet case; with one visible device both
    fleets share ordinal 0."""
    bw = float(getattr(transport, "link_bw", 8e9))
    if calibration is not None:
        bw = calibration.link_bw(getattr(transport, "name", None), bw)
    wire = lambda p: dataclasses.replace(p, link_bw=min(p.link_bw, bw))
    # prefill fleet: a full unit (compute-rich); decode fleet: cheap
    # workers at a quarter of the FLOPs but the full memory bandwidth
    # decode's weight streaming wants
    prefill_p = TPU_V5E
    decode_p = dataclasses.replace(TPU_V5E,
                                   peak_flops=TPU_V5E.peak_flops * 0.25)
    dec_dev = "device:1" if n_devices > 1 else "device:0"
    return [
        Accelerator("prefill-fleet", wire(prefill_p), static_only=True,
                    dynamic_ok=False, backend="device:0"),
        Accelerator("decode-fleet", wire(decode_p), backend=dec_dev),
    ]


def schedule_split(graph: BrickGraph, transport, n_tokens: int,
                   objective: str = "latency", batch: int = 1,
                   calibration: Optional[CostCalibration] = None
                   ) -> Placement:
    """Price the prefill/decode split over a serialized transport.

    Runs the same exact chain DP as :func:`schedule`, but over the two
    fleet rows of :func:`fleet_accelerators` — ``transfer_cost`` then
    prices every cross-fleet edge at the transport's wire bandwidth, so
    the scheduler decides what crosses the wire per substrate table AND
    per transport: a slow socket pushes compute toward fewer crossings,
    a fast in-process channel frees the DP to cut where the roofline
    prefers.  ``transport`` may be a Transport class, instance, or
    registry name (``core/transport.resolve_transport``).

    ``calibration`` feeds BOTH blending edges: per-brick measured
    seconds into ``brick_cost`` (as in :func:`schedule`) and measured
    wire bandwidth into the fleet rows' ``link_bw``
    (``CostCalibration.observe_link`` -> :func:`fleet_accelerators`) —
    the split is repriced from what the frames actually clocked, not
    the transport's static class row."""
    if isinstance(transport, str):
        from repro.core.transport import resolve_transport
        transport = resolve_transport(transport)
    return schedule(graph,
                    fleet_accelerators(transport, calibration=calibration),
                    n_tokens, objective, batch=batch,
                    calibration=calibration)
