"""Backend lowering — one Placement, many substrates (paper §3.2).

The paper's core claim is that each LMM brick runs on its *best-suited*
compute unit (NPU / GPU / DSP).  A :class:`Backend` owns the four
substrate-specific decisions :func:`repro.core.plan.compile_plan` used to
hardcode behind ``if accel.mesh is not None`` branches:

* ``bind_params(brick, params, accel)`` — where a brick's weights live
  between executions (submesh-sharded, committed to one device, or
  host-side numpy);
* ``compile_fn(brick, cfg)`` — the brick's executable, drawn from one
  module-level jit cache (keyed ``(brick, cfg, kernel-mode)``) so the
  engine, cascade, and scheduler paths share compiled executables, and
  consulting :mod:`repro.kernels.dispatch` for the Pallas-vs-reference
  kernel decision;
* ``make_edge(src_accel, dst_accel)`` — the inbound-transfer factory for
  values produced on a different accelerator (SubmeshPipe over ICI,
  committed device_put, or a host pull);
* ``load / unload`` — one-brick residency: a *transient* backend
  (``resident = False``) materializes params load -> execute -> release,
  the paper's On-Demand Cascade policy.

Concrete backends and the paper's hardware they stand in for:

=============== ======================= ================================
backend          paper unit              lowering
=============== ======================= ================================
SubmeshBackend   pod-scale "NPU"/"GPU"   NamedSharding onto the accel's
                 submesh slices          submesh + SubmeshPipe edges
DeviceBackend    single GPU/TPU          committed default-device
                                         placement, device_put edges
HostBackend      NPU/DSP emulated on     host-side numpy params,
                 a pinned CPU thread     load->execute->release,
                                         reference kernels (force_ref)
=============== ======================= ================================

``Accelerator.backend`` names a row of this table; ``schedule()`` carries
it into ``Placement.backends``; ``compile_plan`` resolves each brick
through :func:`resolve_backend` — the same graph lowers to any substrate,
and :meth:`repro.core.plan.ExecutionPlan.relower` re-lowers a single
brick (the ``PowerPolicy.knobs`` THROTTLED demotion hook).
"""
from __future__ import annotations

import re
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bricks import Brick
from repro.kernels import dispatch


class BackendError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# shared executable cache — one jit per (brick, cfg, kernel-mode), so every
# compile_plan call (engine, cascade, scheduler, re-lowering) reuses the
# same compiled callables instead of minting a fresh jax.jit per plan
# ---------------------------------------------------------------------------

_JIT_CACHE: Dict[Tuple[Any, Any, str], Callable] = {}
_JIT_CACHE_LOCK = threading.Lock()


def brick_executable(brick: Brick, cfg, mode: str = "auto") -> Callable:
    """The brick's jitted ``(params, ctx) -> out`` callable.

    ``mode`` is the kernel-dispatch mode baked into the trace:
    ``"auto"`` (Pallas on TPU, interpret elsewhere) or ``"ref"`` (the
    reference/interpret path always — every call runs under
    ``dispatch.force_ref()`` so retraces can never escape it).

    Brick and ModelConfig are frozen dataclasses, so two ``decompose(cfg)``
    calls over equal configs produce equal keys and hit the same entry —
    the cache works *across* plans, which is what lets the engine,
    cascade, and scheduler paths share compiled executables."""
    key = (brick, cfg, mode)
    with _JIT_CACHE_LOCK:
        fn = _JIT_CACHE.get(key)
        if fn is not None:
            return fn
        jitted = jax.jit(lambda p, ctx, _b=brick: _b.apply(p, cfg, ctx))
        if mode == "ref":
            def fn(p, ctx, _j=jitted):
                with dispatch.force_ref():
                    return _j(p, ctx)
        else:
            # an "auto" executable must never trace while a reference
            # override is in effect — jit would bake interpret=True into
            # the shared cache entry for every later caller.  Route such
            # calls to the "ref" variant instead (per call, so toggling
            # REPRO_FORCE_REF or a force_ref() scope always takes effect).
            def fn(p, ctx, _j=jitted, _b=brick):
                if dispatch.force_ref_active():
                    return brick_executable(_b, cfg, "ref")(p, ctx)
                return _j(p, ctx)
        _JIT_CACHE[key] = fn
        return fn


def jit_cache_len() -> int:
    """Number of cached brick executables (test hook for cache hits)."""
    return len(_JIT_CACHE)


# ---------------------------------------------------------------------------
# the Backend protocol
# ---------------------------------------------------------------------------

class Backend:
    """The four substrate-specific decisions of plan lowering.

    Subclasses override the hooks; the base class is the protocol
    documentation (and deliberately not instantiable into a plan —
    ``resolve_backend`` only hands out registered concrete backends)."""

    name: str = "base"
    #: params stay bound between executions; False = load->execute->release
    resident: bool = True
    #: kernels/dispatch mode baked into this backend's executables
    kernel_mode: str = "auto"

    def bind_params(self, brick: Brick, params, accel=None):
        """Placement-time binding of the brick's param slice."""
        raise NotImplementedError

    def compile_fn(self, brick: Brick, cfg) -> Callable:
        """The brick's executable, from the shared jit cache."""
        return brick_executable(brick, cfg, self.kernel_mode)

    def make_edge(self, src_accel, dst_accel) -> Optional[Callable]:
        """Inbound transfer for values produced on a different accelerator
        (``src_accel`` may be None: an external input or host producer).
        None = no transfer needed."""
        return None

    def load(self, brick: Brick, bound):
        """Materialize params for one execution (transient backends)."""
        return bound

    def unload(self, dev_params) -> None:
        """Release what :meth:`load` materialized (transient backends)."""


class SubmeshBackend(Backend):
    """Today's pod path, behavior-preserving: brick weights device_put onto
    the accelerator's submesh (replicated NamedSharding) and every
    cross-accelerator edge a sharding-preserving device_put over ICI
    (:class:`repro.core.scheduler.SubmeshPipe`) — never through the host."""

    name = "submesh"

    def bind_params(self, brick, params, accel=None):
        if accel is None or getattr(accel, "mesh", None) is None:
            raise BackendError(
                f"submesh backend needs an accelerator with a mesh to "
                f"lower brick {brick.name!r}")
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(brick.params_of(params),
                              NamedSharding(accel.mesh, P()))

    def make_edge(self, src_accel, dst_accel):
        from jax.sharding import NamedSharding, PartitionSpec as P
        if getattr(dst_accel, "mesh", None) is None:
            raise BackendError("submesh edge needs a destination mesh")
        if src_accel is not None and getattr(src_accel, "mesh", None) \
                is not None:
            from repro.core.scheduler import SubmeshPipe
            return SubmeshPipe(src_accel, dst_accel, P()).transfer
        dst = NamedSharding(dst_accel.mesh, P())
        return lambda v, _s=dst: jax.device_put(v, _s)


class DeviceBackend(Backend):
    """Single-GPU/TPU lowering: brick weights committed to one device
    (default: ``jax.devices()[0]``), inbound edges a committed device_put
    onto that device's stream, no submeshes anywhere."""

    name = "device"

    def __init__(self, device=None):
        self._device = device

    @property
    def device(self):
        return self._device if self._device is not None else jax.devices()[0]

    def bind_params(self, brick, params, accel=None):
        return jax.device_put(brick.params_of(params), self.device)

    def make_edge(self, src_accel, dst_accel):
        return lambda v, _d=self.device: jax.device_put(v, _d)


class HostBackend(Backend):
    """Thread-pinned CPU execution emulating the paper's NPU/DSP bricks.

    * params are bound host-side (numpy) and materialized per execution —
      ``load -> execute -> release`` — which is exactly the On-Demand
      Cascade residency policy (``residency="one-brick"`` lowers every
      brick through this backend);
    * executables are traced under ``dispatch.force_ref()``: host bricks
      always take the reference/interpret kernels, like the paper's units
      that never run the MXU Pallas path;
    * execution is pinned to one dedicated thread per backend instance —
      the emulated compute unit — so host bricks serialize against each
      other the way a real offload target would, whichever engine/worker
      thread drives the plan."""

    name = "host"
    resident = False
    kernel_mode = "ref"

    def __init__(self, pin_thread: bool = True):
        self._pin = pin_thread
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_lock = threading.Lock()
        self._pool_tids: set = set()

    def _executor(self) -> ThreadPoolExecutor:
        with self._pool_lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="host-backend",
                    initializer=lambda: self._pool_tids.add(
                        threading.get_ident()))
            return self._pool

    def bind_params(self, brick, params, accel=None):
        return jax.tree.map(np.asarray, brick.params_of(params))

    def compile_fn(self, brick, cfg):
        fn = brick_executable(brick, cfg, self.kernel_mode)
        if not self._pin:
            return fn

        def pinned(p, ctx, _fn=fn):
            if threading.get_ident() in self._pool_tids:
                return _fn(p, ctx)          # already on the pinned thread
            return self._executor().submit(_fn, p, ctx).result()

        return pinned

    def make_edge(self, src_accel, dst_accel):
        # jax.devices("cpu") is the right probe: the CPU platform is
        # registered even when the default backend is TPU/GPU, while
        # local_devices() only lists the default backend's devices
        try:
            cpu = jax.devices("cpu")[0]
        except RuntimeError:
            cpu = None
        if cpu is not None:
            return lambda v, _d=cpu: jax.device_put(v, _d)
        return lambda v: jnp.asarray(np.asarray(v))

    def load(self, brick, bound):
        return jax.tree.map(jnp.asarray, bound)

    def unload(self, dev_params):
        for leaf in jax.tree.leaves(dev_params):
            if hasattr(leaf, "delete"):
                try:
                    leaf.delete()
                except Exception:
                    pass


# ---------------------------------------------------------------------------
# registry — the backend table compile_plan consults
# ---------------------------------------------------------------------------

BACKENDS: Dict[str, Backend] = {
    "submesh": SubmeshBackend(),
    "device": DeviceBackend(),
    "host": HostBackend(),
}


def register_backend(backend: Backend) -> Backend:
    """Add a custom substrate to the lowering table."""
    BACKENDS[backend.name] = backend
    return backend


# per-ordinal DeviceBackends ("device:N" specs) — cached so two plans
# naming the same ordinal share one backend instance, and thus one edge
# identity in compile_plan's edge cache
_DEVICE_BACKENDS: Dict[int, DeviceBackend] = {}
_DEVICE_BACKENDS_LOCK = threading.Lock()


def device_backend(ordinal: int) -> DeviceBackend:
    """The committed DeviceBackend for one device ordinal.

    ``resolve_backend("device:N")`` lands here: each accelerator gets
    its OWN device/stream — weights committed to ``jax.devices()[N]``,
    inbound edges device_put onto it — so a multi-GPU (or
    ``--xla_force_host_platform_device_count``) box is the degenerate
    single-host two-fleet case: prefill fleet on ``device:0``, decode
    fleet on ``device:1`` (``core/scheduler.fleet_accelerators``)."""
    with _DEVICE_BACKENDS_LOCK:
        be = _DEVICE_BACKENDS.get(ordinal)
        if be is None:
            devs = jax.devices()
            if not 0 <= ordinal < len(devs):
                raise BackendError(
                    f"device ordinal {ordinal} out of range "
                    f"({len(devs)} visible device(s))")
            be = DeviceBackend(devs[ordinal])
            be.name = f"device:{ordinal}"
            _DEVICE_BACKENDS[ordinal] = be
        return be


# ---------------------------------------------------------------------------
# substrate table — ONE source of truth tying each energy profile (the
# scheduler's cost-model unit) to the backend it lowers through and the
# relative matmul efficiency per quant label.  Before this table the
# scheduler's _BIT_EFFICIENCY and the backend kernel modes agreed only by
# convention; now ``core/scheduler.brick_cost`` (via
# ``Accelerator.throughput_scale`` -> :func:`bit_efficiency`) and backend
# resolution (:func:`substrate_backend`, consulted by ``resolve_backend``
# and ``Accelerator.backend_name``) read the same rows — a unit priced as
# reference-kernel-slow at fp cannot silently lower through the Pallas
# path, and vice versa.
# ---------------------------------------------------------------------------

_SPARSE_RE = re.compile(r"^(?P<base>.+?)-sp(?P<pct>\d{1,2})$")
_GROUP_RE = re.compile(r"^(?P<base>.+?)-g\d+$")


@dataclass(frozen=True)
class Substrate:
    """One compute-unit row: lowering backend + per-quant-label relative
    matmul throughput (fraction of the unit's peak at its preferred
    width).  ``kernel_mode`` is derived from the backend row, never
    stated twice.

    ``sparse_gain`` is the fraction of activation-aware-pruned MACs the
    unit actually skips (EdgeMM-style structured sparsity): a composite
    label like ``q4f16-g32-sp50`` prices as the base row sped up by
    ``1 / (1 - sparsity * sparse_gain)``.  Units whose kernels cannot
    skip zeros (reference host path) keep gain 0 — pruning buys them
    nothing, and ``schedule()`` can therefore flip a sparse brick to a
    sparsity-capable unit even when the dense costs tie."""

    backend: str                            # BACKENDS registry name
    bit_efficiency: Tuple[Tuple[str, float], ...]
    sparse_gain: float = 0.0

    @property
    def kernel_mode(self) -> str:
        return BACKENDS[self.backend].kernel_mode

    def efficiency(self, quant_label: str, default: float = 1.0) -> float:
        table = dict(self.bit_efficiency)
        if quant_label in table:
            return table[quant_label]
        sparsity = 0.0
        m = _SPARSE_RE.match(quant_label)
        if m:
            sparsity = int(m.group("pct")) / 100.0
            quant_label = m.group("base")
        g = _GROUP_RE.match(quant_label)     # "q4f16-g32" -> "q4f16" row
        if g:
            quant_label = g.group("base")
        base = table.get(quant_label, default)
        if sparsity <= 0.0:
            return base
        return base / max(1.0 - sparsity * self.sparse_gain, 1e-6)


SUBSTRATES: Dict[str, Substrate] = {
    # NPU fp16 at 0.6: the RKNN static-graph driver keeps fp16 encoders
    # "substantially faster on the NPU" (paper §NPU) even though its
    # native width is int8 — the paper's Sec. 4 observation that NPUs
    # consistently win encoder inference must emerge from the cost model.
    # The npu/cpu rows lower through the host backend (reference kernels
    # on a pinned thread — hence the fp penalty); the gpu row through the
    # committed device backend; the pod profile through submeshes.
    # sparse_gain: the NPU's structured-sparse MAC arrays skip most
    # pruned products; the GPU recovers about half; the reference host
    # kernels and the MXU (dense systolic array) skip none.
    "rk-npu": Substrate("host", (("q8f16", 1.0), ("q4f16", 1.0),
                                 ("q2f16", 1.0), ("fp16", 0.6),
                                 ("bf16", 0.6)), sparse_gain=0.9),
    "rk-gpu": Substrate("device", (("q8f16", 0.9), ("q4f16", 0.9),
                                   ("q2f16", 0.9), ("fp16", 1.0),
                                   ("bf16", 1.0)), sparse_gain=0.5),
    "rk-cpu": Substrate("host", (("q8f16", 0.8), ("q4f16", 0.6),
                                 ("q2f16", 0.5), ("fp16", 0.3),
                                 ("bf16", 0.3))),
    "tpu-v5e": Substrate("submesh", (("q8f16", 1.0), ("q4f16", 1.0),
                                     ("q2f16", 1.0), ("fp16", 1.0),
                                     ("bf16", 1.0))),
}


def bit_efficiency(profile_name: str, quant_label: str,
                   default: float = 1.0) -> float:
    """The cost model's throughput scale for one unit at one quant width,
    from the shared substrate table (1.0 for unknown units/labels)."""
    sub = SUBSTRATES.get(profile_name)
    return default if sub is None else sub.efficiency(quant_label, default)


def substrate_backend(profile_name: str) -> Optional[str]:
    """The backend registry name a unit's profile lowers through, or None
    for profiles the table does not know."""
    sub = SUBSTRATES.get(profile_name)
    return None if sub is None else sub.backend


def resolve_backend(spec: Union[str, Backend, None],
                    accel=None) -> Backend:
    """Resolve a backend spec to a concrete Backend.

    Priority: explicit ``spec`` (Backend instance, registry name, or a
    ``"device:N"`` ordinal — the per-device committed backend of
    :func:`device_backend`) > the accelerator's ``backend`` profile
    field > the shared :data:`SUBSTRATES` row of the accelerator's
    energy profile (the same row the scheduler's cost model prices
    with) > inferred from the accelerator (mesh -> submesh, mesh-less ->
    host: the paper's edge units are emulated host-side) > ``device``
    (default-device placement when nothing was specified)."""
    if isinstance(spec, Backend):
        return spec
    if spec is not None:
        if isinstance(spec, str) and spec.startswith("device:"):
            tail = spec.split(":", 1)[1]
            if not tail.isdigit():
                raise BackendError(
                    f"bad device ordinal in backend spec {spec!r} "
                    f"(want 'device:<int>')")
            return device_backend(int(tail))
        try:
            return BACKENDS[spec]
        except KeyError:
            raise BackendError(
                f"unknown backend {spec!r}; registered: "
                f"{sorted(BACKENDS)}") from None
    if accel is not None:
        name = getattr(accel, "backend", None)
        if name:
            return resolve_backend(name)
        profile = getattr(accel, "profile", None)
        sub = substrate_backend(getattr(profile, "name", ""))
        mesh = getattr(accel, "mesh", None)
        # the table row binds unless it is physically impossible (a
        # submesh lowering needs a mesh to exist on this accelerator)
        if sub is not None and not (sub == "submesh" and mesh is None):
            return BACKENDS[sub]
        if mesh is not None:
            return BACKENDS["submesh"]
        return BACKENDS["host"]
    return BACKENDS["device"]
