"""Pure-jnp oracle: dense causal GQA attention (fp32 softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal: bool = True):
    """q (B,S,H,hd); k,v (B,S,KV,hd); H = KV*G.  Dense softmax oracle."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bikgh,bjkh->bkgij", qg, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgij,bjkh->bikgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
