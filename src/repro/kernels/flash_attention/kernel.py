"""Causal flash-attention Pallas TPU kernel (online softmax, GQA-aware).

The dense archs' train/prefill hot path.  Grid (B*H, Sq/bq, Sk/bk) with the
KV axis innermost-sequential; running max/denominator/accumulator live in
VMEM scratch.  GQA is handled in the index map: query-head row bh reads KV
row  (bh // H)*KV + (bh % H) // G  — no materialized K/V repeat (the repeat
is free in addressing, exactly what the MXU wants).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nk: int, bq: int, bk: int, scale: float, causal: bool):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                          # (bq, hd)
    k = k_ref[0]                                          # (bk, hd)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev, l_prev, acc = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                        # (bq, 1)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc * corr + jnp.dot(p.astype(v.dtype), v,
                                   preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, kv_heads: int, causal: bool = True,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = False):
    """q (BH, Sq, hd); k,v (BKV, Sk, hd) with BH = B*H, BKV = B*KV."""
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    B = BKV // kv_heads
    H = BH // B
    G = H // kv_heads
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    scale = hd ** -0.5

    def kv_row(bh):
        return (bh // H) * kv_heads + (bh % H) // G

    try:
        cp = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:
        cp = None

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk, scale=scale,
                          causal=causal),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=cp,
        interpret=interpret,
    )(q, k, v)
