"""Public wrapper for the flash-attention kernel (model GQA layout)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def _flash_attention(q, k, v, *, causal: bool, bq: int, bk: int,
                     interpret: bool):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    fold = lambda t, n: t.transpose(0, 2, 1, 3).reshape(B * n, t.shape[1], hd)
    out = flash_attention_pallas(
        fold(q, H), fold(k, KV), fold(v, KV), kv_heads=KV, causal=causal,
        bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: Optional[bool] = None):
    """q (B,S,H,hd); k,v (B,S,KV,hd) -> (B,S,H,hd).

    ``interpret`` resolves through kernels/dispatch (TPU check +
    REPRO_FORCE_REF / force_ref overrides) before entering jit, so the
    trace cache can never freeze a stale dispatch decision."""
    return _flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                            interpret=resolve_interpret(interpret))
