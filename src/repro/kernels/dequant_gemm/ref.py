"""Pure-jnp oracle for the fused dequant-GEMM."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import QTensor, dequantize

ACTS = {None: lambda x: x,
        "relu": jax.nn.relu,
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "squared_relu": lambda x: jnp.square(jax.nn.relu(x))}


def ref_dequant_gemm(x: jnp.ndarray, qt: QTensor,
                     bias: Optional[jnp.ndarray] = None,
                     act: Optional[str] = None) -> jnp.ndarray:
    """x (..., K) @ dequant(qt (N, K)).T -> (..., N), fp32 accumulation,
    optional fused bias + activation (the kernel epilogue)."""
    w = dequantize(qt)                                     # (N, K) in qt.dtype
    out = jnp.einsum("...k,nk->...n", x, w,
                     preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    out = ACTS[act](out)
    return out.astype(x.dtype)
