from repro.kernels.dequant_gemm.ops import dequant_gemm, quant_einsum
from repro.kernels.dequant_gemm.ref import ref_dequant_gemm
