"""Fused W{2,4,8}A16 dequant-GEMM Pallas TPU kernel.

The paper's OpenCL kernel "unpacks and rescales int4 weights in-register
within the GEMM loop, followed immediately by FP16 FMAs ... eliminates
intermediate buffers and memory passes" (§3.2 GPU).  TPU adaptation:

* weights live in HBM as int32 words (32/bits codes each) + per-group
  scales — the *storage* format is the paper's; the compute unit is the MXU,
  so "FP16 FMAs" become bf16 MXU matmuls with fp32 accumulators;
* each grid step stages one (bn x bk) packed tile into VMEM, unpacks with
  vector shifts/masks, rescales from a VMEM-resident scale tile (the analogue
  of the paper's LDS scale tables), and feeds the MXU directly — the
  unpacked weight tile never round-trips to HBM;
* the epilogue (bias + activation) is fused into the last K step, exactly
  like the paper's "epilogue that can fuse bias and activation".

Grid: (M/bm, N/bn, K/bk), K innermost (sequential accumulation into a VMEM
scratch accumulator).  Tiles are MXU-aligned (multiples of 128 on M/N).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dequant_gemm.ref import ACTS


def _unpack_tile(codes, bits: int):
    """(bn, bkw) int32 words -> (bn, bkw*per_word) signed int32 codes."""
    pw = 32 // bits
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, pw), 2) * bits
    field = jax.lax.shift_right_logical(codes[:, :, None], shifts)
    field = jax.lax.bitwise_and(field, (1 << bits) - 1)
    sign = 1 << (bits - 1)
    q = jnp.where(field >= sign, field - (1 << bits), field)
    bn, bkw, _ = q.shape
    return q.reshape(bn, bkw * pw)


def _expand_scales(scales, group_size: int):
    """(bn, bk//G) -> (bn, bk) by broadcast (no gather)."""
    bn, ng = scales.shape
    s = jnp.broadcast_to(scales[:, :, None], (bn, ng, group_size))
    return s.reshape(bn, ng * group_size)


def _body(x_ref, codes_ref, scales_ref, bias_ref, out_ref, acc_ref, *,
          bits: int, group_size: int, nk: int, act: Optional[str]):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = _unpack_tile(codes_ref[...], bits)                  # (bn, bk) int32
    s = _expand_scales(scales_ref[...].astype(jnp.float32), group_size)
    w = (q.astype(jnp.float32) * s).astype(x_ref.dtype)     # in-register tile
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # MXU, fp32 acc

    @pl.when(k == nk - 1)
    def _epilogue():
        r = acc_ref[...]
        if bias_ref is not None:
            r = r + bias_ref[...].astype(jnp.float32)
        out_ref[...] = ACTS[act](r).astype(out_ref.dtype)


def _kernel_bias(x_ref, codes_ref, scales_ref, bias_ref, out_ref, acc_ref,
                 **kw):
    _body(x_ref, codes_ref, scales_ref, bias_ref, out_ref, acc_ref, **kw)


def _kernel_nobias(x_ref, codes_ref, scales_ref, out_ref, acc_ref, **kw):
    _body(x_ref, codes_ref, scales_ref, None, out_ref, acc_ref, **kw)


def dequant_gemm_pallas(x, codes, scales, bias=None, *, bits: int,
                        group_size: int, act: Optional[str] = None,
                        bm: int = 128, bn: int = 128, bk: int = 512,
                        interpret: bool = False):
    """x (M, K) @ W(N, K).T with W packed as codes (N, K*bits/32) int32 and
    scales (N, K//group_size).  Returns (M, N) in x.dtype."""
    M, K = x.shape
    N = scales.shape[0]
    pw = 32 // bits
    assert K % bk == 0 and bk % group_size == 0 and bk % pw == 0
    assert M % bm == 0 and N % bn == 0, (M, bm, N, bn)
    nk = K // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),        # x tile
        pl.BlockSpec((bn, bk // pw), lambda i, j, k: (j, k)),  # packed words
        pl.BlockSpec((bn, bk // group_size), lambda i, j, k: (j, k)),
    ]
    args = [x, codes, scales]
    kern = _kernel_nobias
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(bias.reshape(1, N))
        kern = _kernel_bias

    try:
        cp = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:
        cp = None

    return pl.pallas_call(
        functools.partial(kern, bits=bits, group_size=group_size, nk=nk,
                          act=act),
        grid=(M // bm, N // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=cp,
        interpret=interpret,
    )(*args)
