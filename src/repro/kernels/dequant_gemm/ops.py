"""Public wrapper: QTensor-aware fused dequant-GEMM.

``dequant_gemm(x, qt)`` dispatches to the Pallas kernel (interpret mode when
not on TPU, resolved through kernels/dispatch), padding M/N to tile
multiples.  ``quant_einsum`` is the drop-in used by model code when a
weight leaf has been quantized by the per-brick policy: dense einsums fall
through to jnp, QTensor weights hit the kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QTensor, dequantize
from repro.kernels.dequant_gemm import kernel as K
from repro.kernels.dequant_gemm.ref import ref_dequant_gemm
from repro.kernels.dispatch import resolve_interpret


def _pad_to(x, axis: int, m: int):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("act", "use_kernel",
                                             "interpret", "bm", "bn", "bk"))
def _dequant_gemm(x: jnp.ndarray, qt: QTensor,
                  bias: Optional[jnp.ndarray], act: Optional[str], *,
                  use_kernel: bool, interpret: bool,
                  bm: int, bn: int, bk: int) -> jnp.ndarray:
    N, Klog = qt.shape
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    M = xm.shape[0]
    if not use_kernel:
        return ref_dequant_gemm(xm, qt, bias, act).reshape(*lead, N)
    bm_eff = min(bm, max(8, 1 << (M - 1).bit_length()))
    xm, pm = _pad_to(xm, 0, bm_eff)
    codes, _ = _pad_to(qt.codes, 0, bn)
    scales, pn = _pad_to(qt.scales, 0, bn)
    # K padding (the odd-K path): the packed words already cover the logical
    # K rounded up to the group unit; pad further to a bk multiple so ANY K
    # stays on the kernel.  Zero x columns against zero codes contribute an
    # exact 0.0 to the fp32 accumulator, so the result is unchanged.
    pw, gs = qt.spec.per_word, qt.spec.group_size
    unit = max(gs, pw)
    kp = codes.shape[-1] * pw
    bk_eff = min(bk, kp) if bk % unit == 0 else kp
    kfull = -(-kp // bk_eff) * bk_eff
    xm, _ = _pad_to(xm, 1, kfull)          # logical K -> kfull
    codes, _ = _pad_to(codes, 1, kfull // pw)
    scales, _ = _pad_to(scales, 1, kfull // gs)
    b = None
    if bias is not None:
        b, _ = _pad_to(bias, 0, bn)
    out = K.dequant_gemm_pallas(xm, codes, scales, b, bits=qt.spec.bits,
                                group_size=qt.spec.group_size, act=act,
                                bm=bm_eff, bn=bn, bk=bk_eff,
                                interpret=interpret)
    out = out[:M, :N]
    return out.reshape(*lead, N)


def resolve_use_kernel(qt: QTensor, use_kernel: Optional[bool]) -> bool:
    """The dispatch decision, exported so benchmarks can report which path
    actually ran.  Since the odd-K padding landed, every QTensor shape is
    kernel-eligible — only an explicit ``use_kernel=False`` takes the
    (XLA-fused) reference."""
    del qt
    return True if use_kernel is None else bool(use_kernel)


def dequant_gemm(x: jnp.ndarray, qt: QTensor,
                 bias: Optional[jnp.ndarray] = None,
                 act: Optional[str] = None, *,
                 use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 bm: int = 128, bn: int = 128, bk: int = 512) -> jnp.ndarray:
    """x (..., K) @ dequant(qt (N, K)).T -> (..., N).

    ``interpret`` resolves through kernels/dispatch before entering jit."""
    return _dequant_gemm(x, qt, bias, act,
                         use_kernel=resolve_use_kernel(qt, use_kernel),
                         interpret=resolve_interpret(interpret),
                         bm=bm, bn=bn, bk=bk)


def quant_einsum(spec: str, x: jnp.ndarray, w, **kw) -> jnp.ndarray:
    """Einsum that understands QTensor weights.

    Supported quantized contractions are the model hot paths
    ('...k,nk->...n' layouts after canonicalization); everything else (and
    all dense weights) falls through to jnp.einsum."""
    if not isinstance(w, QTensor):
        return jnp.einsum(spec, x, w)
    return dequant_gemm(x, w, **kw)
