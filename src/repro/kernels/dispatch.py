"""One place for the Pallas-vs-reference kernel dispatch decision.

Every kernel wrapper under ``repro/kernels/*/ops.py`` used to carry its own
``jax.default_backend() == "tpu"`` check.  They now all resolve their
``interpret=`` default through :func:`resolve_interpret`, which honors two
overrides on top of the hardware check:

* ``REPRO_FORCE_REF=1`` (env var) — force the reference/interpret path
  everywhere, e.g. to bisect a kernel numerics issue on real TPU hardware.
* :func:`force_ref` (context manager, thread-local) — scoped override used
  by the backend lowering layer: ``HostBackend.compile_fn`` traces its
  brick executables under ``force_ref()`` so host-lowered bricks always
  take the reference kernels, even when the process owns a TPU (the host
  backend emulates the paper's NPU/DSP units, which never run the MXU
  Pallas kernels).

The resolution must happen *outside* the kernels' inner ``jax.jit`` (in
the plain-Python wrapper), otherwise jit's trace cache would freeze the
first resolution and later overrides would be silently ignored.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from typing import Optional

import jax

_local = threading.local()


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def force_ref_active() -> bool:
    """True when either the env var or a ``force_ref()`` scope demands the
    reference/interpret path regardless of hardware."""
    if getattr(_local, "force_ref", 0) > 0:
        return True
    return os.environ.get("REPRO_FORCE_REF", "") not in ("", "0")


@contextmanager
def force_ref():
    """Scoped (thread-local, re-entrant) reference-kernel override."""
    _local.force_ref = getattr(_local, "force_ref", 0) + 1
    try:
        yield
    finally:
        _local.force_ref -= 1


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a kernel wrapper's ``interpret=`` argument.

    An explicit caller choice wins; otherwise compiled Pallas only on real
    TPU hardware with no reference override in effect."""
    if interpret is not None:
        return bool(interpret)
    return force_ref_active() or not on_tpu()
