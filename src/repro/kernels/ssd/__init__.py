from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ref_ssd
