"""Mamba-2 SSD (state-space dual) chunked-scan Pallas TPU kernel.

The SSD dual form is exactly the structure the paper's streaming linear
attention uses (intra-chunk quadratic + inter-chunk state passing), with a
data-dependent decay: the MXU sees three dense matmuls per chunk
(C.B^T, w.x, C.h) while the (P x N) state is carried in VMEM scratch across
the sequential chunk axis.

Grid: (B, H, S/C).  Per-head blocks keep the working set tiny:
x (C,P), B/C (C,N), dt (C,), state (P,N) — ~200 KB of VMEM at the
assigned-arch sizes (C=256, P=64..128, N=128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
            nc: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[0, 0].astype(jnp.float32)                  # (C, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                 # (C,)
    Bb = b_ref[0, 0].astype(jnp.float32)                  # (C, N)
    Cb = c_ref[0, 0].astype(jnp.float32)                  # (C, N)
    A = a_ref[0, 0]                                       # scalar

    la = dt * A                                           # (C,) log-decay
    cum = jnp.cumsum(la)                                  # (C,)
    C_len = cum.shape[0]

    # intra-chunk: w[i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, j <= i
    # (mask inside the exp: the j > i arguments are large-positive and
    # would overflow — same hazard as the jnp oracle's VJP)
    ii = jax.lax.broadcasted_iota(jnp.int32, (C_len, C_len), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C_len, C_len), 1)
    dec = jnp.exp(jnp.where(ii >= jj, cum[:, None] - cum[None, :], -1e30))
    cb = jnp.dot(Cb, Bb.T, preferred_element_type=jnp.float32)
    w = cb * dec * dt[None, :]
    y_intra = jnp.dot(w, xb, preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(cum_i) * C_i . h_prev      (h: (P, N))
    h = h_ref[...]
    y_inter = jnp.exp(cum)[:, None] * jnp.dot(
        Cb, h.T, preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h <- exp(cum[-1]) h + x^T (B * dt * exp(cum[-1]-cum))
    decay_end = jnp.exp(cum[C_len - 1] - cum)             # (C,)
    bw = Bb * (decay_end * dt)[:, None]                   # (C, N)
    h_ref[...] = (jnp.exp(cum[C_len - 1]) * h
                  + jnp.dot(xb.T, bw, preferred_element_type=jnp.float32))

    @pl.when(c == nc - 1)
    def _emit():
        hout_ref[0, 0] = h_ref[...]


def ssd_pallas(x, dt, A, Bm, Cm, *, chunk: int = 256,
               interpret: bool = False):
    """x (B,H,S,P); dt (B,H,S); A (H,); Bm/Cm (B,G,S,N) with H % G == 0.

    Returns (y (B,H,S,P), h_final (B,H,P,N))."""
    B, H, S, P = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    try:
        cp = pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"))
    except Exception:
        cp = None

    return pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c, _rep=rep: (b, h // _rep, c, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c, _rep=rep: (b, h // _rep, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=cp,
        interpret=interpret,
    )(x, dt, A.reshape(H, 1), Bm, Cm)
