"""Pure-jnp oracle for the SSD kernel: the sequential Mamba-2 recurrence."""
from __future__ import annotations

from repro.models.mamba2 import ssd_reference as ref_ssd  # noqa: F401
