"""Public wrapper for the SSD kernel (model layout <-> kernel layout)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256,
        interpret: Optional[bool] = None
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Model layout: x (B,S,H,P), dt (B,S,H) post-softplus, A (H,) negative,
    Bm/Cm (B,S,G,N).  Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    from repro.kernels.ssd.kernel import ssd_pallas
    if interpret is None:
        interpret = not _on_tpu()
    y, h = ssd_pallas(
        x.transpose(0, 2, 1, 3),
        dt.transpose(0, 2, 1).astype(jnp.float32),
        A.astype(jnp.float32),
        Bm.transpose(0, 2, 1, 3),
        Cm.transpose(0, 2, 1, 3),
        chunk=chunk, interpret=interpret)
    return y.transpose(0, 2, 1, 3), h
