"""Public wrapper for the SSD kernel (model layout <-> kernel layout)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd(x, dt, A, Bm, Cm, *, chunk: int, interpret: bool):
    from repro.kernels.ssd.kernel import ssd_pallas
    y, h = ssd_pallas(
        x.transpose(0, 2, 1, 3),
        dt.transpose(0, 2, 1).astype(jnp.float32),
        A.astype(jnp.float32),
        Bm.transpose(0, 2, 1, 3),
        Cm.transpose(0, 2, 1, 3),
        chunk=chunk, interpret=interpret)
    return y.transpose(0, 2, 1, 3), h


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256,
        interpret: Optional[bool] = None
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Model layout: x (B,S,H,P), dt (B,S,H) post-softplus, A (H,) negative,
    Bm/Cm (B,S,G,N).  Returns (y (B,S,H,P), h_final (B,H,P,N)).

    ``interpret`` resolves through kernels/dispatch before entering jit."""
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk,
                interpret=resolve_interpret(interpret))
