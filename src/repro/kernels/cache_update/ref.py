"""Oracle: scatter one new token row into a (B, S, KV, hd) cache."""
from __future__ import annotations

import jax.numpy as jnp


def ref_cache_row_update(cache, row, index):
    """cache (B,S,KV,hd); row (B,KV,hd); index (B,) int32."""
    b = jnp.arange(cache.shape[0])
    return cache.at[b, index].set(row.astype(cache.dtype))
