"""Zero-copy KV-cache row update — TABM's donation discipline applied to
the decode state (paper §3.2: "the NPU encoder writes embeddings directly
into a buffer slot ... avoiding copies").

GSPMD lowers a one-token dynamic-update into a select over the full local
cache shard (a ~34 MB read+write per layer per step at the 32k serving
cell).  This kernel aliases the cache in place and touches ONLY the row:

* grid (B,): one program per sequence slot;
* input_output_aliasing pins the cache buffer (donation — no copy);
* the row lands via a VMEM block whose index_map reads the per-slot
  write position from scalar prefetch — HBM traffic is the row itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, row_ref, cache_in_ref, cache_out_ref):
    # the out block is the (1, 1, KV, hd) row selected by the index_map;
    # the write covers the whole block — nothing else in the shard moves.
    del cache_in_ref
    cache_out_ref[...] = row_ref[...][:, None].astype(cache_out_ref.dtype)


def cache_row_update_pallas(cache, row, index, *, interpret: bool = False):
    """cache (B,S,KV,hd) donated; row (B,KV,hd); index (B,) int32."""
    B, S, KV, hd = cache.shape

    row_block = pl.BlockSpec((1, 1, KV, hd), lambda b, idx: (b, idx[b], 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, KV, hd), lambda b, idx: (b, 0, 0)),   # row
            row_block,                                             # cache-in
        ],
        out_specs=row_block,
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        input_output_aliases={2: 0},       # cache (after the prefetch
                                           # scalar and the row) aliases out
        interpret=interpret,
    )(index, row, cache)
