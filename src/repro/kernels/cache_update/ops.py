"""Public wrapper for the zero-copy cache row update."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def cache_row_update(cache, row, index, *,
                     interpret: Optional[bool] = None):
    """cache (B,S,KV,hd) <- row (B,KV,hd) at per-slot positions (B,)."""
    from repro.kernels.cache_update.kernel import cache_row_update_pallas
    if interpret is None:
        interpret = not _on_tpu()
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (cache.shape[0],))
    return cache_row_update_pallas(cache, row, idx, interpret=interpret)
