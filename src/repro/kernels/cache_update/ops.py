"""Public wrapper for the zero-copy cache row update."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def _cache_row_update(cache, row, index, *, interpret: bool):
    from repro.kernels.cache_update.kernel import cache_row_update_pallas
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (cache.shape[0],))
    return cache_row_update_pallas(cache, row, idx, interpret=interpret)


def cache_row_update(cache, row, index, *,
                     interpret: Optional[bool] = None):
    """cache (B,S,KV,hd) <- row (B,KV,hd) at per-slot positions (B,).

    ``interpret`` resolves through kernels/dispatch before entering jit."""
    return _cache_row_update(cache, row, index,
                             interpret=resolve_interpret(interpret))
