from repro.kernels.cache_update.ops import cache_row_update
from repro.kernels.cache_update.ref import ref_cache_row_update
