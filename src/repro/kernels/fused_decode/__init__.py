from repro.kernels.fused_decode.ops import (cohort_step, fused_mlp,
                                            fused_qkv, fused_supported,
                                            kv_scatter)
from repro.kernels.fused_decode.ref import (ref_cohort_step, ref_fused_mlp,
                                            ref_fused_qkv, ref_kv_scatter)
