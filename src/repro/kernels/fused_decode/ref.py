"""Pure-jnp oracles for the fused cohort-decode kernels.

Each oracle IS the composed path the kernel replaces — the dequantize ->
einsum chains of models/attention and models/mlp, and the engine's
``.at[...].set(mode="drop")`` paged scatter — so "fused == ref" means the
fused step is bit-identical to what ``ServingEngine._cohort_fn`` computes
today with three separate dispatches (gather -> lm_decode_step -> scatter).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import QTensor, dequantize
from repro.models import model as M


def _dq(w):
    return dequantize(w) if isinstance(w, QTensor) else w


def ref_fused_qkv(h, wq, wk, wv,
                  bq: Optional[jnp.ndarray] = None,
                  bk: Optional[jnp.ndarray] = None,
                  bv: Optional[jnp.ndarray] = None):
    """The composed projection: dequantize (XLA-fused) then qkv_proj."""
    from repro.models import attention as attn
    p = {"wq": _dq(wq), "wk": _dq(wk), "wv": _dq(wv)}
    if bq is not None:
        p.update(bq=bq, bk=bk, bv=bv)
    return attn.qkv_proj(p, h)


def ref_fused_mlp(h, w_up, w_down, w_gate=None, *, act: str):
    """The composed FFN: dequantize then models/mlp.apply_mlp."""
    from repro.models import mlp as mlp_mod

    class _Cfg:
        pass

    cfg = _Cfg()
    cfg.act = act
    p = {"w_up": _dq(w_up), "w_down": _dq(w_down)}
    if w_gate is not None:
        p["w_gate"] = _dq(w_gate)
    return mlp_mod.apply_mlp(p, cfg, h)


def ref_kv_scatter(blk, off, k_rows, v_rows, k_pool, v_pool):
    """The engine's paged single-position scatter, all layer groups at
    once: sentinel block ids (== n_blocks) fall out of range and drop."""
    k_pool = k_pool.at[:, blk, off].set(
        k_rows.astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[:, blk, off].set(
        v_rows.astype(v_pool.dtype), mode="drop")
    return k_pool, v_pool


def ref_cohort_step(params, cfg, tokens, lengths, slot_ids, tables, pool, *,
                    block_size: int, paged):
    """Today's three dispatches, verbatim (the body ServingEngine compiled
    before the fused path existed): gather each row's context from the
    paged pool, run ONE ``lm_decode_step`` over the cohort, scatter the
    new K/V position back through the block tables.  This is the oracle
    the fused step must match bit for bit."""
    bc = tokens.shape[0]
    bs = block_size
    W = tables.shape[1]
    layers = []
    for pos, is_paged in enumerate(paged):
        if is_paged:
            layers.append(jax.tree.map(
                lambda l: jnp.take(
                    l, tables, axis=1, mode="fill",
                    fill_value=0).reshape(
                        (l.shape[0], bc, W * bs) + l.shape[3:]),
                pool[pos]))
        else:
            layers.append(jax.tree.map(
                lambda l: jnp.take(l, slot_ids, axis=1,
                                   mode="fill", fill_value=0),
                pool[pos]))
    cache = {"layers": tuple(layers), "index": lengths}
    logits, new = M.lm_decode_step(params, cfg, tokens, cache)
    blk = jnp.take_along_axis(
        tables, (lengths // bs)[:, None], axis=1)[:, 0]
    off = lengths % bs
    out = []
    for pos, is_paged in enumerate(paged):
        if is_paged:
            def scat(l, nl):
                idx = lengths.reshape((1, bc) + (1,) * (nl.ndim - 2))
                row = jnp.take_along_axis(nl, idx, axis=2)
                return l.at[:, blk, off].set(
                    row[:, :, 0].astype(l.dtype), mode="drop")
            out.append(jax.tree.map(scat, pool[pos], new["layers"][pos]))
        else:
            out.append(jax.tree.map(
                lambda l, nl: l.at[:, slot_ids].set(
                    nl.astype(l.dtype), mode="drop"),
                pool[pos], new["layers"][pos]))
    return logits, tuple(out)
