"""Pallas bodies for the fused low-bit cohort-decode step.

Three kernels, one HBM pass each (paper §3.2 "Quantization" + §3.3 decode
path — "avoid separate dequant passes; write the new KV position, not the
window"):

* :func:`fused_qkv_pallas` — in-VMEM weight unpack (q4/q8 codes + scales,
  the fp16 weight never materializes to HBM) feeding the three QKV GEMMs
  of one attention sublayer;
* :func:`fused_mlp_pallas` — the same unpack fused with the gate/up GEMMs,
  activation, and down GEMM;
* :func:`kv_row_scatter_pallas` — the paged single-position K/V scatter:
  grid (bc,), scalar-prefetched (block, offset) per cohort row, the pool
  aliased in place (donation) and ONLY the one new row's block written —
  sentinel rows (``blk == n_blocks``) write nothing at all.

Bit-exactness contract: the GEMM bodies execute the *same* ``jnp.einsum``
strings on the *same* shapes as the composed jnp path (models/attention
``qkv_proj``, models/mlp ``apply_mlp``), and the in-VMEM unpack replicates
``core.quantize.dequantize``'s cast chain exactly (int unpack -> f32 ->
x scales -> slice -> cast), so interpret-mode outputs equal the composed
oracle bit for bit.  The only Mosaic-specific rewrite is the 2D
``broadcasted_iota`` for the shift vector (1D iota does not lower on TPU)
— integer-exact, so numerics are unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import QTensor, QuantSpec
from repro.models.common import activation
from repro.models.mlp import GATED


def _dequant_block(codes, scales, spec: QuantSpec, logical_k: int, dtype):
    """In-VMEM unpack, numerically identical to core.quantize.dequantize."""
    pw, bits = spec.per_word, spec.bits
    words = codes.shape[-1]
    shifts = jax.lax.broadcasted_iota(
        jnp.int32, codes.shape + (pw,), codes.ndim) * bits
    field = jnp.right_shift(codes[..., None], shifts)
    field = jnp.bitwise_and(field, (1 << bits) - 1)
    sign = 1 << (bits - 1)
    q = jnp.where(field >= sign, field - (1 << bits), field)
    kp = words * pw
    q = q.reshape(*codes.shape[:-1], kp).astype(jnp.float32)
    g = spec.group_size
    q = q.reshape(*q.shape[:-1], kp // g, g)
    w = q * scales.astype(jnp.float32)[..., None]
    w = w.reshape(*w.shape[:-2], kp)[..., :logical_k]
    return w.astype(dtype)


def _weight_operands(ws):
    """Flatten dense/QTensor weights into pallas operands + a static plan."""
    operands, plan = [], []
    for w in ws:
        if isinstance(w, QTensor):
            operands += [w.codes, w.scales]
            plan.append(("quant", w.spec, w.shape[-1], w.dtype))
        else:
            operands.append(w)
            plan.append(("dense", None, None, None))
    return operands, tuple(plan)


def _take_weights(it, plan):
    """Rebuild weight arrays from the ref iterator per the static plan."""
    ws = []
    for kind, spec, logical_k, dtype in plan:
        if kind == "quant":
            codes = next(it)[...]
            scales = next(it)[...]
            ws.append(_dequant_block(codes, scales, spec, logical_k, dtype))
        else:
            ws.append(next(it)[...])
    return ws


def _full_specs(arrays):
    """Whole-array VMEM blocks on a trivial grid (decode shapes are small:
    bc <= n_slots rows against one group's weights)."""
    return [pl.BlockSpec(a.shape, lambda i, _r=a.ndim: (0,) * _r)
            for a in arrays]


def fused_qkv_pallas(h, wq, wk, wv,
                     bq: Optional[jnp.ndarray] = None,
                     bk: Optional[jnp.ndarray] = None,
                     bv: Optional[jnp.ndarray] = None, *,
                     interpret: bool = False):
    """h (bc,1,D) x wq/wk/wv (D,H|KV,hd) [dense or packed] -> q,k,v.

    One pallas_call: the packed codes stream HBM->VMEM once, unpack in
    VMEM, and feed all three projections; biases are fused adds."""
    w_ops, plan = _weight_operands((wq, wk, wv))
    biases = [b for b in (bq, bk, bv) if b is not None]
    assert len(biases) in (0, 3)
    operands = [h] + w_ops + biases

    def shp(w):
        return w.shape if not isinstance(w, QTensor) else w.shape
    bc = h.shape[0]
    out_shapes = tuple(
        jax.ShapeDtypeStruct((bc, 1) + shp(w)[-2:], h.dtype)
        for w in (wq, wk, wv))

    def body(*refs):
        n_out = 3
        ins, outs = refs[:-n_out], refs[-n_out:]
        it = iter(ins)
        x = next(it)[...]
        ws = _take_weights(it, plan)
        bs_ = [next(it)[...] for _ in range(len(biases))]
        for i, (w, o_ref) in enumerate(zip(ws, outs)):
            # the composed path's einsum, verbatim (attention.qkv_proj)
            y = jnp.einsum("bsd,dhk->bshk", x, w)
            if bs_:
                y = y + bs_[i]
            o_ref[...] = y.astype(o_ref.dtype)

    return pl.pallas_call(
        body,
        grid=(1,),
        in_specs=_full_specs(operands),
        out_specs=[pl.BlockSpec(s.shape, lambda i, _r=len(s.shape): (0,) * _r)
                   for s in out_shapes],
        out_shape=out_shapes,
        interpret=interpret,
    )(*operands)


def fused_mlp_pallas(h, w_up, w_down, w_gate=None, *,
                     act: str, interpret: bool = False):
    """h (bc,1,D) -> gate/up GEMMs, activation, down GEMM, one kernel.

    Mirrors models/mlp.apply_mlp einsum-for-einsum; packed weights unpack
    in VMEM so the fp16 d_ff x d_model matrices never hit HBM."""
    ws = (w_up, w_down) + ((w_gate,) if w_gate is not None else ())
    w_ops, plan = _weight_operands(ws)
    operands = [h] + w_ops
    out_shape = jax.ShapeDtypeStruct(h.shape, h.dtype)

    def body(*refs):
        ins, out_ref = refs[:-1], refs[-1]
        it = iter(ins)
        x = next(it)[...]
        got = _take_weights(it, plan)
        up_w, down_w = got[0], got[1]
        up = jnp.einsum("bsd,df->bsf", x, up_w)
        if w_gate is not None:
            gate = jnp.einsum("bsd,df->bsf", x, got[2])
            mid = activation(GATED[act])(gate) * up
        else:
            mid = activation(act)(up)
        out_ref[...] = jnp.einsum("bsf,fd->bsd", mid,
                                  down_w).astype(out_ref.dtype)

    return pl.pallas_call(
        body,
        grid=(1,),
        in_specs=_full_specs(operands),
        out_specs=pl.BlockSpec(h.shape, lambda i, _r=h.ndim: (0,) * _r),
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)


def kv_row_scatter_pallas(blk, off, k_rows, v_rows, k_pool, v_pool, *,
                          interpret: bool = False):
    """Scatter each group's new K/V position per cohort row into the pool.

    k_pool/v_pool (L, n_blocks, bs, KV, hd) donated (aliased in place);
    k_rows/v_rows (L, bc, KV, hd); blk/off (bc,) int32 scalar-prefetched.
    One program per (group, row); HBM traffic is the written rows
    themselves.  Sentinel rows (blk == n_blocks, the padded-cohort marker)
    skip the store entirely — the aliased block keeps its pool content,
    the drop semantics of the composed ``.at[...].set(mode="drop")``
    without touching the pool."""
    L, n_blocks, bs, KV, hd = k_pool.shape

    row_spec = pl.BlockSpec((1, 1, KV, hd),
                            lambda g, b, blk, off: (g, b, 0, 0))
    # clamp the index map for sentinel rows — the selected block is never
    # written for them, it only has to be a legal address
    pool_spec = pl.BlockSpec(
        (1, 1, 1, KV, hd),
        lambda g, b, blk, off: (g, jnp.minimum(blk[b], n_blocks - 1),
                                off[b], 0, 0))

    def body(blk_ref, off_ref, krow_ref, vrow_ref, kin_ref, vin_ref,
             kout_ref, vout_ref):
        del off_ref, kin_ref, vin_ref
        b = pl.program_id(1)

        @pl.when(blk_ref[b] < n_blocks)
        def _():
            kout_ref[...] = krow_ref[...][:, :, None].astype(
                kout_ref.dtype)
            vout_ref[...] = vrow_ref[...][:, :, None].astype(
                vout_ref.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(L, k_rows.shape[1]),
        in_specs=[row_spec, row_spec, pool_spec, pool_spec],
        out_specs=[pool_spec, pool_spec],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)),
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(blk, off, k_rows, v_rows, k_pool, v_pool)
