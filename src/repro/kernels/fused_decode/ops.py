"""Public wrappers for the fused low-bit cohort-decode step.

``cohort_step`` is the engine-facing entry: the batched decode inner loop
``ServingEngine._cohort_fn`` compiles per cohort-size bucket.  With
``use_fused=False`` it runs the composed oracle (ref.py — today's three
dispatches: gather, ``lm_decode_step``, scatter).  With ``use_fused=True``
each layer group runs

* :func:`fused_qkv` — one Pallas pass unpacking the packed q4/q8 weights
  in VMEM and computing the three QKV GEMMs (the fp16 weight matrix never
  materializes to HBM);
* the *composed* attention core (``attention.attn_context``) and output
  projection — softmax math is shared code with the oracle, so the paths
  cannot drift;
* :func:`kv_scatter` — the paged single-position K/V write, aliased in
  place, sentinel rows writing nothing (replaces the oracle's whole-pool
  ``.at[...].set`` pass);
* :func:`fused_mlp` — unpack + gate/up/act/down in one pass.

``interpret=`` resolves through kernels/dispatch *outside* the engine's
jit (the engine resolves at ``_cohort_fn`` build time and passes the
resolved flag in), so ``force_ref()`` / ``REPRO_FORCE_REF`` behave like
every other kernel wrapper.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import QTensor, dequantize
from repro.kernels.dispatch import resolve_interpret
from repro.kernels.fused_decode import kernel as K
from repro.kernels.fused_decode.ref import (ref_cohort_step, ref_fused_mlp,
                                            ref_fused_qkv, ref_kv_scatter)


def fused_qkv(h, wq, wk, wv, bq=None, bk=None, bv=None, *,
              use_kernel: Optional[bool] = None,
              interpret: Optional[bool] = None):
    """h (bc,1,D) -> (q, k, v); weights dense arrays or packed QTensors.

    ``interpret`` resolves through kernels/dispatch."""
    if use_kernel is not None and not use_kernel:
        return ref_fused_qkv(h, wq, wk, wv, bq, bk, bv)
    return K.fused_qkv_pallas(h, wq, wk, wv, bq, bk, bv,
                              interpret=resolve_interpret(interpret))


def fused_mlp(h, w_up, w_down, w_gate=None, *, act: str,
              use_kernel: Optional[bool] = None,
              interpret: Optional[bool] = None):
    """h (bc,1,D) -> (bc,1,D); the sublayer FFN in one fused pass.

    ``interpret`` resolves through kernels/dispatch."""
    if use_kernel is not None and not use_kernel:
        return ref_fused_mlp(h, w_up, w_down, w_gate, act=act)
    return K.fused_mlp_pallas(h, w_up, w_down, w_gate, act=act,
                              interpret=resolve_interpret(interpret))


def kv_scatter(blk, off, k_rows, v_rows, k_pool, v_pool, *,
               use_kernel: Optional[bool] = None,
               interpret: Optional[bool] = None):
    """Write each cohort row's new K/V position (all layer groups at once)
    into the paged pool.

    Pools are donated (aliased); sentinel rows write nothing.
    ``interpret`` resolves through kernels/dispatch."""
    if use_kernel is not None and not use_kernel:
        return ref_kv_scatter(blk, off, k_rows, v_rows, k_pool, v_pool)
    return K.kv_row_scatter_pallas(
        jnp.asarray(blk, jnp.int32), jnp.asarray(off, jnp.int32),
        k_rows, v_rows, k_pool, v_pool,
        interpret=resolve_interpret(interpret))


def fused_supported(cfg) -> bool:
    """The fused path covers the uniform dense-attention serving archs
    (every group position paged: softmax attention + dense MLP).  Hybrid
    SSM groups, MoE FFNs, and linear attention keep the composed path."""
    from repro.models import decoder as dec
    if dec.group_size(cfg) != 1 or cfg.family == "ssm":
        return False
    if dec.cfg_attn_impl(cfg) == "linear" or cfg.moe is not None:
        return False
    return cfg.d_ff > 0


def _dq(w):
    return dequantize(w) if isinstance(w, QTensor) else w


def _fused_cohort_step(params, cfg, tokens, lengths, slot_ids, tables,
                       pool, *, block_size: int, interpret: bool):
    """The fused replacement for ref_cohort_step.

    Structure matters for bit-exactness: the composed path runs the layer
    groups through ``lax.scan`` (decoder.stack_decode), and on CPU XLA
    compiles a scan body differently from an unrolled Python loop — the
    bf16 GEMM accumulation order changes and logits drift ~1e-2.  So the
    fused path is the *same* scan: one ``lax.scan`` over the stacked group
    params whose body swaps the dequant->einsum chains for the fused
    Pallas kernels (interpret-mode pallas inside a scan body is bit-equal
    to the jnp ops it replaces — verified property, see
    tests/test_fused_decode.py).  Everything the kernels do not fuse —
    embed, norms, rope, the attention softmax/context, the output
    projection, the LM head — is the same shared code the composed path
    runs, so equality with the oracle reduces to the per-kernel
    contracts.  The new K/V rows come out of the scan stacked and hit the
    pool in ONE aliased scatter kernel (grid (L, bc)) instead of the
    composed path's whole-pool gather-update-rescatter."""
    from repro.distributed.sharding import constrain_residual
    from repro.models import attention as attn
    from repro.models import model as M
    from repro.models.common import apply_norm

    del slot_ids                       # every position is paged (supported
    #                                    archs have no slot-state layers)
    bc = tokens.shape[0]
    bs = block_size
    W = tables.shape[1]
    k_pool, v_pool = pool[0]
    L = k_pool.shape[0]

    index = jnp.asarray(lengths)
    positions = index[:, None].astype(jnp.int32)
    mrope = jnp.stack([positions] * 3) if cfg.rope == "mrope" else None
    rope_fn = M.make_rope_fn(cfg, positions, mrope)

    x = M._embed(params, cfg, tokens)
    # cohort context gather — identical to the composed path (the fused
    # kernels replace the *scatter* side; reads stay one gather)
    gk = jnp.take(k_pool, tables, axis=1, mode="fill", fill_value=0).reshape(
        (L, bc, W * bs) + k_pool.shape[3:])
    gv = jnp.take(v_pool, tables, axis=1, mode="fill", fill_value=0).reshape(
        (L, bc, W * bs) + v_pool.shape[3:])
    blk = jnp.take_along_axis(tables, (lengths // bs)[:, None], axis=1)[:, 0]
    off = lengths % bs

    def body(x, xs):
        gp, (ck, cv) = xs
        sub = gp[0]                    # fused_supported => group_size == 1
        mix = sub["mixer"]
        h = apply_norm(sub["norm1"], x)
        q, k_new, v_new = fused_qkv(
            h, mix["wq"], mix["wk"], mix["wv"],
            mix.get("bq"), mix.get("bk"), mix.get("bv"),
            interpret=interpret)
        q, k_new = rope_fn(q), rope_fn(k_new)
        o = attn.attn_context(q, k_new, v_new, ck, cv, index, cfg)
        y = attn.out_proj({"wo": _dq(mix["wo"])}, o)
        x = x + y
        h2 = apply_norm(sub["norm2"], x)
        y2 = fused_mlp(h2, sub["ffn"]["w_up"], sub["ffn"]["w_down"],
                       sub["ffn"].get("w_gate"), act=cfg.act,
                       interpret=interpret)
        x = x + constrain_residual(y2)
        return x, (k_new[:, 0], v_new[:, 0])

    x, (k_rows, v_rows) = jax.lax.scan(
        body, x, (params["layers"], (gk, gv)))
    k_pool, v_pool = kv_scatter(blk, off, k_rows, v_rows, k_pool, v_pool,
                                interpret=interpret)

    logits = M._head(params, cfg, x)
    return logits[:, 0], ((k_pool, v_pool),)


def cohort_step(params, cfg, tokens, lengths, slot_ids, tables, pool, *,
                block_size: int, paged,
                use_fused: Optional[bool] = None,
                interpret: Optional[bool] = None):
    """One batched cohort decode step against the paged pool.

    tokens (bc,1) int32; lengths/slot_ids (bc,) int32; tables (bc, W);
    pool: tuple of per-position cache trees (donated).  Returns
    (logits (bc, V), new pool).  ``use_fused=None`` resolves to whether
    the arch is fused-supported; ``interpret`` resolves through
    kernels/dispatch."""
    if use_fused is None:
        use_fused = fused_supported(cfg)
    if not use_fused:
        return ref_cohort_step(params, cfg, tokens, lengths, slot_ids,
                               tables, pool, block_size=block_size,
                               paged=paged)
    assert fused_supported(cfg), (
        "use_fused=True needs a uniform dense-attention arch "
        f"(family={cfg.family}, attn_impl={cfg.attn_impl})")
    assert all(paged), "fused cohort step expects every position paged"
    return _fused_cohort_step(params, cfg, tokens, lengths, slot_ids,
                              tables, pool, block_size=block_size,
                              interpret=resolve_interpret(interpret))
