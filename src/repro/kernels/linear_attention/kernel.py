"""Streaming linear-attention Pallas TPU kernel (paper §3.2 "GPU").

NANOMIND replaces quadratic attention with a "kernelized, streaming variant
[that] maintains running summaries of past keys and values".  TPU shape:

* grid (B*H, S/C): the chunk axis is sequential; the (hd x hd) running
  summary S and the hd-vector normalizer z live in VMEM scratch and persist
  across chunk steps (reset at c == 0);
* per chunk the MXU computes the intra-chunk causal part as two dense
  (C x hd)(hd x C) matmuls + one (C x C)(C x hd), and the inter-chunk part
  as a single matmul against the running state — "a single matrix pass",
  never materializing the T x T score matrix;
* the final state/z are emitted so decode can continue the stream with the
  paper's single mat-vec per token (see ops.decode_step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _phi(x):
    return jax.nn.elu(x.astype(jnp.float32)) + 1.0


def _kernel(q_ref, k_ref, v_ref, o_ref, state_out_ref, z_out_ref,
            state_ref, z_ref, *, nc: int, eps: float):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    qf = _phi(q_ref[0])                                   # (C, hd) f32
    kf = _phi(k_ref[0])
    vf = v_ref[0].astype(jnp.float32)
    C = qf.shape[0]

    state, z = state_ref[...], z_ref[...]                 # (hd,hd), (1,hd)
    o_inter = jnp.dot(qf, state, preferred_element_type=jnp.float32)
    z_inter = jnp.dot(qf, z.T, preferred_element_type=jnp.float32)  # (C,1)

    s = jnp.dot(qf, kf.T, preferred_element_type=jnp.float32)       # (C,C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    s = jnp.where(ii >= jj, s, 0.0)                       # causal (incl diag)
    o_intra = jnp.dot(s, vf, preferred_element_type=jnp.float32)
    z_intra = jnp.sum(s, axis=1, keepdims=True)           # (C,1)

    den = jnp.maximum(z_inter + z_intra, eps)
    o_ref[0] = ((o_inter + o_intra) / den).astype(o_ref.dtype)

    state_ref[...] = state + jnp.dot(kf.T, vf,
                                     preferred_element_type=jnp.float32)
    z_ref[...] = z + jnp.sum(kf, axis=0, keepdims=True)

    @pl.when(c == nc - 1)
    def _emit():
        state_out_ref[0] = state_ref[...]
        z_out_ref[0] = z_ref[...]


def linear_attention_pallas(q, k, v, *, chunk: int = 256,
                            eps: float = 1e-6, interpret: bool = False):
    """q,k,v (BH, S, hd) -> (out (BH,S,hd), state (BH,hd,hd), z (BH,1,hd))."""
    BH, S, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    try:
        cp = pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"))
    except Exception:
        cp = None

    return pl.pallas_call(
        functools.partial(_kernel, nc=nc, eps=eps),
        grid=(BH, nc),
        in_specs=[pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0))] * 3,
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, 1, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32),
                        pltpu.VMEM((1, hd), jnp.float32)],
        compiler_params=cp,
        interpret=interpret,
    )(q, k, v)
