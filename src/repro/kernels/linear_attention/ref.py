"""Pure-jnp oracle: causal linear attention with elu+1 feature map.

Sequential per-token recurrence — the literal form of the paper's "running
summaries of past keys and values" (NANOMIND §3.2 GPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def feature_map(x):
    return jax.nn.elu(x.astype(jnp.float32)) + 1.0


def ref_linear_attention(q, k, v):
    """q,k,v (B,S,H,hd) -> (out (B,S,H,hd), state (B,H,hd,hd), z (B,H,hd)).

    o_t = phi(q_t).S_t / phi(q_t).z_t with S_t = sum_{i<=t} phi(k_i) v_i^T."""
    B, S, H, hd = q.shape
    qf, kf = feature_map(q), feature_map(k)
    vf = v.astype(jnp.float32)

    def step(carry, t):
        state, z = carry
        state = state + jnp.einsum("bhk,bhd->bhkd", kf[:, t], vf[:, t])
        z = z + kf[:, t]
        o = jnp.einsum("bhk,bhkd->bhd", qf[:, t], state)
        den = jnp.maximum(jnp.einsum("bhk,bhk->bh", qf[:, t], z), 1e-6)
        return (state, z), o / den[..., None]

    init = (jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32))
    (state, z), outs = jax.lax.scan(step, init, jnp.arange(S))
    out = jnp.moveaxis(outs, 0, 1).astype(q.dtype)
    return out, state, z
