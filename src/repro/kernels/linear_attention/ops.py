"""Public wrapper for the streaming linear-attention kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_attention(q, k, v, *, chunk: int = 256,
                     interpret: Optional[bool] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q,k,v (B,S,H,hd) -> (out, state (B,H,hd,hd), z (B,H,hd)).

    GQA callers expand kv heads before calling."""
    from repro.kernels.linear_attention.kernel import linear_attention_pallas
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out, state, z = linear_attention_pallas(
        fold(q), fold(k), fold(v), chunk=chunk, interpret=interpret)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return out, state.reshape(B, H, hd, hd), z.reshape(B, H, hd)
