"""Public wrapper for the streaming linear-attention kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _linear_attention(q, k, v, *, chunk: int, interpret: bool):
    from repro.kernels.linear_attention.kernel import linear_attention_pallas
    B, S, H, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out, state, z = linear_attention_pallas(
        fold(q), fold(k), fold(v), chunk=chunk, interpret=interpret)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return out, state.reshape(B, H, hd, hd), z.reshape(B, H, hd)


def linear_attention(q, k, v, *, chunk: int = 256,
                     interpret: Optional[bool] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q,k,v (B,S,H,hd) -> (out, state (B,H,hd,hd), z (B,H,hd)).

    GQA callers expand kv heads before calling.  ``interpret`` resolves
    through kernels/dispatch before entering jit."""
    return _linear_attention(q, k, v, chunk=chunk,
                             interpret=resolve_interpret(interpret))
