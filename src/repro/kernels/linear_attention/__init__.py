from repro.kernels.linear_attention.ops import linear_attention
from repro.kernels.linear_attention.ref import ref_linear_attention
