"""Model / shape-cell configuration system.

Every assigned architecture is a :class:`ModelConfig`; every benchmark cell is
a :class:`ShapeCell`.  Configs are plain frozen dataclasses so they can be
hashed into jit static args and printed into EXPERIMENTS.md verbatim.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (routed + shared experts)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0          # width of the shared-expert FFN (total)
    every: int = 1                # MoE FFN on layers where (idx % every)==every-1
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) mixer configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def n_heads(self, d_model: int) -> int:
        return (self.expand * d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture.  All assigned archs instantiate this."""

    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    act: str = "swiglu"           # swiglu | squared_relu | gelu | geglu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    rope: str = "rope"            # rope | mrope | partial | none
    rope_frac: float = 1.0        # fraction of head_dim rotated (partial rope)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    hybrid_group: int = 0         # layers per scan group (jamba: 8); 0 = uniform
    attn_every: int = 0           # within a hybrid group, index of the attn layer
    # --- encoder-decoder (audio) ---
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 8192       # fixed audio-frame length for decode cells
    # --- VLM ---
    vlm: bool = False
    vision_feat_dim: int = 0      # frontend-stub patch-feature width
    vision_tokens: int = 0        # patches per full-resolution image
    # TABM slot classes (core/slot_classes): per-image token counts of each
    # resolution bucket, ascending; () means one bucket = vision_tokens.
    # vision_max_images is the largest image count one request may carry —
    # together they key the class-partitioned TABM pool (image-count bucket
    # x resolution bucket), so a thumbnail request never pads into a
    # multi-image full-resolution slab.
    vision_token_buckets: Tuple[int, ...] = ()
    vision_max_images: int = 1
    # largest same-class staging microbatch this arch's engine may commit
    # as one strided TABM slab (one batched vision-encode+projector call);
    # the effective batch is min(this, Knobs.max_stage_batch, class ring
    # capacity) — battery throttling shrinks it before shedding depth
    max_stage_batch: int = 4
    # --- numerics / sharding ---
    dtype: str = "bfloat16"
    attn_impl: str = "softmax"    # softmax | linear (paper's streaming variant)
    attn_sharding: str = "head"   # head | context (context-parallel attention)
    # attention tiling: q/kv chunk sizes for the online-softmax path.
    # 0 = single fused dot->softmax->dot region — the shape the Pallas
    # flash kernel implements on TPU (kernels/flash_attention); the
    # dry-run's fusion-aware cost model recognizes it as VMEM-resident.
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    vocab_pad_to: int = 512
    remat: bool = True
    # long-context capability (sub-quadratic path exists)
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def n_active_params(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 * max(1, self.hybrid_group)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            vocab_pad_to=64,
            remat=False,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=64, d_ff_shared=64 if self.moe.n_shared else 0)
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=32)
        if self.encdec:
            small["n_enc_layers"] = 2
            small["enc_seq_len"] = 64
        if self.vlm:
            small["vision_feat_dim"] = 48
            small["vision_tokens"] = 8
            # keep two resolution buckets (thumbnail = quarter resolution)
            # so the slot-class machinery is exercised at CPU scale
            small["vision_token_buckets"] = (2, 8)
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeCell:
    """One benchmark cell: (kind, seq_len, global_batch)."""

    name: str
    kind: str                     # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable per the assignment rules."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch; 500k dense decode is the "
                       "T^2 regime the paper replaces with linear attention "
                       "(see DESIGN.md §4)")
    return True, ""
