"""dbrx-132b — fine-grained MoE [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752/expert, 16 experts top-4,
vocab 100352.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    act="swiglu",
    norm="layernorm",
    rope="rope",
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
)
