"""nemotron-4-15b — GQA + squared-ReLU FFN [arXiv:2402.16819].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    head_dim=128,
    act="squared_relu",    # Nemotron uses squared ReLU, non-gated
    norm="layernorm",
    rope="rope",
    rope_frac=0.5,         # Nemotron-4 rotary on 50% of head dim
)
