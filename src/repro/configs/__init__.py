"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

One module per assigned architecture (exact published configs) plus the
paper's own model (llava-onevision-0.5b = SigLip-stub + Qwen2-0.5B).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig, ShapeCell,
                                SHAPES, cell_applicable)

_ARCH_MODULES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "deepseek-67b": "deepseek_67b",
    "nemotron-4-15b": "nemotron_4_15b",
    "stablelm-1.6b": "stablelm_1p6b",
    "stablelm-12b": "stablelm_12b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "llava-onevision-0.5b": "llava_onevision_0p5b",
}


def list_archs():
    return list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "ShapeCell", "SHAPES",
           "cell_applicable", "get_config", "list_archs"]
