"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576, MoE 16e top-2 every other layer,
vocab 65536.  Layout: 9 groups of 8 sublayers; attention at in-group index 4,
Mamba elsewhere; MoE FFN on odd in-group indices.  Sub-quadratic (runs
long_500k: only the 9 attention layers hold a 500k KV cache).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope="none",           # Jamba uses no positional embedding
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128, n_groups=1,
                  chunk_size=256),
    hybrid_group=8,
    attn_every=4,
    subquadratic=True,
)
