"""qwen2-vl-7b — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The vision
frontend (ViT) is a STUB per the assignment: input_specs() provides
precomputed patch features (width 1280, SigLip/Qwen2-ViT hidden size);
the projector + multimodal merge + decoder are real bricks.

28 heads do not divide the 16-way model axis, so attention uses the
context-parallel sharding mode (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope="mrope",
    rope_theta=1000000.0,
    vlm=True,
    vision_feat_dim=1280,
    vision_tokens=1024,    # fixed-resolution preprocessing (paper §NPU)
    # dynamic resolution buckets quantized to the NPU's static shapes:
    # low-res (256 merged patches) vs the full 1024-patch grid, up to 4
    # images per request (video frames bucket the same way)
    vision_token_buckets=(256, 1024),
    vision_max_images=4,
    # 1024-patch slabs at d_model 3584 are memory-heavy: cap the strided
    # staging slab at two requests per commit
    max_stage_batch=2,
    attn_sharding="context",
)
