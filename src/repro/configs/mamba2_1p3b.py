"""mamba2-1.3b — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, attention-free (d_ff=0: pure Mamba-2 blocks), vocab 50280,
ssm_state=128.  Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,            # SSD heads = expand*d_model/head_dim = 4096/64
    n_kv_heads=64,
    d_ff=0,                # attn-free, no MLP (Mamba-2 block only)
    vocab_size=50280,
    head_dim=64,
    rope="none",
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    subquadratic=True,
)
