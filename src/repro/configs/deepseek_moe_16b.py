"""deepseek-moe-16b — fine-grained + shared experts [arXiv:2401.06066].

28L d_model=2048 16H (kv=16 -> MHA) d_ff=1408/expert, 2 shared + 64 routed
top-6, vocab 102400.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope="rope",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  d_ff_shared=2816),
)
