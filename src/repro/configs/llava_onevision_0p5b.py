"""llava-onevision-0.5b — the paper's own demonstration model (§3.1).

SigLip vision encoder (stubbed frontend -> patch features of width 1152) +
projector + Qwen2-0.5B decoder: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936.  This is the model NANOMIND decomposes into bricks and runs
with vis-fp16 / dec-q4f16 hybrid quantization.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-onevision-0.5b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    head_dim=64,
    act="swiglu",
    norm="rmsnorm",
    rope="rope",
    rope_theta=1000000.0,
    tie_embeddings=True,
    vlm=True,
    vision_feat_dim=1152,
    vision_tokens=729,     # 27x27 patches (SigLip-384)
    # slot classes: thumbnail (14x14 ≈ 196 patches) vs full SigLip-384
    # resolution; OneVision's anyres grid carries up to 4 image tiles
    vision_token_buckets=(196, 729),
    vision_max_images=4,
    # the 0.5B decoder leaves headroom on the staging side: commit up to
    # four same-class requests per strided TABM slab
    max_stage_batch=4,
    attn_sharding="context",
)
