"""seamless-m4t-large-v2 — enc-dec multimodal [arXiv:2308.11596].

24L (enc) + 24L (dec), d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
Audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, T, d_model).  Decode cells use a fixed 8192-frame encoder
memory with the decoder self-cache at the cell's seq_len (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,           # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    act="gelu",
    norm="layernorm",
    rope="rope",
    tie_embeddings=True,
    encdec=True,
    n_enc_layers=24,
    enc_seq_len=8192,
)
