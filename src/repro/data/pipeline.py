"""Data pipeline: synthetic corpora -> packing -> sharded device batches.

Everything is deterministic in (seed, host_id) so a restarted / re-meshed
job replays the same stream from a step counter — the data-side half of
fault tolerance (distributed/fault_tolerance.py drives the re-mesh; this
module guarantees the stream is reproducible across it).

Synthetic documents use a Zipf unigram model with EOS-terminated variable
lengths — enough structure for loss curves to move and packing code paths
(document boundaries, loss masks) to be exercised for real.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def synthetic_documents(seed: int, vocab_size: int, mean_len: int = 512,
                        eos_id: int = 1) -> Iterator[np.ndarray]:
    """Endless stream of variable-length token documents (Zipf unigrams)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    while True:
        n = max(8, int(rng.exponential(mean_len)))
        doc = rng.choice(ranks, size=n, p=probs).astype(np.int32)
        doc[-1] = eos_id
        yield doc


@dataclass
class PackedLMDataset:
    """Packs documents into fixed (seq_len,) rows with loss masks.

    Fixed shapes are a *feature*, not a limitation: the paper's NPU section
    makes the same choice (pre-resize all inputs; never recompile)."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    eos_id: int = 1

    def __post_init__(self):
        self._docs = synthetic_documents(self.seed, self.vocab_size,
                                         eos_id=self.eos_id)
        self._buf = np.empty((0,), np.int32)

    def next_row(self) -> Dict[str, np.ndarray]:
        while self._buf.shape[0] < self.seq_len + 1:
            self._buf = np.concatenate([self._buf, next(self._docs)])
        row = self._buf[: self.seq_len]
        self._buf = self._buf[self.seq_len:]
        mask = (row != self.eos_id).astype(np.int32)
        return {"tokens": row.copy(), "loss_mask": mask}


@dataclass
class ShardedLoader:
    """Per-host batch loader: host h of H draws rows [h::H] of the global
    batch, so the concatenation across hosts is the deterministic global
    stream regardless of topology."""

    dataset: PackedLMDataset
    global_batch: int
    host_id: int = 0
    n_hosts: int = 1
    step: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def seek(self, step: int):
        """Replay determinism: rebuild the stream and skip to `step`."""
        self.dataset.__post_init__()
        self.step = 0
        for _ in range(step * self.global_batch):
            self.dataset.next_row()
        self.step = step

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rows = []
        for i in range(self.global_batch):
            row = self.dataset.next_row()
            if i % self.n_hosts == self.host_id:
                rows.append(row)
        self.step += 1
        return {k: np.stack([r[k] for r in rows]) for k in rows[0]}


def multimodal_batch_iter(cfg, global_batch: int, seq_len: int,
                          seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Adds the stub modality frontends' outputs (precomputed patch/frame
    embeddings per the assignment) to the token stream."""
    ds = PackedLMDataset(cfg.vocab_size, seq_len, seed=seed)
    loader = ShardedLoader(ds, global_batch)
    rng = np.random.default_rng(seed + 1)
    for batch in loader:
        if cfg.vlm:
            batch["vision_feats"] = rng.standard_normal(
                (global_batch, cfg.vision_tokens, cfg.vision_feat_dim)
            ).astype(np.float32) * 0.02
        if cfg.encdec:
            batch["src_embeds"] = rng.standard_normal(
                (global_batch, seq_len, cfg.d_model)).astype(np.float32) * 0.02
            batch["tgt_tokens"] = batch.pop("tokens")
            batch.pop("loss_mask", None)
        yield batch
