"""Data substrate: synthetic corpora, packing, sharded host loading."""
from repro.data.pipeline import (PackedLMDataset, ShardedLoader,
                                 multimodal_batch_iter, synthetic_documents)
