"""Continuous-batching serving engine with the paper's full pipeline:

  modality frontend (stub) -> projector brick -> TABM ring slot ->
  decoder prefill (bucketed static shapes) -> slot cache -> batched decode

The vision path is not reimplemented here: the engine compiles the
BrickGraph into an :class:`repro.core.plan.ExecutionPlan` and drives the
plan's TABM edge as a real producer/consumer pair —

* **producer** (``_stage``): ``plan.produce`` runs the frontend/projector
  bricks and commits the embeds into a ring slot, possibly several steps
  before the request is admitted.  A FULL ring stalls staging (requests
  stay queued) — backpressure, never a silent ring bypass.
* **consumer** (``_bind_vision``): at admission the oldest READY slot is
  bound as the prefill's vision input (zero-copy via donation; see
  core/tabm.py) and released once the prefill has consumed it.

Other paper mechanisms wired in:
* **module-level offloading** — the same plan compiles against submesh
  accelerators (core/scheduler.make_virtual_accelerators) for the pod-mode
  NPU/GPU split; see launch/serve_disagg.py.
* **battery-aware execution** — admission/batch knobs come from the
  three-state policy; CRITICAL switches to cascade one-shot inference.
* **static shapes** — prompts bucket-pad (kv_cache.bucket_length): one
  compiled prefill per bucket, one compiled decode step, never recompiled.

Metrics mirror the paper's evaluation: tokens/s, end-to-end latency
(submit -> finish), modeled energy, memory (pool + weights).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bricks import decompose
from repro.core.plan import compile_plan
from repro.core.power import BatteryAwareExecutor, PMU, PowerState
from repro.core.tabm import RingBuffer
from repro.models import model as M
from repro.serving.kv_cache import SlotCache, bucket_length
from repro.serving.sampling import sample

EOS_ID = 1


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                     # prompt token ids
    vision_feats: Optional[np.ndarray] = None
    max_new_tokens: int = 32
    temperature: float = 0.0
    submit_t: float = field(default_factory=time.time)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    out_tokens: List[int] = field(default_factory=list)
    slot: Optional[int] = None                 # KV-cache slot once admitted
    tabm_slot: Optional[int] = None            # ring slot once staged
    staged: bool = False                       # producer half already ran

    @property
    def e2e_latency(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


@dataclass
class EngineStats:
    decoded_tokens: int = 0
    prefills: int = 0
    steps: int = 0
    finished: int = 0
    start_t: float = field(default_factory=time.time)

    def tokens_per_s(self) -> float:
        dt = time.time() - self.start_t
        return self.decoded_tokens / dt if dt > 0 else 0.0


class ServingEngine:
    """Decoder-only (dense/moe/ssm/hybrid/vlm) continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 2048, executor: Optional[
                     BatteryAwareExecutor] = None,
                 rng_seed: int = 0):
        assert not cfg.encdec, "engine serves decoder-only archs"
        self.cfg = cfg
        self.params = params
        self.slots = SlotCache(cfg, n_slots, max_len)
        self.max_len = max_len
        self.executor = executor or BatteryAwareExecutor(PMU())
        self.queue: List[Request] = []
        self.live: Dict[int, Request] = {}      # slot -> request
        self.done: List[Request] = []
        self.stats = EngineStats()
        self.key = jax.random.PRNGKey(rng_seed)
        # TABM pool between encoder and decoder bricks (vlm archs)
        self.tabm = RingBuffer(n_slots=max(2, n_slots // 2),
                               max_tokens=cfg.vision_tokens or 1,
                               dim=cfg.d_model) if cfg.vlm else None
        # the one brick runtime: vision staging routes through the plan's
        # projector brick and TABM edge (no inline reimplementation)
        self.plan = compile_plan(decompose(cfg), params, tabm=self.tabm)

        self._prefill_cache: Dict[int, Any] = {}
        self._decode = jax.jit(
            lambda p, t, c: M.lm_decode_step(p, cfg, t, c),
            donate_argnums=(2,))

    # -- public api ----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or self.live) and self.stats.steps < max_steps:
            self.step()
        return self.done

    # -- internals -----------------------------------------------------------
    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            cfg = self.cfg

            def fn(p, tokens, vision_embeds, last_idx):
                """Right-padded bucket prefill; logits read at the true
                prompt end (last_idx-1); pad positions stay in the cache
                but decode's per-slot length mask never attends them."""
                B, S = tokens.shape
                from repro.models.common import (default_mrope_positions,
                                                 default_positions)
                positions = default_positions(B, S)
                mrope = (default_mrope_positions(B, S)
                         if cfg.rope == "mrope" else None)
                rope_fn = M.make_rope_fn(cfg, positions, mrope)
                x = p["embed"][tokens]
                if vision_embeds is not None:
                    x = jnp.concatenate(
                        [vision_embeds.astype(x.dtype),
                         x[:, vision_embeds.shape[1]:]], axis=1)
                from repro.models import decoder as dec
                x, caches, _ = dec.stack_forward(
                    p["layers"], cfg, x, rope_fn, causal=True,
                    want_cache=True, decode_len=self.max_len, remat=False)
                x_last = jnp.take_along_axis(
                    x, (last_idx - 1)[:, None, None].astype(jnp.int32), 1)
                logits = M._head(p, cfg, x_last)
                return logits[:, 0], {"layers": caches}

            self._prefill_cache[bucket] = jax.jit(fn)
        return self._prefill_cache[bucket]

    def _stage(self):
        """Producer half of the TABM edge: run the plan's frontend/projector
        stages for queued vlm requests and commit the embeds into ring
        slots, ahead of (and decoupled from) KV-slot admission.  A FULL
        ring stalls the producer — the stalled request stays at the queue
        head and staging retries next step (backpressure, never a bypass)."""
        if self.tabm is None:
            return
        for req in self.queue:
            if req.staged:
                continue
            if req.vision_feats is None:
                req.staged = True              # text-only: nothing to commit
                continue
            slot = self.plan.produce(
                {"vision_feats": jnp.asarray(req.vision_feats)})
            if slot is None:                   # FULL -> stall, retry later
                break
            req.tabm_slot = slot
            req.staged = True

    def _bind_vision(self, req: Request) -> Optional[jnp.ndarray]:
        """Consumer half: bind the oldest READY ring slot as the prefill's
        vision input.  FIFO commit order == FIFO admission order, so the
        bound slot is this request's."""
        if req.tabm_slot is None:
            return None
        got = self.plan.consume()
        assert got is not None and got[0] == req.tabm_slot
        slot, view, n = got
        return view[None, :n]

    def _admit(self):
        state, knobs, _ = self.executor.current()
        power_ok = (knobs.admission_rate > 0
                    or state is PowerState.UNCONSTRAINED)
        if power_ok:
            self._stage()                      # producer runs ahead
        budget = min(len(self.slots.free), knobs.max_batch)
        if not power_ok:
            budget = 0
        while self.queue and budget > 0:
            req = self.queue[0]
            if self.tabm is not None and not req.staged:
                break                          # producer stalled on FULL ring
            slot = self.slots.take_slot()
            if slot is None:
                break
            self.queue.pop(0)
            budget -= 1
            prompt = np.asarray(req.tokens, np.int32)
            bucket = bucket_length(len(prompt),
                                   buckets=self._buckets())
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :len(prompt)] = prompt      # right-pad into the bucket
            vision = self._bind_vision(req)
            logits, cache = self._prefill_fn(bucket)(
                self.params, jnp.asarray(padded), vision,
                jnp.asarray([len(prompt)], jnp.int32))
            if req.tabm_slot is not None:      # prefill consumed the view
                self.plan.release(req.tabm_slot)
            self.slots.insert(slot, cache, len(prompt))
            req.slot = slot
            self.live[slot] = req
            self.stats.prefills += 1
            # first token from the prefill logits
            tok = self._pick(logits, req)
            req.out_tokens.append(int(tok[0]))
            req.first_token_t = time.time()

    def _pick(self, logits, req: Request):
        if req.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return sample(logits, k, temperature=req.temperature)

    def _buckets(self):
        caps = [b for b in (128, 256, 512, 1024, 2048, 4096)
                if b <= self.max_len - 1]
        return tuple(caps) or (self.max_len - 1,)

    def step(self):
        self._admit()
        if not self.live:
            self.stats.steps += 1
            return
        # batched decode over ALL slots (inactive ones masked out)
        tokens = np.zeros((self.slots.n_slots, 1), np.int32)
        for slot, req in self.live.items():
            tokens[slot, 0] = req.out_tokens[-1]
        logits, self.slots.cache = self._decode(
            self.params, jnp.asarray(tokens), self.slots.cache)
        self.stats.steps += 1

        finished = []
        for slot, req in list(self.live.items()):
            tok = self._pick(logits[slot:slot + 1], req)
            t = int(tok[0])
            req.out_tokens.append(t)
            self.stats.decoded_tokens += 1
            over_len = int(self.slots.lengths[slot]) + 1 >= self.max_len
            if (t == EOS_ID or len(req.out_tokens) >= req.max_new_tokens
                    or over_len):
                req.finish_t = time.time()
                finished.append(slot)
        for slot in finished:
            self.done.append(self.live.pop(slot))
            self.slots.release(slot)
            self.stats.finished += 1

    # -- reporting -----------------------------------------------------------
    def memory_bytes(self) -> Dict[str, int]:
        from repro.core.quantize import tree_bytes
        return {"weights": tree_bytes(self.params),
                "kv_pool": self.slots.nbytes,
                "tabm": self.tabm.nbytes if self.tabm else 0}
