"""Continuous-batching serving engine with the paper's full pipeline:

  modality frontend (stub) -> encoder/projector brick -> TABM ring slot ->
  decoder prefill (bucketed static shapes) -> slot cache -> batched decode

Paper mechanisms wired in:
* **module-level offloading** — when the engine is built with submeshes
  (core/scheduler.make_virtual_accelerators) the encoder brick runs on the
  "NPU" slice and decode on the "GPU" slice, hand-off via SubmeshPipe;
  single-mesh mode keeps the same code path with a no-op pipe.
* **TABM** — encoder outputs land in a RingBuffer slot; the decoder binds
  the slot as prefill input (zero-copy via donation; see core/tabm.py).
* **battery-aware execution** — admission/batch knobs come from the
  three-state policy; CRITICAL switches to cascade one-shot inference.
* **static shapes** — prompts bucket-pad (kv_cache.bucket_length): one
  compiled prefill per bucket, one compiled decode step, never recompiled.

Metrics mirror the paper's evaluation: tokens/s, end-to-end latency
(submit -> finish), modeled energy, memory (pool + weights).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.power import BatteryAwareExecutor, PMU, PowerState
from repro.core.tabm import RingBuffer
from repro.models import model as M
from repro.serving.kv_cache import SlotCache, bucket_length
from repro.serving.sampling import sample

EOS_ID = 1


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                     # prompt token ids
    vision_feats: Optional[np.ndarray] = None
    max_new_tokens: int = 32
    temperature: float = 0.0
    submit_t: float = field(default_factory=time.time)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    out_tokens: List[int] = field(default_factory=list)
    slot: Optional[int] = None

    @property
    def e2e_latency(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


@dataclass
class EngineStats:
    decoded_tokens: int = 0
    prefills: int = 0
    steps: int = 0
    finished: int = 0
    start_t: float = field(default_factory=time.time)

    def tokens_per_s(self) -> float:
        dt = time.time() - self.start_t
        return self.decoded_tokens / dt if dt > 0 else 0.0


class ServingEngine:
    """Decoder-only (dense/moe/ssm/hybrid/vlm) continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 2048, executor: Optional[
                     BatteryAwareExecutor] = None,
                 rng_seed: int = 0):
        assert not cfg.encdec, "engine serves decoder-only archs"
        self.cfg = cfg
        self.params = params
        self.slots = SlotCache(cfg, n_slots, max_len)
        self.max_len = max_len
        self.executor = executor or BatteryAwareExecutor(PMU())
        self.queue: List[Request] = []
        self.live: Dict[int, Request] = {}      # slot -> request
        self.done: List[Request] = []
        self.stats = EngineStats()
        self.key = jax.random.PRNGKey(rng_seed)
        # TABM pool between encoder and decoder bricks (vlm archs)
        self.tabm = RingBuffer(n_slots=max(2, n_slots // 2),
                               max_tokens=cfg.vision_tokens or 1,
                               dim=cfg.d_model) if cfg.vlm else None

        self._prefill_cache: Dict[int, Any] = {}
        self._decode = jax.jit(
            lambda p, t, c: M.lm_decode_step(p, cfg, t, c),
            donate_argnums=(2,))

    # -- public api ----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or self.live) and self.stats.steps < max_steps:
            self.step()
        return self.done

    # -- internals -----------------------------------------------------------
    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            cfg = self.cfg

            def fn(p, tokens, vision_embeds, last_idx):
                """Right-padded bucket prefill; logits read at the true
                prompt end (last_idx-1); pad positions stay in the cache
                but decode's per-slot length mask never attends them."""
                B, S = tokens.shape
                from repro.models.common import (default_mrope_positions,
                                                 default_positions)
                positions = default_positions(B, S)
                mrope = (default_mrope_positions(B, S)
                         if cfg.rope == "mrope" else None)
                rope_fn = M.make_rope_fn(cfg, positions, mrope)
                x = p["embed"][tokens]
                if vision_embeds is not None:
                    x = jnp.concatenate(
                        [vision_embeds.astype(x.dtype),
                         x[:, vision_embeds.shape[1]:]], axis=1)
                from repro.models import decoder as dec
                x, caches, _ = dec.stack_forward(
                    p["layers"], cfg, x, rope_fn, causal=True,
                    want_cache=True, decode_len=self.max_len, remat=False)
                x_last = jnp.take_along_axis(
                    x, (last_idx - 1)[:, None, None].astype(jnp.int32), 1)
                logits = M._head(p, cfg, x_last)
                return logits[:, 0], {"layers": caches}

            self._prefill_cache[bucket] = jax.jit(fn)
        return self._prefill_cache[bucket]

    def _encode_vision(self, req: Request) -> Optional[jnp.ndarray]:
        """Encoder brick -> TABM slot -> bind for the decoder (zero-copy)."""
        if not (self.cfg.vlm and req.vision_feats is not None):
            return None
        vp = self.params["vis_proj"]
        feats = jnp.asarray(req.vision_feats)
        v = jax.nn.gelu(jnp.einsum(
            "bnf,fd->bnd", feats.astype(self.cfg.compute_dtype), vp["w1"]))
        v = jnp.einsum("bnd,de->bne", v, vp["w2"])
        slot = self.tabm.acquire_write()
        if slot is None:                       # ring full: backpressure
            return v
        self.tabm.commit_write(slot, v[0])
        got = self.tabm.acquire_read()
        assert got is not None
        s, view, n = got
        self.tabm.release(s)
        return view[None, :n]

    def _admit(self):
        state, knobs, _ = self.executor.current()
        budget = min(len(self.slots.free), knobs.max_batch)
        if knobs.admission_rate <= 0 and state is not PowerState.UNCONSTRAINED:
            budget = 0
        while self.queue and budget > 0:
            req = self.queue[0]
            slot = self.slots.take_slot()
            if slot is None:
                break
            self.queue.pop(0)
            budget -= 1
            prompt = np.asarray(req.tokens, np.int32)
            bucket = bucket_length(len(prompt),
                                   buckets=self._buckets())
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :len(prompt)] = prompt      # right-pad into the bucket
            vision = self._encode_vision(req)
            logits, cache = self._prefill_fn(bucket)(
                self.params, jnp.asarray(padded), vision,
                jnp.asarray([len(prompt)], jnp.int32))
            self.slots.insert(slot, cache, len(prompt))
            req.slot = slot
            self.live[slot] = req
            self.stats.prefills += 1
            # first token from the prefill logits
            tok = self._pick(logits, req)
            req.out_tokens.append(int(tok[0]))
            req.first_token_t = time.time()

    def _pick(self, logits, req: Request):
        if req.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return sample(logits, k, temperature=req.temperature)

    def _buckets(self):
        caps = [b for b in (128, 256, 512, 1024, 2048, 4096)
                if b <= self.max_len - 1]
        return tuple(caps) or (self.max_len - 1,)

    def step(self):
        self._admit()
        if not self.live:
            self.stats.steps += 1
            return
        # batched decode over ALL slots (inactive ones masked out)
        tokens = np.zeros((self.slots.n_slots, 1), np.int32)
        for slot, req in self.live.items():
            tokens[slot, 0] = req.out_tokens[-1]
        logits, self.slots.cache = self._decode(
            self.params, jnp.asarray(tokens), self.slots.cache)
        self.stats.steps += 1

        finished = []
        for slot, req in list(self.live.items()):
            tok = self._pick(logits[slot:slot + 1], req)
            t = int(tok[0])
            req.out_tokens.append(t)
            self.stats.decoded_tokens += 1
            over_len = int(self.slots.lengths[slot]) + 1 >= self.max_len
            if (t == EOS_ID or len(req.out_tokens) >= req.max_new_tokens
                    or over_len):
                req.finish_t = time.time()
                finished.append(slot)
        for slot in finished:
            self.done.append(self.live.pop(slot))
            self.slots.release(slot)
            self.stats.finished += 1

    # -- reporting -----------------------------------------------------------
    def memory_bytes(self) -> Dict[str, int]:
        from repro.core.quantize import tree_bytes
        return {"weights": tree_bytes(self.params),
                "kv_pool": self.slots.nbytes,
                "tabm": self.tabm.nbytes if self.tabm else 0}
