"""Continuous-batching serving engine — a three-stage async pipeline over
a class-partitioned TABM pool, batched at every stage:

    producer threads (StagingWorker,         consumer (step loop)
    one per slot class)                      ---------------------
    ------------------------------           plan.consume (per-slot,
    microbatch: vision encode +              per-class ready wait) ->
    projector as ONE jit call ->             grouped batch-B prefill ->
    plan.produce_many -> ONE strided         KVCache.insert_many (one
    class-slab ring commit (blocks on        strided scatter) ->
    class FULL = per-class backpressure)     batched decode

Batching knobs: a class's staging microbatch is
``min(ModelConfig.max_stage_batch, Knobs.max_stage_batch, ring
capacity)`` — THROTTLED shrinks the batch before any class sheds depth —
and ``_admit`` groups *consecutive* bucket-matched staged requests (same
prompt bucket + same class/slab width) into one compiled batch-B prefill
call.  Cross-class aging (``aging_steps``) reserves a KV slot for a
request skipped too many admission rounds, so a thumbnail flood cannot
starve a stalled hi-res head forever.

The vision path is not reimplemented here: the engine compiles the
BrickGraph into an :class:`repro.core.plan.ExecutionPlan` and drives the
plan's TABM edge as a real producer/consumer pair —

* **slot classes**: every vision request is classified at submit (image
  count × resolution bucket, from the arch config — core/slot_classes)
  and staged through its own class-sized ring of the
  :class:`~repro.core.tabm.SlotClassPool`.  A 1-image thumbnail no longer
  pads into a 4-image full-resolution slab, and a FULL high-resolution
  ring stalls only that class's producer thread — thumbnails keep
  staging and admitting (class isolation).
* **producer** (:class:`StagingWorker`): one thread per slot class pulls
  admitted requests from its class's hand-off queue and runs
  ``plan.produce`` (vision encode -> projector -> ring commit) *off the
  step loop*, so request k+1's vision encode overlaps request k's decode
  — the paper's TABM smoothing made actually concurrent.  A FULL class
  ring blocks that class's thread inside ``acquire_write`` (backpressure,
  never a silent bypass); admission charges each request's class against
  its own staged-ahead depth budget
  (core/scheduler.class_staging_budgets), scaled by the battery knob
  ``class_depth_scale`` — THROTTLED shrinks the high-resolution classes'
  depth first, so expensive staging is the first load shed.
* **consumer** (``_bind_vision``): at admission the request's committed
  slot is bound as the prefill's vision input after a per-slot ready wait
  on its class ring (``wait_ready``; zero-copy via donation, see
  core/tabm.py) and released once the prefill has consumed it —
  validated by the ring's seqlock generation.

Lifecycle: ``shutdown()`` (or the context manager) stops the worker —
closing the ring wakes a producer stalled on FULL — joins the thread,
drains staged-but-unconsumed slots back to EMPTY, and resolves every
outstanding request (queued or live mid-decode) as failed with
:class:`EngineClosed`; an engine dropped without shutdown is reaped by a
finalizer so the producer thread never leaks.  A staging error (e.g. the projector
raising) aborts the ring write inside ``plan.produce`` and surfaces on the
originating request's ``error`` field; the request finishes failed instead
of wedging the pipeline.  ``async_staging=False`` keeps the old inline
single-threaded staging — bit-identical tokens, used as the equivalence
oracle in tests/test_engine_async.py.

Other paper mechanisms wired in:
* **module-level offloading** — the same plan compiles against submesh
  accelerators (core/scheduler.make_virtual_accelerators) for the pod-mode
  NPU/GPU split; see launch/serve_disagg.py.
* **battery-aware execution** — admission/batch knobs come from the
  three-state policy; CRITICAL switches to cascade one-shot inference.
* **static shapes** — prompts bucket-pad (kv_cache.bucket_length): one
  compiled prefill per bucket, one compiled decode step, never recompiled.

Decode is a **cohort step** over a **paged KV pool**
(kv_cache.PagedKVCache): every in-flight request joins one batched jit
decode call — padded to a small set of cohort-size buckets (powers of
two, one compile each) — that gathers each row's context through its
block table and scatters the new K/V back into its granted blocks.
Admission *grants* each request the KV blocks its lifetime needs,
charged per slot class (core/scheduler.kv_block_budgets) exactly like
staged-ahead depth, and the battery knob ``class_kv_scale`` sheds the
high-resolution classes' block share first under THROTTLED.  A
finishing request's blocks return to the free pool the same step
(continuous batching: the next staged request can admit mid-flight,
while everyone else's rows decode on undisturbed).

Staged TABM slots are **shared**: two requests submitting identical
vision bytes (same class, same content hash) stage ONCE — the second
takes a refcounted read view of the first's READY slot
(core/tabm.addref/shared_view) and the slab frees only when the last
holder releases.

Metrics mirror the paper's evaluation: tokens/s, end-to-end latency
(submit -> finish), modeled energy, memory (pool + weights).  ``trace``
records the producer/consumer interleaving ((event, rid, t) tuples) —
the overlap evidence the async tests assert on.
"""
from __future__ import annotations

import hashlib
import queue
import threading
import time
import weakref
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bricks import decompose
from repro.core.plan import compile_plan
from repro.core.power import BatteryAwareExecutor, PMU, PowerState
from repro.core.scheduler import class_staging_budgets, kv_block_budgets
from repro.core.tabm import SlotClassPool, TABMError
from repro.models import model as M
from repro.serving.kv_cache import PagedKVCache, SlotCache, bucket_length
from repro.serving.sampling import sample
from repro.telemetry.calibration import CostCalibration
from repro.telemetry.ledger import Ledger
from repro.telemetry.probes import WallProbe

EOS_ID = 1


class TraceEvent(NamedTuple):
    """One engine lifecycle event, stamped with ``time.monotonic()`` at
    record time — monotonic so producer-thread and step-loop events
    interleave in true order (the telemetry ledger's wall-time probes
    anchor to the same clock).  Tuple-compatible: existing consumers
    unpack ``(event, rid, t)``."""

    event: str
    rid: int
    t: float


class EngineClosed(RuntimeError):
    """The engine shut down before this request could complete."""


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                     # prompt token ids
    vision_feats: Optional[np.ndarray] = None
    n_images: int = 1                      # images the vision feats cover
    max_new_tokens: int = 32
    temperature: float = 0.0
    submit_t: float = field(default_factory=time.time)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    out_tokens: List[int] = field(default_factory=list)
    slot: Optional[int] = None                 # KV-cache slot once admitted
    tabm_slot: Optional[int] = None            # class-ring slot once staged
    slot_class: Optional[str] = None           # TABM class, set at submit
    stage_submitted: bool = False              # handed to the StagingWorker
    aging: int = 0                             # admission rounds spent queued
                                               # (cross-class KV reservation
                                               # once >= engine.aging_steps)
    error: Optional[BaseException] = None      # staging/engine failure
    # committed TABM slab, trimmed to its true token count — captured at
    # vision bind when the engine runs capture_slab=True (the prefill
    # fleet: the slab rides the wire so the hand-off is self-contained)
    slab: Optional[np.ndarray] = field(default=None, repr=False)
    # staged-slab sharing: identical vision bytes stage once.  share_of
    # points at the request that owns the staging; the owner's sharers
    # list is granted refcounted views of its slot at bind time
    share_of: Optional["Request"] = None
    sharers: List["Request"] = field(default_factory=list, repr=False)
    _share_key: Optional[tuple] = None
    _tabm_gen: Optional[int] = None            # seqlock gen at consume
    _staged_ev: threading.Event = field(default_factory=threading.Event,
                                        repr=False)

    @property
    def staged(self) -> bool:
        """Producer half already ran (committed or failed).  Derived from
        the event so the admission check and the idle park can never
        desynchronize."""
        return self._staged_ev.is_set()

    @property
    def e2e_latency(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


@dataclass
class EngineStats:
    decoded_tokens: int = 0
    prefills: int = 0
    steps: int = 0
    finished: int = 0
    failed: int = 0
    start_t: float = field(default_factory=time.time)

    def tokens_per_s(self) -> float:
        dt = time.time() - self.start_t
        return self.decoded_tokens / dt if dt > 0 else 0.0


_STOP = object()


class StagingWorker:
    """The pipeline's producer stage: one thread *per slot class*, each
    draining its class's hand-off queue into **microbatches** through
    ``plan.produce_many`` — one batched vision-encode+projector call and
    one strided slab commit per drain, up to ``stage_batch(cls)`` requests
    (the battery-scaled ``Knobs.max_stage_batch`` × the arch's
    ``max_stage_batch``, clamped to the class ring's capacity).

    The worker owns the ring-write side of the TABM contract, per class:
    a class thread blocks *inside* ``acquire_write_many`` on its own FULL
    ring (so backpressure stalls exactly that class's producer — never
    the decode loop, never another class's staging), aborts the whole
    slab if a brick raises — then **isolates** the failure by restaging
    the microbatch one request at a time, so one request's bad input
    fails only its owner, never its batchmates — and attaches any
    failure to the originating request before flagging it staged.
    ``shutdown`` closes the pool first — waking every stalled class
    thread — then joins them all; requests still queued at that point
    are cancelled with :class:`EngineClosed`.

    ``classes=(None,)`` (the default) degenerates to the single-ring,
    single-thread pipeline; ``stage_batch=None`` to K=1 staging."""

    def __init__(self, plan, trace, classes=(None,), stage_batch=None):
        self.plan = plan
        self._trace = trace                     # (event, rid) -> None
        self._classes = tuple(classes)
        self._stage_batch = stage_batch         # (slot_class) -> int | None
        self._qs: Dict[Optional[str], "queue.Queue"] = {
            c: queue.Queue() for c in self._classes}
        self._stop = threading.Event()
        self._lock = threading.Lock()
        # handed over, not yet staged — charged per class at hand-off
        self._in_flight: Dict[Optional[str], int] = {
            c: 0 for c in self._classes}
        self._threads: Dict[Optional[str], threading.Thread] = {}

    def in_flight(self, slot_class: Optional[str] = None) -> int:
        with self._lock:
            return self._in_flight[slot_class]

    def in_flight_by_class(self) -> Dict[Optional[str], int]:
        with self._lock:
            return dict(self._in_flight)

    def start(self, slot_class: Optional[str] = None):
        if slot_class not in self._threads:
            name = "tabm-staging" if slot_class is None \
                else f"tabm-staging[{slot_class}]"
            t = threading.Thread(target=self._run, args=(slot_class,),
                                 name=name, daemon=True)
            self._threads[slot_class] = t
            t.start()

    def submit(self, reqs):
        """Hand one request — or one list of same-class requests, the
        admission round's microbatch — to the owning class thread."""
        batch = reqs if isinstance(reqs, list) else [reqs]
        if not batch:
            return
        if self._stop.is_set():
            raise EngineClosed("staging worker already shut down")
        cls = batch[0].slot_class
        if any(r.slot_class != cls for r in batch):
            raise EngineClosed("a staging microbatch must be one class")
        if cls not in self._qs:
            raise EngineClosed(f"no staging queue for slot class {cls!r}")
        self.start(cls)
        with self._lock:
            self._in_flight[cls] += len(batch)
        self._qs[cls].put(batch)

    def _cap(self, slot_class: Optional[str]) -> int:
        if self._stage_batch is None:
            return 1
        return max(1, int(self._stage_batch(slot_class)))

    def _run(self, slot_class: Optional[str]):
        q = self._qs[slot_class]
        pending: "deque[Request]" = deque()
        stop_seen = False
        while True:
            if not pending:
                item = q.get()
                if item is _STOP:
                    break
                pending.extend(item if isinstance(item, list) else [item])
            while True:                        # opportunistic drain, no block
                try:
                    nxt = q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stop_seen = True
                    break
                pending.extend(nxt if isinstance(nxt, list) else [nxt])
            cap = self._cap(slot_class)        # battery-scaled, per drain
            batch = [pending.popleft()
                     for _ in range(min(cap, len(pending)))]
            self._stage_batch_now(slot_class, batch)
            if stop_seen and not pending:
                break

    def _stage_batch_now(self, slot_class: Optional[str],
                         batch: List[Request]):
        """One microbatch through produce_many: K FIFO slots, one batched
        projector call, one strided slab commit; per-request commit
        events so consumers see the same per-slot signals as K=1."""
        try:
            if self._stop.is_set():
                raise EngineClosed("engine shut down before staging")
            for req in batch:
                self._trace("stage_start", req.rid)
            slots = self.plan.produce_many(
                [{"vision_feats": jnp.asarray(r.vision_feats)}
                 for r in batch],
                slot_class=slot_class, block=True)
            if slots is None:                  # ring closed mid-stall
                raise EngineClosed("ring closed while staging stalled")
            for req, slot in zip(batch, slots):
                req.tabm_slot = slot
                self._trace("stage_commit", req.rid)
            if len(batch) > 1:                 # the acceptance evidence
                self._trace("slab_commit", len(batch))
        except BaseException as e:
            if len(batch) > 1 and not isinstance(e, EngineClosed):
                # the slab was aborted whole (abort-all-on-failure);
                # isolate the bad request by restaging one at a time so
                # the error lands only on its owner
                self._restage_isolated(slot_class, batch)
            else:
                for req in batch:              # propagate to the request(s)
                    req.error = e
                    self._trace("stage_error", req.rid)
        finally:
            with self._lock:
                self._in_flight[slot_class] -= len(batch)
            for req in batch:
                req._staged_ev.set()            # marks staged

    def _restage_isolated(self, slot_class: Optional[str],
                          batch: List[Request]):
        for req in batch:
            try:
                if self._stop.is_set():
                    raise EngineClosed("engine shut down before staging")
                slot = self.plan.produce(
                    {"vision_feats": jnp.asarray(req.vision_feats)},
                    slot_class=slot_class, block=True)
                if slot is None:
                    raise EngineClosed("ring closed while staging stalled")
                req.tabm_slot = slot
                self._trace("stage_commit", req.rid)
            except BaseException as e:
                req.error = e
                self._trace("stage_error", req.rid)

    def shutdown(self, timeout: float = 10.0) -> bool:
        """Stop accepting, cancel in-flight staging, join every class
        thread.  Returns True when all threads are fully dead (no daemon
        leak)."""
        self._stop.set()
        if self.plan.tabm is not None:
            self.plan.tabm.close()        # wakes every class's FULL stall
        threads = list(self._threads.items())
        for cls, _ in threads:
            self._qs[cls].put(_STOP)
        deadline = time.monotonic() + timeout
        alive = False
        for _, t in threads:
            t.join(max(0.0, deadline - time.monotonic()))
            alive = alive or t.is_alive()
        return not alive


class ServingEngine:
    """Decoder-only (dense/moe/ssm/hybrid/vlm) continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 2048, executor: Optional[
                     BatteryAwareExecutor] = None,
                 rng_seed: int = 0, async_staging: bool = True,
                 placement=None, accels=None, backend=None,
                 stage_batch: Optional[int] = None,
                 aging_steps: int = 32, block_size: int = 64,
                 kv_blocks: Optional[int] = None,
                 max_cohort: Optional[int] = None,
                 share_staged: bool = True,
                 calibration: Optional[CostCalibration] = None,
                 capture_slab: bool = False,
                 use_fused: Optional[bool] = None):
        assert not cfg.encdec, "engine serves decoder-only archs"
        self.cfg = cfg
        self.params = params
        # fused cohort-decode step (kernels/fused_decode): None resolves
        # per the dispatch convention — compiled Pallas on real TPU only;
        # off-TPU the composed path is the same numerics and faster than
        # interpret mode.  True forces the fused step (tests/bench).
        self.use_fused = use_fused
        # paged decode pool: kv_blocks < n_slots*blocks_per_slot
        # oversubscribes slots against KV memory; admission grants per
        # request, per class (kv_block_budgets)
        self.slots = PagedKVCache(cfg, n_slots, max_len,
                                  block_size=block_size,
                                  total_blocks=kv_blocks)
        self.max_len = max_len
        # cohort cap (None = every live slot decodes each step); when
        # capped, a rotating pointer keeps the excluded rows fair
        self.max_cohort = max_cohort
        self._rotate = 0
        self.executor = executor or BatteryAwareExecutor(PMU())
        # staging microbatch override; None = min(arch max_stage_batch,
        # battery Knobs.max_stage_batch), always clamped to ring capacity
        self._stage_batch_override = stage_batch
        # cross-class aging: a vision request skipped at admission this
        # many rounds reserves a KV slot against newer other-class
        # requests (anti-starvation under thumbnail floods)
        self.aging_steps = aging_steps
        self.queue: List[Request] = []
        self.live: Dict[int, Request] = {}      # slot -> request
        self.done: List[Request] = []
        self.stats = EngineStats()
        self.key = jax.random.PRNGKey(rng_seed)
        # producer/consumer interleaving evidence: TraceEvent(event, rid,
        # t=monotonic); bounded so a long-running server doesn't grow it
        # without limit
        self.trace: "deque[TraceEvent]" = deque(maxlen=4096)
        # wall-time probe feeding the telemetry ledger: per-brick staging
        # spans (via the plan) + the engine's prefill/decode spans, all
        # host clocks — no device syncs beyond the ones the loop already
        # pays.  `calibration` (optional, e.g. from a previous run's
        # measured ledger) lets admission price KV budgets from
        # observation (see _kv_energy_pressure)
        self.probe = WallProbe()
        self.calibration = calibration
        self._kv_pressure: Optional[float] = None
        # class-partitioned TABM pool between encoder and decoder bricks
        # (vlm archs): one class-sized ring per image-count x resolution
        # bucket (core/slot_classes), so a thumbnail request neither pads
        # into nor queues behind a multi-image full-resolution slab
        self.tabm = SlotClassPool.from_config(
            cfg, dim=cfg.d_model,
            slots_per_class=max(2, n_slots // 2)) if cfg.vlm else None
        # the one brick runtime: vision staging routes through the plan's
        # projector brick and TABM edge (no inline reimplementation).
        # placement/accels/backend pick the lowering substrate per brick
        # (core/backends) — the engine's step loop is identical on all of
        # them, the paper's "same graph, swappable compute unit"
        self.plan = compile_plan(decompose(cfg), params, tabm=self.tabm,
                                 placement=placement, accels=accels,
                                 backend=backend, probe=self.probe)
        # remembered so the battery policy's demotion can be undone when
        # charge recovers (plan.relower back to the compiled substrate)
        self._lowered_backends = {s.brick.name: s.backend
                                  for s in self.plan.steps}
        self._demoted_to: Optional[str] = None
        # producer stage: own thread unless the caller opts back into the
        # synchronous single-threaded pipeline (the equivalence oracle)
        self.async_staging = bool(async_staging and self.tabm is not None)
        self._worker = None
        if self.async_staging:
            # the worker must reference the engine only weakly (the live
            # thread roots the worker), or a dropped engine could never be
            # collected and its producer thread would leak; the finalizer
            # joins the thread for callers that skip shutdown()
            wself = weakref.ref(self)

            def _trace(event, rid):
                eng = wself()
                if eng is not None:
                    eng._trace_event(event, rid)

            def _stage_cap(slot_class):
                eng = wself()
                return 1 if eng is None else eng._class_stage_batch(
                    slot_class)

            self._worker = StagingWorker(
                self.plan, _trace, classes=tuple(self.tabm.names()),
                stage_batch=_stage_cap)
            self._finalizer = weakref.finalize(
                self, StagingWorker.shutdown, self._worker, 1.0)
        self._closed = False

        self._prefill_cache: Dict[int, Any] = {}
        # one compiled cohort decode step per cohort-size bucket
        self._cohort_cache: Dict[int, Any] = {}
        # staged-slab dedup registry: share key -> owning request
        self.share_staged = bool(share_staged and self.tabm is not None)
        self._stage_keys: Dict[tuple, Request] = {}
        # prefill-fleet mode: keep each request's committed slab (host
        # copy, trimmed) at vision bind, so export_remote can ship it
        self.capture_slab = bool(capture_slab)

    # -- public api ----------------------------------------------------------
    def submit(self, req: Request):
        if self._closed:
            raise EngineClosed("engine already shut down")
        if self.tabm is None or req.vision_feats is None:
            req._staged_ev.set()           # text-only: nothing to commit
        elif req.slot_class is None:
            # classify from the vision spec (token count x image count) —
            # the request is charged against exactly this class's ring and
            # admission depth; an unservable spec fails fast, at submit
            req.slot_class = self.tabm.classify(
                int(np.asarray(req.vision_feats).shape[1]), req.n_images)
        else:
            self.tabm.ring(req.slot_class)     # unknown class fails fast
        if self.share_staged and req.vision_feats is not None:
            # staged-slab dedup: identical vision bytes (class + shape +
            # content hash) stage once; later twins take refcounted read
            # views of the owner's slot at bind time (_grant_shares)
            key = self._stage_key(req)
            req._share_key = key
            owner = self._stage_keys.get(key)
            if (owner is not None and owner.error is None
                    and owner.finish_t is None):
                req.share_of = owner
                owner.sharers.append(req)
            else:
                self._stage_keys[key] = req
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or self.live) and self.stats.steps < max_steps:
            self.step()
        return self.done

    def shutdown(self, timeout: float = 10.0) -> bool:
        """Tear the pipeline down: stop+join the producer thread (a FULL
        stall is woken via ring close), drain staged-but-unconsumed slots
        back to EMPTY, and resolve every outstanding request — live
        mid-decode ones keep their partial tokens — as failed with
        EngineClosed.  Idempotent; returns True when no worker thread is
        left alive."""
        self._closed = True
        joined = True
        if self._worker is not None:
            joined = self._worker.shutdown(timeout)
            if joined:
                # torn down manually; a thread that outlived the join
                # timeout keeps its finalizer as the reaping safety net
                self._finalizer.detach()
        elif self.tabm is not None:
            self.tabm.close()
        if self.tabm is not None and joined:
            self.tabm.drain()              # READY/CONSUMED leftovers -> EMPTY
        for slot, req in list(self.live.items()):
            if req.error is None:
                req.error = EngineClosed("engine shut down mid-decode")
            self.slots.release(slot)
            self._fail(req)                # partial out_tokens are kept
        self.live.clear()
        while self.queue:
            req = self.queue.pop(0)
            if req.error is None:
                req.error = EngineClosed("engine shut down before admission")
            self._fail(req)
        return joined

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- internals -----------------------------------------------------------
    def _trace_event(self, event: str, rid: int):
        self.trace.append(TraceEvent(event, rid, time.monotonic()))

    def _stage_key(self, req: Request) -> tuple:
        """Dedup identity of a request's staged vision: class + slab
        shape + dtype + content hash — equal keys would commit
        byte-identical slabs, so one commit can serve all of them."""
        feats = np.asarray(req.vision_feats)
        return (req.slot_class, feats.shape, str(feats.dtype),
                hashlib.sha1(feats.tobytes()).hexdigest())

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            cfg = self.cfg
            # prefilled caches are written straight into granted KV
            # blocks (insert_many), so the cache width must be
            # block-aligned — the prompt bucket rounded up, NOT max_len:
            # a short prompt's prefill touches only the blocks its
            # grant actually covers
            bs = self.slots.block_size
            decode_len = -(-bucket // bs) * bs

            def fn(p, tokens, vision_embeds, last_idx):
                """Right-padded bucket prefill; logits read at the true
                prompt end (last_idx-1); pad positions stay in the cache
                but decode's per-slot length mask never attends them."""
                B, S = tokens.shape
                from repro.models.common import (default_mrope_positions,
                                                 default_positions)
                positions = default_positions(B, S)
                mrope = (default_mrope_positions(B, S)
                         if cfg.rope == "mrope" else None)
                rope_fn = M.make_rope_fn(cfg, positions, mrope)
                x = p["embed"][tokens]
                if vision_embeds is not None:
                    x = jnp.concatenate(
                        [vision_embeds.astype(x.dtype),
                         x[:, vision_embeds.shape[1]:]], axis=1)
                from repro.models import decoder as dec
                x, caches, _ = dec.stack_forward(
                    p["layers"], cfg, x, rope_fn, causal=True,
                    want_cache=True, decode_len=decode_len, remat=False)
                x_last = jnp.take_along_axis(
                    x, (last_idx - 1)[:, None, None].astype(jnp.int32), 1)
                logits = M._head(p, cfg, x_last)
                return logits[:, 0], {"layers": caches}

            self._prefill_cache[bucket] = jax.jit(fn)
        return self._prefill_cache[bucket]

    def _cohort_bucket(self, n: int) -> int:
        """Pad the cohort to the next power of two (capped at n_slots):
        a handful of compiled step sizes instead of one per live count."""
        return min(1 << max(0, n - 1).bit_length(), self.slots.n_slots)

    def _cohort_slots(self) -> List[int]:
        """The slots decoding this step.  Uncapped: every live slot —
        ONE batched call serves the whole fleet.  Capped (max_cohort): a
        rotating window so excluded rows are never starved."""
        slots = sorted(self.live)
        if self.max_cohort is not None and len(slots) > self.max_cohort:
            k = self._rotate % len(slots)
            slots = (slots[k:] + slots[:k])[: self.max_cohort]
            self._rotate += self.max_cohort
        return slots

    def _cohort_fn(self, bc: int):
        """The compiled cohort decode step for cohort-size bucket `bc`:
        gather each row's context from the paged pool through its block
        table — (bc, W) ids -> (G, bc, W*block_size, ...) — run ONE
        ``lm_decode_step`` over the cohort (per-row lengths as the
        index vector; rows are independent, so each decodes exactly as
        it would alone), then scatter the one new K/V position back
        into each row's current block and the updated slot state back
        by slot id.  Padded rows carry sentinel ids: gathers fill
        zeros (masked by length 0), scatters drop — padding costs no
        host branching and writes nothing.

        ``use_fused`` (engine flag) swaps the body for the fused
        Pallas step (kernels/fused_decode.cohort_step): in-VMEM weight
        unpack + QKV/MLP GEMMs + single-position KV scatter, bit-equal
        to this composed body.  Both flags (fused?, interpret?) resolve
        HERE, at build time, outside the jit — the dispatch rule of
        kernels/dispatch."""
        if bc not in self._cohort_cache:
            cfg = self.cfg
            paged = self.slots.paged
            bs = self.slots.block_size
            W = self.slots.blocks_per_slot

            from repro.kernels.dispatch import resolve_interpret
            from repro.kernels.fused_decode import (cohort_step,
                                                    fused_supported)
            use_fused = self.use_fused
            if use_fused is None:
                # default: fused only where compiled Pallas actually runs
                # (real TPU, no force_ref override) — off-TPU interpret
                # mode is the same numerics but strictly slower than the
                # composed XLA path
                use_fused = fused_supported(cfg) and not resolve_interpret()
            if use_fused:
                interp = resolve_interpret(None)

                def fn(p, tokens, lengths, slot_ids, tables, pool):
                    return cohort_step(
                        p, cfg, tokens, lengths, slot_ids, tables, pool,
                        block_size=bs, paged=paged, use_fused=True,
                        interpret=interp)

                self._cohort_cache[bc] = jax.jit(fn, donate_argnums=(5,))
                return self._cohort_cache[bc]

            def fn(p, tokens, lengths, slot_ids, tables, pool):
                layers = []
                for pos, is_paged in enumerate(paged):
                    if is_paged:
                        layers.append(jax.tree.map(
                            lambda l: jnp.take(
                                l, tables, axis=1, mode="fill",
                                fill_value=0).reshape(
                                    (l.shape[0], bc, W * bs)
                                    + l.shape[3:]),
                            pool[pos]))
                    else:
                        layers.append(jax.tree.map(
                            lambda l: jnp.take(l, slot_ids, axis=1,
                                               mode="fill", fill_value=0),
                            pool[pos]))
                cache = {"layers": tuple(layers), "index": lengths}
                logits, new = M.lm_decode_step(p, cfg, tokens, cache)
                # the block holding each row's newly written position
                blk = jnp.take_along_axis(
                    tables, (lengths // bs)[:, None], axis=1)[:, 0]
                off = lengths % bs
                out = []
                for pos, is_paged in enumerate(paged):
                    if is_paged:
                        def scat(l, nl):
                            idx = lengths.reshape(
                                (1, bc) + (1,) * (nl.ndim - 2))
                            row = jnp.take_along_axis(nl, idx, axis=2)
                            return l.at[:, blk, off].set(
                                row[:, :, 0].astype(l.dtype), mode="drop")
                        out.append(jax.tree.map(
                            scat, pool[pos], new["layers"][pos]))
                    else:
                        out.append(jax.tree.map(
                            lambda l, nl: l.at[:, slot_ids].set(
                                nl.astype(l.dtype), mode="drop"),
                            pool[pos], new["layers"][pos]))
                return logits, tuple(out)

            self._cohort_cache[bc] = jax.jit(fn, donate_argnums=(5,))
        return self._cohort_cache[bc]

    def _stage(self, depth_scale: float = 1.0):
        """Synchronous fallback producer (``async_staging=False``): run the
        plan's frontend/projector stages inline for queued vlm requests,
        class by class.  A FULL class ring stalls *that class* — its
        requests keep their FIFO positions and retry next step — while
        later requests of other classes continue staging (per-class
        backpressure, never a bypass, never cross-class head-of-line
        blocking).  The battery knob gates classes exactly like the async
        hand-off: a class whose scaled depth is already met stages
        nothing this step (high-resolution classes shed first)."""
        if self.tabm is None:
            return
        table = self.tabm.admission_table(depth_scale)
        stalled: set = set()                   # classes FULL this pass
        for req in self.queue:
            if req.staged or req.vision_feats is None \
                    or req.share_of is not None:
                continue
            if req.slot_class in stalled:      # keep FIFO within the class
                continue
            ring, cap = table[req.slot_class]
            staged_now = ring.staged_ahead() if ring is not None else 0
            if cap < self.tabm.max_ahead(req.slot_class) \
                    and staged_now >= cap:
                # the *throttle* binds (scaled depth met) — skip the class
                # without touching the ring; plain FULL still goes through
                # produce below so backpressure stalls are observable
                stalled.add(req.slot_class)
                continue
            if not req.stage_submitted:    # one stage_start per request,
                req.stage_submitted = True  # even across FULL-stall retries
                self._trace_event("stage_start", req.rid)
            try:
                slot = self.plan.produce(
                    {"vision_feats": jnp.asarray(req.vision_feats)},
                    slot_class=req.slot_class)
            except Exception as e:             # surface on the owning request
                req.error = e
                req._staged_ev.set()            # marks staged
                self._trace_event("stage_error", req.rid)
                continue
            if slot is None:                   # class FULL -> stall the class
                stalled.add(req.slot_class)
                continue
            req.tabm_slot = slot
            req._staged_ev.set()           # marks staged
            self._trace_event("stage_commit", req.rid)

    def _class_stage_batch(self, slot_class: Optional[str]) -> int:
        """The effective staging microbatch for one class *right now*:
        the engine override, else min(arch ``max_stage_batch``, battery
        ``Knobs.max_stage_batch``) — THROTTLED shrinks the batch before
        any depth sheds — clamped to the class ring's capacity (a slab
        larger than the ring could never commit)."""
        if self._stage_batch_override is not None:
            cap = self._stage_batch_override
        else:
            _, knobs, _ = self.executor.current()
            cap = min(self.cfg.max_stage_batch, knobs.max_stage_batch)
        if self.tabm is not None and slot_class is not None:
            cap = min(cap, self.tabm.classes[slot_class].n_slots)
        return max(1, cap)

    def _feed_staging(self, knobs=None):
        """Admission's producer hand-off, charged per class *and per
        microbatch*: each round, every class collects its eligible queued
        requests — up to its staged-ahead depth budget
        (core/scheduler.class_staging_budgets), itself capped at one
        staging microbatch — and hands them to its class thread as ONE
        list, which the worker commits as one strided slab
        (``produce_many``).  The depth cap is each class's own
        ``max_ahead`` — by default the class ring's capacity, so the
        hand-off queue is bounded by the ring and shutdown cancellation
        stays cheap — scaled by the battery knob ``class_depth_scale``
        (high-resolution classes shrink first; the microbatch shrinks
        before that).  A class with no budget (FULL, throttled, or
        saturated hand-off) is simply skipped; later requests of other
        classes still hand off — the class isolation the single FIFO cap
        could not give."""
        if knobs is None:
            _, knobs, _ = self.executor.current()
        # the battery knobs are constant within one admission round: read
        # them once (the caller's copy), clamp per class against the
        # static ring capacities — never re-poll the executor per request
        if self._stage_batch_override is not None:
            global_cap = max(1, self._stage_batch_override)
        else:
            global_cap = max(1, min(self.cfg.max_stage_batch,
                                    knobs.max_stage_batch))
        budgets = class_staging_budgets(
            self.tabm, self._worker.in_flight_by_class(),
            knobs.class_depth_scale, stage_batch=global_cap)
        groups: Dict[str, List[Request]] = {}
        for req in self.queue:
            if req.staged or req.stage_submitted \
                    or req.vision_feats is None or req.share_of is not None:
                continue
            # budgets are already microbatch- and ring-capacity-capped
            if len(groups.get(req.slot_class, ())) >= \
                    budgets.get(req.slot_class, 0):
                continue                       # class exhausted; others go on
            req.stage_submitted = True
            groups.setdefault(req.slot_class, []).append(req)
        for batch in groups.values():          # one hand-off = one microbatch
            self._worker.submit(batch)

    def _ring_of(self, req: Request):
        """The class ring holding this request's staged embeds."""
        return self.tabm.ring(req.slot_class)

    def _bind_vision(self, req: Request) -> Optional[jnp.ndarray]:
        """Consumer half: per-slot ready wait on the request's class ring,
        then bind that ring's oldest READY slot as the prefill's vision
        input.  FIFO commit order == FIFO admission order *within a
        class*, so the bound slot is this request's; the seqlock
        generation is captured so release can assert the zero-copy view
        stayed valid across the prefill."""
        if req.tabm_slot is None:
            return None
        if req.share_of is not None:
            # refcounted read view of the owner's consumed slot — the
            # slab was staged once, this request never touched the ring
            got = self.plan.shared_view(req.tabm_slot, req._tabm_gen,
                                        slot_class=req.slot_class)
            if got is None:
                raise TABMError(
                    f"shared slot {req.tabm_slot} ({req.slot_class}) "
                    f"recycled before request {req.rid} bound its view")
            view, n = got
            if self.capture_slab:
                req.slab = np.array(view[:n])      # host copy, trimmed
            return view[None, :n]
        # normally immediate — admission only runs once `staged` is set,
        # which the worker sets strictly after commit — but this is the
        # formal consumer-side gate (and the blocking point if admission
        # ever runs ahead of the staged flag)
        if not self.plan.wait_ready(req.tabm_slot, timeout=30.0,
                                    slot_class=req.slot_class):
            raise TABMError(
                f"slot {req.tabm_slot} ({req.slot_class}) did not become "
                f"READY (aborted, ring closed, or timed out)")
        got = self.plan.consume(slot_class=req.slot_class)
        if got is None or got[0] != req.tabm_slot:
            # enforced with a real raise (not assert): this is the
            # per-class FIFO contract the zero-copy hand-off stands on
            raise TABMError(
                f"consume returned {got and got[0]}, expected request "
                f"{req.rid}'s slot {req.tabm_slot} of class "
                f"{req.slot_class} (per-class FIFO order broken)")
        slot, view, n = got
        req._tabm_gen = self._ring_of(req).slot_generation(slot)
        self._grant_shares(req, slot)
        if self.capture_slab:
            req.slab = np.array(view[:n])          # host copy, trimmed
        return view[None, :n]

    def _grant_shares(self, owner: Request, slot: int):
        """The owner's slab just got consumed: grant every waiting twin
        a refcounted view of the same slot (tabm.addref) so they admit
        without ever staging.  A twin the addref misses (slot already
        on its way out) falls back to staging privately."""
        if owner._share_key is not None and \
                self._stage_keys.get(owner._share_key) is owner:
            self._stage_keys.pop(owner._share_key)
        for s in owner.sharers:
            if (s.error is not None or s.finish_t is not None
                    or s.share_of is not owner):
                continue
            if self.plan.addref(slot, owner._tabm_gen,
                                slot_class=owner.slot_class):
                s.tabm_slot = slot
                s._tabm_gen = owner._tabm_gen
                s._staged_ev.set()         # admissible, no staging needed
                self._trace_event("stage_share", s.rid)
            else:
                s.share_of = None          # stage privately instead
        owner.sharers = []

    def _unshare(self, req: Request):
        """A request leaves the dedup registry (failed or shut down):
        sharers not yet granted a view go back to staging privately."""
        if req._share_key is not None and \
                self._stage_keys.get(req._share_key) is req:
            self._stage_keys.pop(req._share_key)
        for s in req.sharers:
            if s.share_of is req and s.tabm_slot is None:
                s.share_of = None
        req.sharers = []

    def _fail(self, req: Request):
        self._unshare(req)
        req.finish_t = req.finish_t or time.time()
        self.stats.failed += 1
        self._trace_event("failed", req.rid)
        self.done.append(req)

    def _apply_backend_knobs(self, knobs):
        """The PowerPolicy re-lowering hook: demote the static-shape
        (encoder-side) bricks to the knob's cheaper backend under deep
        THROTTLED, and restore the compiled substrate when charge
        recovers.  plan.relower swaps each step atomically, so the
        staging thread's in-flight produce is never torn."""
        target = knobs.backend_demotion
        if target == self._demoted_to:
            return
        for s in list(self.plan.steps):
            if not s.brick.static_shape:
                continue
            self.plan.relower(
                s.brick.name,
                target if target is not None
                else self._lowered_backends[s.brick.name])
        self._demoted_to = target
        self._trace_event(f"relower:{target or 'restore'}", -1)

    def _group_key(self, req: Request):
        """Bucket-match key for grouped prefill: requests sharing a
        prompt bucket and an identical vision spec (class + staged token
        count — one slab shape, one compiled prefill signature) may
        prefill as one batch.  Text-only requests group by bucket."""
        bucket = bucket_length(len(req.tokens), buckets=self._buckets())
        vis = None
        if self.tabm is not None and req.vision_feats is not None:
            vis = (req.slot_class,
                   int(np.asarray(req.vision_feats).shape[1]))
        return (bucket, vis)

    def _admissible(self, req: Request) -> bool:
        return not (self.tabm is not None and req.vision_feats is not None
                    and not req.staged)

    def _block_need(self, req: Request) -> int:
        """KV blocks this request's lifetime needs: the block-aligned
        prompt bucket (the prefill writes that many), grown to cover
        max_new_tokens of decode, capped at a full slot's worth."""
        bs = self.slots.block_size
        bucket = bucket_length(len(req.tokens), buckets=self._buckets())
        aligned = -(-bucket // bs) * bs
        want = max(aligned,
                   min(self.max_len, len(req.tokens) + req.max_new_tokens))
        return min(self.slots.blocks_per_slot, -(-want // bs))

    def _collect_group(self, i: int, max_n: int,
                       kv_budget: Optional[int] = None) -> List[Request]:
        """Pop the maximal run of *consecutive* bucket-matched admissible
        requests starting at queue position i (consecutive, so per-class
        ring-FIFO consume order and overall admission FIFO both hold).
        The run also stops where its cumulative KV-block need would
        outrun the free pool (or the class's battery-scaled block
        budget) — the caller admits what fits, the rest keeps FIFO."""
        key = self._group_key(self.queue[i])
        blocks_left = self.slots.free_block_count
        if kv_budget is not None:
            blocks_left = min(blocks_left, kv_budget)
        blocks_left -= self._block_need(self.queue[i])
        j = i + 1
        while j < len(self.queue) and j - i < max_n:
            nxt = self.queue[j]
            if (nxt.error is not None or not self._admissible(nxt)
                    or self._group_key(nxt) != key):
                break
            need = self._block_need(nxt)
            if need > blocks_left:
                break
            blocks_left -= need
            j += 1
        group = self.queue[i:j]
        del self.queue[i:j]
        return group

    def _admit_group(self, group: List[Request]):
        """One batch-B prefill call for a bucket-matched group: bind each
        request's staged slab view (class-FIFO consume order == group
        order), run the compiled bucket prefill once over the stacked
        batch, then write all B prefilled caches into B KV slots in a
        single strided ``insert_many``.  On any failure the whole group
        fails: every KV slot and every consumed ring slot is released —
        nothing leaks, the engine keeps serving.  Unlike the staging
        side there is no one-by-one retry: the ring slots were already
        consumed, so releasing them destroys the staged vision (a retry
        would need a full restage), and a prefill-time failure is
        batch-level in practice — the per-request inputs (bucketed int
        tokens, validated slab views) cannot individually fail a
        compiled call."""
        t0 = time.perf_counter()
        taken: List[int] = []
        try:
            for req in group:
                slot = self.slots.take_slot()
                if slot is None:               # sized by the caller; defensive
                    raise RuntimeError("KV slots exhausted mid-group")
                taken.append(slot)
                # the lifetime block grant, charged to the class — the
                # caller (_collect_group) sized the group to fit
                self.slots.grant_blocks(slot, self._block_need(req),
                                        slot_class=req.slot_class)
            B = len(group)
            bucket = self._group_key(group[0])[0]
            padded = np.zeros((B, bucket), np.int32)
            lens = np.zeros((B,), np.int32)
            for b, req in enumerate(group):
                prompt = np.asarray(req.tokens, np.int32)
                padded[b, :len(prompt)] = prompt   # right-pad into the bucket
                lens[b] = len(prompt)
            views = [v for v in (self._bind_vision(r) for r in group)
                     if v is not None]
            vision = jnp.concatenate(views, axis=0) if views else None
            logits, cache = self._prefill_fn(bucket)(
                self.params, jnp.asarray(padded), vision,
                jnp.asarray(lens))
            for req in group:                  # prefill consumed the views
                if req.tabm_slot is not None:
                    if not self._ring_of(req).view_valid(req.tabm_slot,
                                                         req._tabm_gen):
                        raise TABMError(
                            f"slot {req.tabm_slot} recycled under request "
                            f"{req.rid}'s zero-copy view (seqlock "
                            f"violation)")
                    self.plan.release(req.tabm_slot,
                                      slot_class=req.slot_class)
        except Exception as e:
            # neither a KV slot nor a ring slot may leak, and every
            # request must still be accounted for (e.g. the ring closed
            # under a concurrent shutdown mid-admission): fail the group,
            # keep serving
            for req in group:
                if req.tabm_slot is None:
                    pass
                elif (req._tabm_gen is not None
                        and self._ring_of(req).view_valid(req.tabm_slot,
                                                          req._tabm_gen)):
                    self.plan.release(req.tabm_slot,   # consumed, unreleased
                                      slot_class=req.slot_class)
                elif req._tabm_gen is None:
                    # staged but never consumed (a bind earlier in the
                    # group raised): its committed slot is the class
                    # ring's oldest READY — pull it out and release, or
                    # an ownerless slot would wedge every later same-
                    # class consume (per-class FIFO).  A closed ring
                    # (consume -> None) is drained at shutdown instead.
                    got = self.plan.consume(slot_class=req.slot_class)
                    if got is not None and got[0] == req.tabm_slot:
                        self.plan.release(got[0], slot_class=req.slot_class)
                req.error = e
                self._fail(req)
            for slot in taken:
                self.slots.release(slot)
            return
        self.slots.insert_many(taken, cache, [int(n) for n in lens])
        for b, (slot, req) in enumerate(zip(taken, group)):
            req.slot = slot
            self.live[slot] = req
            self.stats.prefills += 1
            self._trace_event("prefill", req.rid)
            # first token from this request's row of the prefill logits
            tok = self._pick(logits[b:b + 1], req)
            req.out_tokens.append(int(tok[0]))
            req.first_token_t = time.time()
        if len(group) > 1:                     # the acceptance evidence
            self._trace_event("prefill_batch", len(group))
        # measured prefill span: ends past insert_many and the first-token
        # reads, so device work is complete — true wall time of the group
        self.probe.record("decoder", "prefill", time.perf_counter() - t0,
                          tokens=int(lens.sum()))

    def _admit(self):
        state, knobs, _ = self.executor.current()
        self._apply_backend_knobs(knobs)
        power_ok = (knobs.admission_rate > 0
                    or state is PowerState.UNCONSTRAINED)
        if power_ok:
            if self._worker is not None:
                # producer threads run ahead, charged per class and scaled
                # by the battery knob (batch shrinks first, then high-res
                # classes shed depth)
                self._feed_staging(knobs)
            else:
                # sync fallback: inline, same per-class battery gating —
                # the equivalence oracle throttles like the async path
                self._stage(knobs.class_depth_scale)
        budget = min(len(self.slots.free), knobs.max_batch)
        if not power_ok:
            budget = 0
        # per-class KV *block* budgets, battery-scaled exactly like the
        # staging depth (shed_scales): under THROTTLED the hi-res
        # classes' share of the paged pool shrinks first, so expensive
        # long-context grants are shed while thumbnails keep admitting
        kv_budgets = None
        if self.tabm is not None:
            kv_budgets = kv_block_budgets(
                self.tabm, self.slots.n_blocks, self.slots.used_blocks,
                knobs.class_kv_scale,
                energy_pressure=self._kv_energy_pressure())
        # cross-class aging: classes of requests that have waited out
        # aging_steps admission rounds while skipped (class stalled or
        # slow); each holds one KV-slot reservation that newer requests
        # of OTHER classes may not take — a thumbnail flood can no longer
        # absorb every freed slot while a hi-res head waits.  A class the
        # battery policy deliberately shed (depth gated to zero) earns no
        # reservation: fairness must not undo the power policy's choice
        # to keep cheap classes flowing.
        shed: set = set()
        if self.tabm is not None:
            shed = {name for name, (_, cap) in self.tabm.admission_table(
                knobs.class_depth_scale).items() if cap <= 0}
        # ONE reservation per aged class, not per aged request: a class
        # admits FIFO, so one held slot guarantees its aged head makes
        # progress, while a deeply-backlogged class can never reserve the
        # whole KV pool away from everyone else
        aged_classes: set = set()
        # classes with a request skipped earlier in THIS pass: later
        # classmates must be skipped too, even if their staged flag reads
        # True by now — admission samples `staged` at different times per
        # request, and admitting a younger classmate whose older sibling
        # was mid-staging a moment ago would consume the sibling's ring
        # slot (per-class FIFO violation)
        stalled: set = set()
        i = 0
        while i < len(self.queue) and budget > 0:
            req = self.queue[i]
            if not self._admissible(req) or (
                    req.vision_feats is not None
                    and req.slot_class in stalled):
                # this request's class producer is stalled (FULL ring,
                # throttled depth, or an earlier classmate this pass) —
                # skip it, keep its FIFO position, and let staged
                # requests of *other* classes admit behind it: a stalled
                # high-res class never blocks thumbnails
                stalled.add(req.slot_class)
                req.aging += 1                 # a real skip, not residency
                if req.aging >= self.aging_steps \
                        and req.slot_class not in shed:
                    aged_classes.add(req.slot_class)
                i += 1
                continue
            # error is read only after the staged flag: the worker stores
            # error before staged=True, so a failed request can never slip
            # through as staged-with-no-slot and prefill without vision
            if req.error is not None:          # staging failed: finish failed
                self.queue.pop(i)
                self._fail(req)
                continue
            # KV slots reserved by aged classes other than this request's
            # stay free for them (their class may stage any round now)
            reserved = sum(1 for c in aged_classes if c != req.slot_class)
            avail = len(self.slots.free) - reserved
            if avail <= 0:
                if req.vision_feats is not None:
                    stalled.add(req.slot_class)    # keep class FIFO
                req.aging += 1
                i += 1                         # reserved: skip, keep position
                continue
            # paged-KV admission: the head's lifetime block need must fit
            # the class's battery-scaled share (hi-res classes shed
            # first) AND the free pool; a gated head keeps its FIFO
            # position — blocks freed by any finishing request are
            # grantable the very next round (continuous batching)
            need = self._block_need(req)
            kv_cap = (kv_budgets.get(req.slot_class)
                      if kv_budgets is not None
                      and req.vision_feats is not None else None)
            if kv_cap is not None and need > kv_cap:
                stalled.add(req.slot_class)    # keep class FIFO
                req.aging += 1
                self._trace_event("kv_gated", req.rid)
                i += 1
                continue
            if need > self.slots.free_block_count:
                if req.vision_feats is not None:
                    stalled.add(req.slot_class)
                req.aging += 1
                i += 1
                continue
            group = self._collect_group(i, min(budget, avail),
                                        kv_budget=kv_cap)
            budget -= len(group)
            self._admit_group(group)
            # queue shrank at position i: the next candidate is at i again
        if not self.live and self.queue:
            waiter = None
            if self._worker is not None:
                # idle consumer waiting on the producer: park briefly on
                # the first pending staged event instead of hot-spinning
                # the loop (only stage_submitted requests qualify — the
                # worker WILL stage those; gated heads won't set it)
                waiter = next((r for r in self.queue
                               if r.error is None and r.stage_submitted
                               and not r.staged), None)
            if waiter is not None:
                waiter._staged_ev.wait(0.05)
            elif not any(r.staged and r.error is None for r in self.queue):
                # nothing live, nothing admissible, nothing being staged —
                # every queued request is power- or class-depth-gated.
                # Breathe instead of hot-spinning the step loop at full
                # CPU (which would burn the very battery the throttle is
                # conserving) until charge recovers.
                time.sleep(0.005)

    def _pick(self, logits, req: Request):
        if req.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return sample(logits, k, temperature=req.temperature)

    def _buckets(self):
        caps = [b for b in (128, 256, 512, 1024, 2048, 4096)
                if b <= self.max_len - 1]
        return tuple(caps) or (self.max_len - 1,)

    def step(self):
        self._admit()
        if not self.live:
            self.stats.steps += 1
            return
        # cohort decode: every in-flight request rides ONE batched jit
        # step, padded to a power-of-two cohort bucket (sentinel rows:
        # gathers fill, scatters drop).  Rows are independent, so a
        # request admitted or retired between steps never perturbs the
        # others' tokens — mid-flight continuous batching
        cohort = self._cohort_slots()
        bc = self._cohort_bucket(len(cohort))
        tokens = np.zeros((bc, 1), np.int32)
        lengths = np.zeros((bc,), np.int32)
        slot_ids = np.full((bc,), self.slots.n_slots, np.int32)
        tables = np.full((bc, self.slots.blocks_per_slot),
                         self.slots.n_blocks, np.int32)
        tables[:len(cohort)] = self.slots.gather_tables(cohort)
        for b, slot in enumerate(cohort):
            req = self.live[slot]
            tokens[b, 0] = req.out_tokens[-1]
            lengths[b] = self.slots.lengths[slot]
            slot_ids[b] = slot
        t0 = time.perf_counter()
        logits, self.slots.pool = self._cohort_fn(bc)(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(slot_ids), jnp.asarray(tables), self.slots.pool)
        self.stats.steps += 1
        self._trace_event("decode_step", self.stats.steps)
        self._trace_event("decode_cohort", len(cohort))

        finished = []
        for b, slot in enumerate(cohort):
            req = self.live[slot]
            tok = self._pick(logits[b:b + 1], req)
            # deliberate per-token sampling read: the sampled id feeds the
            # next step's host-side token buffer and EOS check
            t = int(tok[0])  # replint: disable=host-sync
            req.out_tokens.append(t)
            self.slots.bump(slot)
            self.stats.decoded_tokens += 1
            over_len = self.slots.lengths[slot] + 1 >= self.max_len
            if (t == EOS_ID or len(req.out_tokens) >= req.max_new_tokens
                    or over_len):
                req.finish_t = time.time()
                finished.append(slot)
        # measured decode span for the telemetry ledger: the per-token
        # sampling reads above already synced, so this is true wall time
        # of one cohort step (host clocks only — replint-clean)
        self.probe.record("decoder", "decode", time.perf_counter() - t0,
                          tokens=len(cohort))
        for slot in finished:
            req = self.live.pop(slot)
            self.done.append(req)
            # the retiring request's KV blocks return to the free pool
            # NOW — grantable to the next admission round, mid-flight
            self.slots.release(slot)
            self.stats.finished += 1
            self._trace_event("finish", req.rid)

    # -- disaggregated fleets (serving/disagg.py) ----------------------------
    def prefill_step(self) -> List[Request]:
        """One admission round without decoding — the prefill fleet's
        step: staging hand-off + grouped batched prefill exactly as
        :meth:`step` would run them, but the newly admitted requests
        (prefilled cache landed, first token picked from the prefill
        logits) are *returned* instead of decoded, ready for
        :meth:`export_remote`.  Requests whose staging failed land in
        ``done`` as usual."""
        before = set(self.live)
        self._admit()
        self.stats.steps += 1
        return [self.live[s] for s in sorted(set(self.live) - before)]

    def export_remote(self, req: Request):
        """Hand a just-prefilled request off the engine as a
        :class:`~repro.core.transport.RemotePrefill`: export the
        *written* KV blocks (the block-aligned prompt bucket — never the
        whole grant, never a whole lane), pop the request from the live
        set, and release its slot and blocks — this engine is done with
        it; the decode fleet owns it now.  Must run before any decode
        step touches the slot (the prefill fleet never decodes, so the
        per-slot length still equals the prompt length)."""
        from repro.core.transport import RemotePrefill
        slot = req.slot
        if slot is None or self.live.get(slot) is not req:
            raise RuntimeError(
                f"request {req.rid} is not live on this engine")
        bs = self.slots.block_size
        bucket = bucket_length(len(req.tokens), buckets=self._buckets())
        nb_written = -(-bucket // bs)
        granted = len(self.slots.block_tables[slot])
        rp = RemotePrefill(
            rid=req.rid,
            prompt=np.asarray(req.tokens, np.int32),
            first_token=int(req.out_tokens[0]),
            max_new_tokens=int(req.max_new_tokens),
            blocks_granted=granted,
            paged=self.slots.paged,
            kv=self.slots.export_blocks(slot, nb_written),
            slot_class=req.slot_class,
            slab=req.slab,
            prompt_len=int(self.slots.lengths[slot]))
        del self.live[slot]
        self.slots.release(slot)
        req.slot = None
        self._trace_event("export_remote", req.rid)
        return rp

    def admit_remote(self, msg) -> bool:
        """Admit a :class:`~repro.core.transport.RemotePrefill` streamed
        from a prefill fleet straight into the paged pool: take a slot,
        grant the request's full block count, land the shipped written
        blocks (:meth:`PagedKVCache.import_blocks`), and enter the
        request live with its first token — from here :meth:`step`
        decodes it exactly like a locally prefilled request (same cohort
        step, same EOS/max-new semantics: bit-identical tokens).

        Returns False — admit nothing, change nothing — when no slot or
        too few free blocks are available; the caller decodes a step to
        retire capacity and retries (continuous batching across the
        fleet boundary)."""
        if self._closed:
            raise EngineClosed("engine already shut down")
        if tuple(msg.paged) != tuple(self.slots.paged):
            raise RuntimeError(
                f"remote prefill paged layout {tuple(msg.paged)} does not "
                f"match this pool's {tuple(self.slots.paged)} (fleet "
                f"config mismatch)")
        if int(msg.blocks_granted) > self.slots.free_block_count:
            return False
        slot = self.slots.take_slot()
        if slot is None:
            return False
        self.slots.grant_blocks(slot, int(msg.blocks_granted),
                                slot_class=msg.slot_class)
        self.slots.import_blocks(slot, msg.kv)
        self.slots.lengths[slot] = int(msg.prompt_len)
        req = Request(rid=int(msg.rid),
                      tokens=np.asarray(msg.prompt, np.int32),
                      max_new_tokens=int(msg.max_new_tokens),
                      slot_class=msg.slot_class)
        req.slot = slot
        req.out_tokens.append(int(msg.first_token))
        req.first_token_t = time.time()
        req._staged_ev.set()
        self.live[slot] = req
        self.stats.prefills += 1
        self._trace_event("admit_remote", req.rid)
        return True

    # -- reporting / telemetry ----------------------------------------------
    def memory_bytes(self) -> Dict[str, int]:
        from repro.core.quantize import tree_bytes
        return {"weights": tree_bytes(self.params),
                "kv_pool": self.slots.nbytes,
                "tabm": self.tabm.nbytes if self.tabm else 0}

    def _kv_energy_pressure(self) -> float:
        """Measured-over-modeled decode J/token ratio for kv_block_budgets
        (cached: one scheduler lookup, not one per admission round).
        1.0 — i.e. no tightening — without a calibration table, without
        an energy observation, or when the plan carries no accelerator
        identities to price the model against."""
        if self.calibration is None:
            return 1.0
        if self._kv_pressure is None:
            from repro.core.scheduler import brick_cost
            press = 1.0
            for s in self.plan.steps:
                if s.brick.kind == "decoder" and s.accel is not None:
                    modeled = brick_cost(s.brick, s.accel, 1)
                    press = self.calibration.energy_pressure(
                        s.brick.name, s.accel.profile.name,
                        modeled.energy_j)
                    break
            self._kv_pressure = press
        return self._kv_pressure

    def measured_ledger(self) -> Ledger:
        """The dynamic (probe-fed) telemetry ledger of this engine run:
        per-brick staging spans recorded by the plan plus the engine's
        prefill/decode spans, folded per (brick, phase)."""
        return self.probe.to_ledger(meta={"collector": "serving-engine"})

    def measured_calibration(self, prior: int = 4) -> CostCalibration:
        """A scheduler-consumable calibration table from this run's
        measured ledger — the feedback loop closed in one call:
        ``schedule(graph, accels, n, calibration=eng.measured_calibration())``
        prices the next placement from what this engine observed."""
        return CostCalibration.from_ledger(self.measured_ledger(),
                                           prior=prior)
