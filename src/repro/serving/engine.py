"""Continuous-batching serving engine — a two-stage async pipeline over a
class-partitioned TABM pool:

    producer threads (StagingWorker,         consumer (step loop)
    one per slot class)                      ---------------------
    ------------------------------           plan.consume (per-slot,
    vision encode -> projector ->            per-class ready wait) ->
    plan.produce -> class ring commit        prefill -> batched decode
    (blocks on class FULL = per-class
    backpressure)

The vision path is not reimplemented here: the engine compiles the
BrickGraph into an :class:`repro.core.plan.ExecutionPlan` and drives the
plan's TABM edge as a real producer/consumer pair —

* **slot classes**: every vision request is classified at submit (image
  count × resolution bucket, from the arch config — core/slot_classes)
  and staged through its own class-sized ring of the
  :class:`~repro.core.tabm.SlotClassPool`.  A 1-image thumbnail no longer
  pads into a 4-image full-resolution slab, and a FULL high-resolution
  ring stalls only that class's producer thread — thumbnails keep
  staging and admitting (class isolation).
* **producer** (:class:`StagingWorker`): one thread per slot class pulls
  admitted requests from its class's hand-off queue and runs
  ``plan.produce`` (vision encode -> projector -> ring commit) *off the
  step loop*, so request k+1's vision encode overlaps request k's decode
  — the paper's TABM smoothing made actually concurrent.  A FULL class
  ring blocks that class's thread inside ``acquire_write`` (backpressure,
  never a silent bypass); admission charges each request's class against
  its own staged-ahead depth budget
  (core/scheduler.class_staging_budgets), scaled by the battery knob
  ``class_depth_scale`` — THROTTLED shrinks the high-resolution classes'
  depth first, so expensive staging is the first load shed.
* **consumer** (``_bind_vision``): at admission the request's committed
  slot is bound as the prefill's vision input after a per-slot ready wait
  on its class ring (``wait_ready``; zero-copy via donation, see
  core/tabm.py) and released once the prefill has consumed it —
  validated by the ring's seqlock generation.

Lifecycle: ``shutdown()`` (or the context manager) stops the worker —
closing the ring wakes a producer stalled on FULL — joins the thread,
drains staged-but-unconsumed slots back to EMPTY, and resolves every
outstanding request (queued or live mid-decode) as failed with
:class:`EngineClosed`; an engine dropped without shutdown is reaped by a
finalizer so the producer thread never leaks.  A staging error (e.g. the projector
raising) aborts the ring write inside ``plan.produce`` and surfaces on the
originating request's ``error`` field; the request finishes failed instead
of wedging the pipeline.  ``async_staging=False`` keeps the old inline
single-threaded staging — bit-identical tokens, used as the equivalence
oracle in tests/test_engine_async.py.

Other paper mechanisms wired in:
* **module-level offloading** — the same plan compiles against submesh
  accelerators (core/scheduler.make_virtual_accelerators) for the pod-mode
  NPU/GPU split; see launch/serve_disagg.py.
* **battery-aware execution** — admission/batch knobs come from the
  three-state policy; CRITICAL switches to cascade one-shot inference.
* **static shapes** — prompts bucket-pad (kv_cache.bucket_length): one
  compiled prefill per bucket, one compiled decode step, never recompiled.

Metrics mirror the paper's evaluation: tokens/s, end-to-end latency
(submit -> finish), modeled energy, memory (pool + weights).  ``trace``
records the producer/consumer interleaving ((event, rid, t) tuples) —
the overlap evidence the async tests assert on.
"""
from __future__ import annotations

import queue
import threading
import time
import weakref
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bricks import decompose
from repro.core.plan import compile_plan
from repro.core.power import BatteryAwareExecutor, PMU, PowerState
from repro.core.scheduler import class_staging_budgets
from repro.core.tabm import SlotClassPool, TABMError
from repro.models import model as M
from repro.serving.kv_cache import SlotCache, bucket_length
from repro.serving.sampling import sample

EOS_ID = 1


class EngineClosed(RuntimeError):
    """The engine shut down before this request could complete."""


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                     # prompt token ids
    vision_feats: Optional[np.ndarray] = None
    n_images: int = 1                      # images the vision feats cover
    max_new_tokens: int = 32
    temperature: float = 0.0
    submit_t: float = field(default_factory=time.time)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    out_tokens: List[int] = field(default_factory=list)
    slot: Optional[int] = None                 # KV-cache slot once admitted
    tabm_slot: Optional[int] = None            # class-ring slot once staged
    slot_class: Optional[str] = None           # TABM class, set at submit
    stage_submitted: bool = False              # handed to the StagingWorker
    error: Optional[BaseException] = None      # staging/engine failure
    _tabm_gen: Optional[int] = None            # seqlock gen at consume
    _staged_ev: threading.Event = field(default_factory=threading.Event,
                                        repr=False)

    @property
    def staged(self) -> bool:
        """Producer half already ran (committed or failed).  Derived from
        the event so the admission check and the idle park can never
        desynchronize."""
        return self._staged_ev.is_set()

    @property
    def e2e_latency(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


@dataclass
class EngineStats:
    decoded_tokens: int = 0
    prefills: int = 0
    steps: int = 0
    finished: int = 0
    failed: int = 0
    start_t: float = field(default_factory=time.time)

    def tokens_per_s(self) -> float:
        dt = time.time() - self.start_t
        return self.decoded_tokens / dt if dt > 0 else 0.0


_STOP = object()


class StagingWorker:
    """The pipeline's producer stage: one thread *per slot class*, each
    draining its class's hand-off queue through ``plan.produce``.

    The worker owns the ring-write side of the TABM contract, per class:
    a class thread blocks *inside* ``acquire_write`` on its own FULL ring
    (so backpressure stalls exactly that class's producer — never the
    decode loop, never another class's staging), aborts the slot if a
    brick raises, and attaches any failure to the originating request
    before flagging it staged.  ``shutdown`` closes the pool first —
    waking every stalled class thread — then joins them all; requests
    still queued at that point are cancelled with :class:`EngineClosed`.

    ``classes=(None,)`` (the default) degenerates to the single-ring,
    single-thread pipeline."""

    def __init__(self, plan, trace, classes=(None,)):
        self.plan = plan
        self._trace = trace                     # (event, rid) -> None
        self._classes = tuple(classes)
        self._qs: Dict[Optional[str], "queue.Queue"] = {
            c: queue.Queue() for c in self._classes}
        self._stop = threading.Event()
        self._lock = threading.Lock()
        # handed over, not yet staged — charged per class at hand-off
        self._in_flight: Dict[Optional[str], int] = {
            c: 0 for c in self._classes}
        self._threads: Dict[Optional[str], threading.Thread] = {}

    def in_flight(self, slot_class: Optional[str] = None) -> int:
        with self._lock:
            return self._in_flight[slot_class]

    def in_flight_by_class(self) -> Dict[Optional[str], int]:
        with self._lock:
            return dict(self._in_flight)

    def start(self, slot_class: Optional[str] = None):
        if slot_class not in self._threads:
            name = "tabm-staging" if slot_class is None \
                else f"tabm-staging[{slot_class}]"
            t = threading.Thread(target=self._run, args=(slot_class,),
                                 name=name, daemon=True)
            self._threads[slot_class] = t
            t.start()

    def submit(self, req: Request):
        if self._stop.is_set():
            raise EngineClosed("staging worker already shut down")
        cls = req.slot_class
        if cls not in self._qs:
            raise EngineClosed(f"no staging queue for slot class {cls!r}")
        self.start(cls)
        with self._lock:
            self._in_flight[cls] += 1
        self._qs[cls].put(req)

    def _run(self, slot_class: Optional[str]):
        q = self._qs[slot_class]
        while True:
            item = q.get()
            if item is _STOP:
                break
            req: Request = item
            try:
                if self._stop.is_set():
                    raise EngineClosed("engine shut down before staging")
                self._trace("stage_start", req.rid)
                slot = self.plan.produce(
                    {"vision_feats": jnp.asarray(req.vision_feats)},
                    slot_class=slot_class, block=True)
                if slot is None:                # ring closed mid-stall
                    raise EngineClosed("ring closed while staging stalled")
                req.tabm_slot = slot
                self._trace("stage_commit", req.rid)
            except BaseException as e:          # propagate to the request
                req.error = e
                self._trace("stage_error", req.rid)
            finally:
                with self._lock:
                    self._in_flight[slot_class] -= 1
                req._staged_ev.set()            # marks staged

    def shutdown(self, timeout: float = 10.0) -> bool:
        """Stop accepting, cancel in-flight staging, join every class
        thread.  Returns True when all threads are fully dead (no daemon
        leak)."""
        self._stop.set()
        if self.plan.tabm is not None:
            self.plan.tabm.close()        # wakes every class's FULL stall
        threads = list(self._threads.items())
        for cls, _ in threads:
            self._qs[cls].put(_STOP)
        deadline = time.monotonic() + timeout
        alive = False
        for _, t in threads:
            t.join(max(0.0, deadline - time.monotonic()))
            alive = alive or t.is_alive()
        return not alive


class ServingEngine:
    """Decoder-only (dense/moe/ssm/hybrid/vlm) continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 2048, executor: Optional[
                     BatteryAwareExecutor] = None,
                 rng_seed: int = 0, async_staging: bool = True,
                 placement=None, accels=None, backend=None):
        assert not cfg.encdec, "engine serves decoder-only archs"
        self.cfg = cfg
        self.params = params
        self.slots = SlotCache(cfg, n_slots, max_len)
        self.max_len = max_len
        self.executor = executor or BatteryAwareExecutor(PMU())
        self.queue: List[Request] = []
        self.live: Dict[int, Request] = {}      # slot -> request
        self.done: List[Request] = []
        self.stats = EngineStats()
        self.key = jax.random.PRNGKey(rng_seed)
        # producer/consumer interleaving evidence: (event, rid, t); bounded
        # so a long-running server doesn't grow it without limit
        self.trace: "deque[tuple]" = deque(maxlen=4096)
        # class-partitioned TABM pool between encoder and decoder bricks
        # (vlm archs): one class-sized ring per image-count x resolution
        # bucket (core/slot_classes), so a thumbnail request neither pads
        # into nor queues behind a multi-image full-resolution slab
        self.tabm = SlotClassPool.from_config(
            cfg, dim=cfg.d_model,
            slots_per_class=max(2, n_slots // 2)) if cfg.vlm else None
        # the one brick runtime: vision staging routes through the plan's
        # projector brick and TABM edge (no inline reimplementation).
        # placement/accels/backend pick the lowering substrate per brick
        # (core/backends) — the engine's step loop is identical on all of
        # them, the paper's "same graph, swappable compute unit"
        self.plan = compile_plan(decompose(cfg), params, tabm=self.tabm,
                                 placement=placement, accels=accels,
                                 backend=backend)
        # remembered so the battery policy's demotion can be undone when
        # charge recovers (plan.relower back to the compiled substrate)
        self._lowered_backends = {s.brick.name: s.backend
                                  for s in self.plan.steps}
        self._demoted_to: Optional[str] = None
        # producer stage: own thread unless the caller opts back into the
        # synchronous single-threaded pipeline (the equivalence oracle)
        self.async_staging = bool(async_staging and self.tabm is not None)
        self._worker = None
        if self.async_staging:
            # the worker must reference the engine only weakly (the live
            # thread roots the worker), or a dropped engine could never be
            # collected and its producer thread would leak; the finalizer
            # joins the thread for callers that skip shutdown()
            wself = weakref.ref(self)

            def _trace(event, rid):
                eng = wself()
                if eng is not None:
                    eng._trace_event(event, rid)

            self._worker = StagingWorker(self.plan, _trace,
                                         classes=tuple(self.tabm.names()))
            self._finalizer = weakref.finalize(
                self, StagingWorker.shutdown, self._worker, 1.0)
        self._closed = False

        self._prefill_cache: Dict[int, Any] = {}
        self._decode = jax.jit(
            lambda p, t, c: M.lm_decode_step(p, cfg, t, c),
            donate_argnums=(2,))

    # -- public api ----------------------------------------------------------
    def submit(self, req: Request):
        if self._closed:
            raise EngineClosed("engine already shut down")
        if self.tabm is None or req.vision_feats is None:
            req._staged_ev.set()           # text-only: nothing to commit
        elif req.slot_class is None:
            # classify from the vision spec (token count x image count) —
            # the request is charged against exactly this class's ring and
            # admission depth; an unservable spec fails fast, at submit
            req.slot_class = self.tabm.classify(
                int(np.asarray(req.vision_feats).shape[1]), req.n_images)
        else:
            self.tabm.ring(req.slot_class)     # unknown class fails fast
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or self.live) and self.stats.steps < max_steps:
            self.step()
        return self.done

    def shutdown(self, timeout: float = 10.0) -> bool:
        """Tear the pipeline down: stop+join the producer thread (a FULL
        stall is woken via ring close), drain staged-but-unconsumed slots
        back to EMPTY, and resolve every outstanding request — live
        mid-decode ones keep their partial tokens — as failed with
        EngineClosed.  Idempotent; returns True when no worker thread is
        left alive."""
        self._closed = True
        joined = True
        if self._worker is not None:
            joined = self._worker.shutdown(timeout)
            if joined:
                # torn down manually; a thread that outlived the join
                # timeout keeps its finalizer as the reaping safety net
                self._finalizer.detach()
        elif self.tabm is not None:
            self.tabm.close()
        if self.tabm is not None and joined:
            self.tabm.drain()              # READY/CONSUMED leftovers -> EMPTY
        for slot, req in list(self.live.items()):
            if req.error is None:
                req.error = EngineClosed("engine shut down mid-decode")
            self.slots.release(slot)
            self._fail(req)                # partial out_tokens are kept
        self.live.clear()
        while self.queue:
            req = self.queue.pop(0)
            if req.error is None:
                req.error = EngineClosed("engine shut down before admission")
            self._fail(req)
        return joined

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- internals -----------------------------------------------------------
    def _trace_event(self, event: str, rid: int):
        self.trace.append((event, rid, time.monotonic()))

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            cfg = self.cfg

            def fn(p, tokens, vision_embeds, last_idx):
                """Right-padded bucket prefill; logits read at the true
                prompt end (last_idx-1); pad positions stay in the cache
                but decode's per-slot length mask never attends them."""
                B, S = tokens.shape
                from repro.models.common import (default_mrope_positions,
                                                 default_positions)
                positions = default_positions(B, S)
                mrope = (default_mrope_positions(B, S)
                         if cfg.rope == "mrope" else None)
                rope_fn = M.make_rope_fn(cfg, positions, mrope)
                x = p["embed"][tokens]
                if vision_embeds is not None:
                    x = jnp.concatenate(
                        [vision_embeds.astype(x.dtype),
                         x[:, vision_embeds.shape[1]:]], axis=1)
                from repro.models import decoder as dec
                x, caches, _ = dec.stack_forward(
                    p["layers"], cfg, x, rope_fn, causal=True,
                    want_cache=True, decode_len=self.max_len, remat=False)
                x_last = jnp.take_along_axis(
                    x, (last_idx - 1)[:, None, None].astype(jnp.int32), 1)
                logits = M._head(p, cfg, x_last)
                return logits[:, 0], {"layers": caches}

            self._prefill_cache[bucket] = jax.jit(fn)
        return self._prefill_cache[bucket]

    def _stage(self, depth_scale: float = 1.0):
        """Synchronous fallback producer (``async_staging=False``): run the
        plan's frontend/projector stages inline for queued vlm requests,
        class by class.  A FULL class ring stalls *that class* — its
        requests keep their FIFO positions and retry next step — while
        later requests of other classes continue staging (per-class
        backpressure, never a bypass, never cross-class head-of-line
        blocking).  The battery knob gates classes exactly like the async
        hand-off: a class whose scaled depth is already met stages
        nothing this step (high-resolution classes shed first)."""
        if self.tabm is None:
            return
        table = self.tabm.admission_table(depth_scale)
        stalled: set = set()                   # classes FULL this pass
        for req in self.queue:
            if req.staged or req.vision_feats is None:
                continue
            if req.slot_class in stalled:      # keep FIFO within the class
                continue
            ring, cap = table[req.slot_class]
            staged_now = ring.staged_ahead() if ring is not None else 0
            if cap < self.tabm.max_ahead(req.slot_class) \
                    and staged_now >= cap:
                # the *throttle* binds (scaled depth met) — skip the class
                # without touching the ring; plain FULL still goes through
                # produce below so backpressure stalls are observable
                stalled.add(req.slot_class)
                continue
            if not req.stage_submitted:    # one stage_start per request,
                req.stage_submitted = True  # even across FULL-stall retries
                self._trace_event("stage_start", req.rid)
            try:
                slot = self.plan.produce(
                    {"vision_feats": jnp.asarray(req.vision_feats)},
                    slot_class=req.slot_class)
            except Exception as e:             # surface on the owning request
                req.error = e
                req._staged_ev.set()            # marks staged
                self._trace_event("stage_error", req.rid)
                continue
            if slot is None:                   # class FULL -> stall the class
                stalled.add(req.slot_class)
                continue
            req.tabm_slot = slot
            req._staged_ev.set()           # marks staged
            self._trace_event("stage_commit", req.rid)

    def _feed_staging(self, depth_scale: float = 1.0):
        """Admission's producer hand-off, charged per class: each request
        is handed to its class's staging thread only while that class's
        staged-ahead depth budget (core/scheduler.class_staging_budgets)
        allows.  The cap is each class's own ``max_ahead`` — by default
        the class ring's capacity, ``staging_budget``'s own default, so
        the hand-off queue is bounded by the ring and shutdown
        cancellation stays cheap — scaled by the battery knob
        ``depth_scale`` (high-resolution classes shrink first).  A class
        with no budget (FULL, throttled, or saturated hand-off) is simply
        skipped; later requests of other classes still hand off — the
        class isolation the single FIFO cap could not give."""
        budgets = class_staging_budgets(
            self.tabm, self._worker.in_flight_by_class(), depth_scale)
        for req in self.queue:
            if req.staged or req.stage_submitted or req.vision_feats is None:
                continue
            if budgets.get(req.slot_class, 0) <= 0:
                continue                       # class exhausted; others go on
            budgets[req.slot_class] -= 1
            req.stage_submitted = True
            self._worker.submit(req)

    def _ring_of(self, req: Request):
        """The class ring holding this request's staged embeds."""
        return self.tabm.ring(req.slot_class)

    def _bind_vision(self, req: Request) -> Optional[jnp.ndarray]:
        """Consumer half: per-slot ready wait on the request's class ring,
        then bind that ring's oldest READY slot as the prefill's vision
        input.  FIFO commit order == FIFO admission order *within a
        class*, so the bound slot is this request's; the seqlock
        generation is captured so release can assert the zero-copy view
        stayed valid across the prefill."""
        if req.tabm_slot is None:
            return None
        # normally immediate — admission only runs once `staged` is set,
        # which the worker sets strictly after commit — but this is the
        # formal consumer-side gate (and the blocking point if admission
        # ever runs ahead of the staged flag)
        if not self.plan.wait_ready(req.tabm_slot, timeout=30.0,
                                    slot_class=req.slot_class):
            raise TABMError(
                f"slot {req.tabm_slot} ({req.slot_class}) did not become "
                f"READY (aborted, ring closed, or timed out)")
        got = self.plan.consume(slot_class=req.slot_class)
        if got is None or got[0] != req.tabm_slot:
            # enforced with a real raise (not assert): this is the
            # per-class FIFO contract the zero-copy hand-off stands on
            raise TABMError(
                f"consume returned {got and got[0]}, expected request "
                f"{req.rid}'s slot {req.tabm_slot} of class "
                f"{req.slot_class} (per-class FIFO order broken)")
        slot, view, n = got
        req._tabm_gen = self._ring_of(req).slot_generation(slot)
        return view[None, :n]

    def _fail(self, req: Request):
        req.finish_t = req.finish_t or time.time()
        self.stats.failed += 1
        self._trace_event("failed", req.rid)
        self.done.append(req)

    def _apply_backend_knobs(self, knobs):
        """The PowerPolicy re-lowering hook: demote the static-shape
        (encoder-side) bricks to the knob's cheaper backend under deep
        THROTTLED, and restore the compiled substrate when charge
        recovers.  plan.relower swaps each step atomically, so the
        staging thread's in-flight produce is never torn."""
        target = knobs.backend_demotion
        if target == self._demoted_to:
            return
        for s in list(self.plan.steps):
            if not s.brick.static_shape:
                continue
            self.plan.relower(
                s.brick.name,
                target if target is not None
                else self._lowered_backends[s.brick.name])
        self._demoted_to = target
        self._trace_event(f"relower:{target or 'restore'}", -1)

    def _admit(self):
        state, knobs, _ = self.executor.current()
        self._apply_backend_knobs(knobs)
        power_ok = (knobs.admission_rate > 0
                    or state is PowerState.UNCONSTRAINED)
        if power_ok:
            if self._worker is not None:
                # producer threads run ahead, charged per class and scaled
                # by the battery knob (high-res classes shed depth first)
                self._feed_staging(knobs.class_depth_scale)
            else:
                # sync fallback: inline, same per-class battery gating —
                # the equivalence oracle throttles like the async path
                self._stage(knobs.class_depth_scale)
        budget = min(len(self.slots.free), knobs.max_batch)
        if not power_ok:
            budget = 0
        i = 0
        while i < len(self.queue) and budget > 0:
            req = self.queue[i]
            if self.tabm is not None and req.vision_feats is not None \
                    and not req.staged:
                # this request's class producer is stalled (FULL ring or
                # throttled depth) — skip it, keep its FIFO position, and
                # let staged requests of *other* classes admit behind it:
                # a stalled high-res class never blocks thumbnails
                i += 1
                continue
            # error is read only after the staged flag: the worker stores
            # error before staged=True, so a failed request can never slip
            # through as staged-with-no-slot and prefill without vision
            if req.error is not None:          # staging failed: finish failed
                self.queue.pop(i)
                self._fail(req)
                continue
            slot = self.slots.take_slot()
            if slot is None:
                break
            self.queue.pop(i)
            budget -= 1
            try:
                prompt = np.asarray(req.tokens, np.int32)
                bucket = bucket_length(len(prompt),
                                       buckets=self._buckets())
                padded = np.zeros((1, bucket), np.int32)
                padded[0, :len(prompt)] = prompt  # right-pad into the bucket
                vision = self._bind_vision(req)
                logits, cache = self._prefill_fn(bucket)(
                    self.params, jnp.asarray(padded), vision,
                    jnp.asarray([len(prompt)], jnp.int32))
                if req.tabm_slot is not None:  # prefill consumed the view
                    if not self._ring_of(req).view_valid(req.tabm_slot,
                                                         req._tabm_gen):
                        raise TABMError(
                            f"slot {req.tabm_slot} recycled under request "
                            f"{req.rid}'s zero-copy view (seqlock "
                            f"violation)")
                    self.plan.release(req.tabm_slot,
                                      slot_class=req.slot_class)
            except Exception as e:
                # neither the KV slot nor a consumed ring slot may leak,
                # and the request must still be accounted for (e.g. the
                # ring closed under a concurrent shutdown mid-admission):
                # fail this request, keep serving
                if (req.tabm_slot is not None and req._tabm_gen is not None
                        and self._ring_of(req).view_valid(req.tabm_slot,
                                                          req._tabm_gen)):
                    self.plan.release(req.tabm_slot,   # consumed, unreleased
                                      slot_class=req.slot_class)
                self.slots.release(slot)
                req.error = e
                self._fail(req)
                continue
            self.slots.insert(slot, cache, len(prompt))
            req.slot = slot
            self.live[slot] = req
            self.stats.prefills += 1
            self._trace_event("prefill", req.rid)
            # first token from the prefill logits
            tok = self._pick(logits, req)
            req.out_tokens.append(int(tok[0]))
            req.first_token_t = time.time()
        if not self.live and self.queue:
            waiter = None
            if self._worker is not None:
                # idle consumer waiting on the producer: park briefly on
                # the first pending staged event instead of hot-spinning
                # the loop (only stage_submitted requests qualify — the
                # worker WILL stage those; gated heads won't set it)
                waiter = next((r for r in self.queue
                               if r.error is None and r.stage_submitted
                               and not r.staged), None)
            if waiter is not None:
                waiter._staged_ev.wait(0.05)
            elif not any(r.staged and r.error is None for r in self.queue):
                # nothing live, nothing admissible, nothing being staged —
                # every queued request is power- or class-depth-gated.
                # Breathe instead of hot-spinning the step loop at full
                # CPU (which would burn the very battery the throttle is
                # conserving) until charge recovers.
                time.sleep(0.005)

    def _pick(self, logits, req: Request):
        if req.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return sample(logits, k, temperature=req.temperature)

    def _buckets(self):
        caps = [b for b in (128, 256, 512, 1024, 2048, 4096)
                if b <= self.max_len - 1]
        return tuple(caps) or (self.max_len - 1,)

    def step(self):
        self._admit()
        if not self.live:
            self.stats.steps += 1
            return
        # batched decode over ALL slots (inactive ones masked out)
        tokens = np.zeros((self.slots.n_slots, 1), np.int32)
        for slot, req in self.live.items():
            tokens[slot, 0] = req.out_tokens[-1]
        logits, self.slots.cache = self._decode(
            self.params, jnp.asarray(tokens), self.slots.cache)
        self.stats.steps += 1
        self._trace_event("decode_step", self.stats.steps)

        finished = []
        for slot, req in list(self.live.items()):
            tok = self._pick(logits[slot:slot + 1], req)
            t = int(tok[0])
            req.out_tokens.append(t)
            self.stats.decoded_tokens += 1
            over_len = int(self.slots.lengths[slot]) + 1 >= self.max_len
            if (t == EOS_ID or len(req.out_tokens) >= req.max_new_tokens
                    or over_len):
                req.finish_t = time.time()
                finished.append(slot)
        for slot in finished:
            req = self.live.pop(slot)
            self.done.append(req)
            self.slots.release(slot)
            self.stats.finished += 1
            self._trace_event("finish", req.rid)

    # -- reporting -----------------------------------------------------------
    def memory_bytes(self) -> Dict[str, int]:
        from repro.core.quantize import tree_bytes
        return {"weights": tree_bytes(self.params),
                "kv_pool": self.slots.nbytes,
                "tabm": self.tabm.nbytes if self.tabm else 0}
