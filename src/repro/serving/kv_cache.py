"""Slot- and block-paged KV/SSM caches for continuous batching.

Two pools, same static-shape discipline (the paper's NPU section: never
recompile):

* :class:`SlotCache` — the original flat pool: ``n_slots`` request rows,
  each ``max_len`` wide, per-slot lengths in the cache's ``index``
  vector.  Still the simplest thing that works when every request may
  grow to ``max_len`` anyway; kept as the reference layout.

* :class:`PagedKVCache` — the paged pool the engine's decode cohort
  runs on.  Attention K/V live as fixed-size **blocks** ``(n_blocks,
  block_size, ...)`` instead of per-slot rows; every admitted request
  owns a **block table** (host-side list of granted block ids) and
  decode gathers its context as ``pool[table]``.  SSM / linear-attention
  state has no length axis, so those group positions stay slot-indexed.
  Admission *grants* a request exactly the blocks its lifetime needs
  (block-aligned prefill bucket + decode growth), charged per slot class
  (``core/scheduler.kv_block_budgets``), and retirement returns them to
  the free deque immediately — the continuous-batching property that a
  finishing request's memory is grantable at the very next step.

Both pools land grouped batch-B prefills in ONE donated strided scatter
per leaf (``insert_many``): the flat pool scatters rows, the paged pool
reshapes the block-aligned prefill width ``(B, nb*block_size)`` into
``(B*nb, block_size)`` and scatters into the owners' granted blocks.

Out-of-range sentinels make cohort padding free: a padded cohort row
carries slot id ``n_slots`` and block id ``n_blocks`` — device gathers
use ``mode="fill"`` (zeros in, masked by the per-row length), scatters
use ``mode="drop"`` (writes vanish), so no host-side branching per row.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decoder as dec
from repro.models import model as M


@functools.partial(jax.jit, donate_argnums=(0,))
def _insert_slots(pool_leaf, batch_leaf, slots: jnp.ndarray):
    """Write a batch-K cache leaf (L, K, ...) into rows `slots` of the
    (L, B, ...) pools — ONE strided scatter per leaf, donated in place,
    so a grouped batch-B prefill lands in B slots in a single op instead
    of B slot-by-slot merges.  Leaves carry a leading layer-stack dim."""
    return pool_leaf.at[:, slots].set(batch_leaf.astype(pool_leaf.dtype))


@functools.partial(jax.jit, donate_argnums=(0,), static_argnums=(3,))
def _insert_blocks(pool_leaf, batch_leaf, block_ids: jnp.ndarray,
                   block_size: int):
    """Paged twin of :func:`_insert_slots`: a batch-K prefilled attention
    leaf (L, K, S, ...) with block-aligned S = nb*block_size lands in
    each request's granted blocks — `block_ids` is (K, nb) — as ONE
    donated strided scatter into the (L, n_blocks, block_size, ...)
    pool.  Sentinel ids (>= n_blocks) are dropped."""
    L, K, S = batch_leaf.shape[:3]
    nb = S // block_size
    resh = batch_leaf.reshape((L, K * nb, block_size)
                              + batch_leaf.shape[3:])
    return pool_leaf.at[:, block_ids.reshape(-1)].set(
        resh.astype(pool_leaf.dtype), mode="drop")


def paged_positions(cfg: ModelConfig) -> Tuple[bool, ...]:
    """Which group positions carry a length-indexed attention K/V cache —
    the positions the paged pool blocks.  Mamba and linear-attention
    state is fixed-size per request, so it stays slot-indexed."""
    return tuple(
        dec.sublayer_spec(cfg, pos)[0] == "attn"
        and dec.cfg_attn_impl(cfg) != "linear"
        for pos in range(dec.group_size(cfg)))


@dataclass
class SlotCache:
    """The pooled decode state + the host-side free list."""

    cfg: ModelConfig
    n_slots: int
    max_len: int

    def __post_init__(self):
        self.cache = M.init_decode_state(self.cfg, self.n_slots, self.max_len,
                                         start_index=0)
        # per-slot lengths (vector index => continuous batching)
        self.cache["index"] = jnp.zeros((self.n_slots,), jnp.int32)
        self.free: Deque[int] = deque(range(self.n_slots))
        self.live: Dict[int, Any] = {}

    # -- admission ----------------------------------------------------------
    def take_slot(self) -> Optional[int]:
        return self.free.popleft() if self.free else None

    def insert(self, slot: int, prefill_cache, prompt_len: int):
        """Merge a batch-1 prefilled cache into the pool at `slot` — the
        K=1 case of :meth:`insert_many`."""
        self.insert_many([slot], prefill_cache, [prompt_len])

    def insert_many(self, slots: List[int], prefill_cache,
                    prompt_lens: List[int]):
        """Merge a batch-K prefilled cache (leaves (L, K, ...)) into K
        pool slots in one strided scatter per leaf — the admission side
        of the grouped prefill: one device op per leaf regardless of how
        many requests the prefill batched."""
        idx = jnp.asarray(slots, jnp.int32)
        self.cache["layers"] = jax.tree.map(
            lambda pool, many: _insert_slots(pool, many, idx),
            self.cache["layers"], prefill_cache["layers"])
        self.cache["index"] = self.cache["index"].at[idx].set(
            jnp.asarray(prompt_lens, jnp.int32))

    def release(self, slot: int):
        self.cache["index"] = self.cache["index"].at[slot].set(0)
        self.free.append(slot)

    # -- views --------------------------------------------------------------
    @property
    def lengths(self) -> jnp.ndarray:
        return self.cache["index"]

    def active_mask(self, live_slots) -> jnp.ndarray:
        m = jnp.zeros((self.n_slots,), bool)
        if live_slots:
            m = m.at[jnp.asarray(sorted(live_slots))].set(True)
        return m

    @property
    def nbytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(self.cache))


class PagedKVCache:
    """Block-paged decode state: the device pools plus the host-side
    block allocator (free deques, per-request block tables, per-class
    block accounting, per-slot lengths).

    Device layout, one entry per group position (``paged_positions``):

    * paged (attention K/V): leaves ``(L, n_blocks, block_size, ...)``;
    * slot state (mamba / linear attention): leaves ``(L, n_slots, ...)``
      exactly as :func:`repro.models.decoder.init_cache` builds them.

    Host bookkeeping is plain Python under the engine's single-threaded
    step loop: ``free`` / ``free_blocks`` are deques (O(1) head pops —
    the old ``free.pop(0)`` was O(n)), ``block_tables[slot]`` is the
    request's granted block-id run, ``used_blocks[slot_class]`` the
    per-class charge ``core/scheduler.kv_block_budgets`` reads, and
    ``lengths`` a host numpy vector (the decode cohort feeds it in as
    the batched ``index``, so retiring or admitting a request never
    touches device state — continuous batching is pure bookkeeping)."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int, *,
                 block_size: int = 64, total_blocks: Optional[int] = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = block_size
        self.blocks_per_slot = -(-max_len // block_size)
        # the paged win: total_blocks < n_slots*blocks_per_slot
        # oversubscribes slots against memory (most requests never grow
        # to max_len); default is the flat pool's worst case
        self.n_blocks = (n_slots * self.blocks_per_slot
                         if total_blocks is None else int(total_blocks))
        self.paged = paged_positions(cfg)
        base = dec.init_cache(cfg, n_slots, block_size)
        pool = []
        for pos, paged in enumerate(self.paged):
            if paged:
                # reuse the (L, n_slots, block_size, ...) template leaf
                # for dtype/trailing dims; blocks replace the slot axis
                pool.append(jax.tree.map(
                    lambda l: jnp.zeros(
                        (l.shape[0], self.n_blocks) + l.shape[2:], l.dtype),
                    base[pos]))
            else:
                pool.append(base[pos])
        self.pool: Tuple[Any, ...] = tuple(pool)
        self.free: Deque[int] = deque(range(n_slots))
        self.free_blocks: Deque[int] = deque(range(self.n_blocks))
        self.block_tables: Dict[int, List[int]] = {}
        self.slot_class_of: Dict[int, Optional[str]] = {}
        self.used_blocks: Dict[Optional[str], int] = {}
        self.lengths = np.zeros((n_slots,), np.int32)

    # -- admission ----------------------------------------------------------
    @property
    def free_block_count(self) -> int:
        return len(self.free_blocks)

    def take_slot(self) -> Optional[int]:
        return self.free.popleft() if self.free else None

    def grant_blocks(self, slot: int, n: int,
                     slot_class: Optional[str] = None) -> List[int]:
        """Grant `n` KV blocks to `slot`, charged to `slot_class`.
        Admission must have checked ``free_block_count`` (and the class
        budget) first — an unfulfillable or double grant raises."""
        if slot in self.block_tables:
            raise RuntimeError(f"slot {slot} already holds a block grant")
        if n > len(self.free_blocks):
            raise RuntimeError(
                f"grant of {n} blocks with only {len(self.free_blocks)} "
                f"free (admission must check first)")
        blocks = [self.free_blocks.popleft() for _ in range(n)]
        self.block_tables[slot] = blocks
        self.slot_class_of[slot] = slot_class
        self.used_blocks[slot_class] = \
            self.used_blocks.get(slot_class, 0) + n
        return blocks

    def insert(self, slot: int, prefill_cache, prompt_len: int):
        """K=1 case of :meth:`insert_many`."""
        self.insert_many([slot], prefill_cache, [prompt_len])

    def insert_many(self, slots: List[int], prefill_cache,
                    prompt_lens: List[int]):
        """Land a batch-K prefilled cache: attention leaves — prefilled
        at a block-aligned width S = nb*block_size — scatter into each
        request's first nb granted blocks (one donated strided scatter
        per leaf, :func:`_insert_blocks`); slot-state leaves scatter by
        slot id exactly like the flat pool."""
        layers = prefill_cache["layers"]
        idx = jnp.asarray(slots, jnp.int32)
        bs = self.block_size
        ids = None
        new_pool = []
        for pos, paged in enumerate(self.paged):
            if paged:
                if ids is None:
                    S = jax.tree.leaves(layers[pos])[0].shape[2]
                    if S % bs:
                        raise RuntimeError(
                            f"prefill width {S} is not block-aligned "
                            f"(block_size {bs})")
                    nb = S // bs
                    host = np.full((len(slots), nb), self.n_blocks,
                                   np.int32)
                    for b, slot in enumerate(slots):
                        tbl = self.block_tables.get(slot, [])
                        if len(tbl) < nb:
                            raise RuntimeError(
                                f"slot {slot} holds {len(tbl)} blocks, "
                                f"prefill needs {nb}")
                        host[b] = tbl[:nb]
                    ids = jnp.asarray(host)
                new_pool.append(jax.tree.map(
                    lambda p, m: _insert_blocks(p, m, ids, bs),
                    self.pool[pos], layers[pos]))
            else:
                new_pool.append(jax.tree.map(
                    lambda p, m: _insert_slots(p, m, idx),
                    self.pool[pos], layers[pos]))
        self.pool = tuple(new_pool)
        for slot, n in zip(slots, prompt_lens):
            self.lengths[slot] = int(n)

    def release(self, slot: int):
        """Retire a request: its blocks return to the free deque NOW —
        grantable to the next admission, before any device op runs."""
        blocks = self.block_tables.pop(slot, None)
        cls = self.slot_class_of.pop(slot, None)
        if blocks:
            self.used_blocks[cls] = \
                self.used_blocks.get(cls, 0) - len(blocks)
            self.free_blocks.extend(blocks)
        self.lengths[slot] = 0
        self.free.append(slot)

    # -- decode-cohort views ------------------------------------------------
    def bump(self, slot: int):
        """One decode step served this slot: host-side length += 1."""
        self.lengths[slot] += 1

    def gather_tables(self, slots: Sequence[int]) -> np.ndarray:
        """Block tables of `slots` as one (len(slots), blocks_per_slot)
        int32 array, padded with the out-of-range sentinel ``n_blocks``
        (device gathers fill zeros, scatters drop)."""
        out = np.full((len(slots), self.blocks_per_slot), self.n_blocks,
                      np.int32)
        for i, slot in enumerate(slots):
            tbl = self.block_tables.get(slot, ())
            out[i, :len(tbl)] = tbl
        return out

    # -- fleet wire (disaggregated prefill -> decode hand-off) ---------------
    @property
    def slot_lane_bytes(self) -> int:
        """Paged bytes of one whole ``max_len`` lane — what shipping a
        flat per-slot row (``blocks_per_slot`` blocks across every paged
        position) would cost.  The baseline
        :meth:`~repro.core.transport.RemotePrefill.kv_wire_bytes` is
        asserted against: a disaggregated hand-off ships only the
        *written* blocks, so its wire bytes must come in under this."""
        per_block = sum(
            leaf.nbytes // self.n_blocks
            for pos, paged in enumerate(self.paged) if paged
            for leaf in jax.tree.leaves(self.pool[pos]))
        return per_block * self.blocks_per_slot

    def export_blocks(self, slot: int, n_blocks: int) -> List[List[Any]]:
        """Pull one request's prefill-written cache off the device for
        the wire: per group position, the flat leaf list — paged
        positions as ``(L, nb, block_size, ...)`` host arrays holding the
        first ``n_blocks`` granted blocks (the *written* ones — never the
        whole lane), slot-state positions as the request's ``(L, 1,
        ...)`` row.  Tree structure is not exported; the importing pool
        re-derives it from its own treedef (same config both fleets).
        The ``np.asarray`` pulls are the serialization boundary — this
        data is leaving the process, so the device sync is the point."""
        tbl = self.block_tables.get(slot, [])
        if n_blocks > len(tbl):
            raise RuntimeError(
                f"export of {n_blocks} blocks from slot {slot} which "
                f"holds {len(tbl)}")
        ids = jnp.asarray(tbl[:n_blocks], jnp.int32)
        out: List[List[Any]] = []
        for pos, paged in enumerate(self.paged):
            if paged:
                out.append([
                    np.asarray(jnp.take(leaf, ids, axis=1))  # replint: disable=host-sync
                    for leaf in jax.tree.leaves(self.pool[pos])])
            else:
                out.append([
                    np.asarray(leaf[:, slot:slot + 1])  # replint: disable=host-sync
                    for leaf in jax.tree.leaves(self.pool[pos])])
        return out

    def import_blocks(self, slot: int, payload: List[List[Any]]) -> None:
        """Land an :meth:`export_blocks` payload in this pool at `slot`
        (which must already hold a block grant at least as long as the
        payload): paged leaves reshape back to one batch-1 block-aligned
        prefill and reuse the donated :func:`_insert_blocks` scatter into
        the slot's own granted blocks; slot-state leaves scatter by slot
        id.  Byte-for-byte: export -> wire -> import preserves every leaf
        exactly (tests/test_transport.py), which is what makes
        disaggregated decode bit-identical to single-process."""
        bs = self.block_size
        tbl = self.block_tables.get(slot)
        idx = jnp.asarray([slot], jnp.int32)
        new_pool = list(self.pool)
        for pos, paged in enumerate(self.paged):
            treedef = jax.tree.structure(self.pool[pos])
            leaves = [jnp.asarray(l) for l in payload[pos]]
            batch = jax.tree.unflatten(treedef, leaves)
            if paged:
                nb = int(payload[pos][0].shape[1])
                if tbl is None or len(tbl) < nb:
                    raise RuntimeError(
                        f"import of {nb} blocks into slot {slot} which "
                        f"holds {0 if tbl is None else len(tbl)}")
                ids = jnp.asarray(  # host block table, no device involved
                    np.asarray(tbl[:nb], np.int32)  # replint: disable=host-sync
                    .reshape(1, nb))
                batch = jax.tree.map(
                    lambda l: l.reshape((l.shape[0], 1, nb * bs)
                                        + l.shape[3:]), batch)
                new_pool[pos] = jax.tree.map(
                    lambda p, m: _insert_blocks(p, m, ids, bs),
                    new_pool[pos], batch)
            else:
                new_pool[pos] = jax.tree.map(
                    lambda p, m: _insert_slots(p, m, idx),
                    new_pool[pos], batch)
        self.pool = tuple(new_pool)

    # -- invariants / reporting ---------------------------------------------
    def check_block_invariants(self):
        """Raise unless the allocator is conservation-clean: every block
        is free xor granted to exactly one slot (no double grant, no
        orphan), and the per-class charge matches the tables.  The
        property-test hook (tests/test_decode_cohort.py)."""
        granted = [b for t in self.block_tables.values() for b in t]
        if len(granted) != len(set(granted)):
            raise AssertionError(f"double-granted block in "
                                 f"{self.block_tables}")
        free = list(self.free_blocks)
        if len(free) != len(set(free)):
            raise AssertionError(f"duplicate free block in {free}")
        if set(granted) & set(free):
            raise AssertionError("block both granted and free")
        if len(granted) + len(free) != self.n_blocks:
            raise AssertionError(
                f"block leak: {len(granted)} granted + {len(free)} free "
                f"!= {self.n_blocks}")
        by_class: Dict[Optional[str], int] = {}
        for slot, tbl in self.block_tables.items():
            cls = self.slot_class_of.get(slot)
            by_class[cls] = by_class.get(cls, 0) + len(tbl)
        used = {c: n for c, n in self.used_blocks.items() if n}
        if by_class != used:
            raise AssertionError(f"class charge drift: tables say "
                                 f"{by_class}, used_blocks says {used}")

    @property
    def nbytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(self.pool))


def bucket_length(n: int, buckets=(128, 256, 512, 1024, 2048, 4096)) -> int:
    """Static-shape prompt bucketing (paper §NPU: fixed input shapes; we
    pad prompts up to the nearest bucket instead of recompiling)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]
