"""Slot-based KV/SSM cache for continuous batching.

A fixed pool of ``n_slots`` request slots (static shapes — the same
discipline the paper's NPU section imposes: never recompile).  Each slot
holds one request's caches; per-slot lengths live in the cache's ``index``
vector.  Admission writes a prefilled (batch-1) cache into a free slot;
retirement just frees the slot id — the cache memory is reused in place
(ring-buffer thinking applied to decode state: TABM's FREE/ALLOCATED cycle
at request granularity).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


@functools.partial(jax.jit, donate_argnums=(0,))
def _insert_slot(pool_leaf, slot_leaf, slot: jnp.ndarray):
    """Write a batch-1 cache leaf (1, ...) into slot b of (B, ...) pools.
    Leaves carry a leading layer-stack dim: (L, B, ...) vs (L, 1, ...)."""
    return jax.lax.dynamic_update_slice(
        pool_leaf, slot_leaf.astype(pool_leaf.dtype),
        (0, slot) + (0,) * (pool_leaf.ndim - 2))


@dataclass
class SlotCache:
    """The pooled decode state + the host-side free list."""

    cfg: ModelConfig
    n_slots: int
    max_len: int

    def __post_init__(self):
        self.cache = M.init_decode_state(self.cfg, self.n_slots, self.max_len,
                                         start_index=0)
        # per-slot lengths (vector index => continuous batching)
        self.cache["index"] = jnp.zeros((self.n_slots,), jnp.int32)
        self.free: List[int] = list(range(self.n_slots))
        self.live: Dict[int, Any] = {}

    # -- admission ----------------------------------------------------------
    def take_slot(self) -> Optional[int]:
        return self.free.pop(0) if self.free else None

    def insert(self, slot: int, prefill_cache, prompt_len: int):
        """Merge a batch-1 prefilled cache into the pool at `slot`."""
        pool_layers = self.cache["layers"]
        new_layers = jax.tree.map(
            lambda pool, one: _insert_slot(pool, one, jnp.asarray(slot)),
            pool_layers, prefill_cache["layers"])
        self.cache["layers"] = new_layers
        self.cache["index"] = self.cache["index"].at[slot].set(prompt_len)

    def release(self, slot: int):
        self.cache["index"] = self.cache["index"].at[slot].set(0)
        self.free.append(slot)

    # -- views --------------------------------------------------------------
    @property
    def lengths(self) -> jnp.ndarray:
        return self.cache["index"]

    def active_mask(self, live_slots) -> jnp.ndarray:
        m = jnp.zeros((self.n_slots,), bool)
        if live_slots:
            m = m.at[jnp.asarray(sorted(live_slots))].set(True)
        return m

    @property
    def nbytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(self.cache))


def bucket_length(n: int, buckets=(128, 256, 512, 1024, 2048, 4096)) -> int:
    """Static-shape prompt bucketing (paper §NPU: fixed input shapes; we
    pad prompts up to the nearest bucket instead of recompiling)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]
