"""Slot-based KV/SSM cache for continuous batching.

A fixed pool of ``n_slots`` request slots (static shapes — the same
discipline the paper's NPU section imposes: never recompile).  Each slot
holds one request's caches; per-slot lengths live in the cache's ``index``
vector.  Admission writes a prefilled (batch-1) cache into a free slot;
retirement just frees the slot id — the cache memory is reused in place
(ring-buffer thinking applied to decode state: TABM's FREE/ALLOCATED cycle
at request granularity).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


@functools.partial(jax.jit, donate_argnums=(0,))
def _insert_slots(pool_leaf, batch_leaf, slots: jnp.ndarray):
    """Write a batch-K cache leaf (L, K, ...) into rows `slots` of the
    (L, B, ...) pools — ONE strided scatter per leaf, donated in place,
    so a grouped batch-B prefill lands in B slots in a single op instead
    of B slot-by-slot merges.  Leaves carry a leading layer-stack dim."""
    return pool_leaf.at[:, slots].set(batch_leaf.astype(pool_leaf.dtype))


@dataclass
class SlotCache:
    """The pooled decode state + the host-side free list."""

    cfg: ModelConfig
    n_slots: int
    max_len: int

    def __post_init__(self):
        self.cache = M.init_decode_state(self.cfg, self.n_slots, self.max_len,
                                         start_index=0)
        # per-slot lengths (vector index => continuous batching)
        self.cache["index"] = jnp.zeros((self.n_slots,), jnp.int32)
        self.free: List[int] = list(range(self.n_slots))
        self.live: Dict[int, Any] = {}

    # -- admission ----------------------------------------------------------
    def take_slot(self) -> Optional[int]:
        return self.free.pop(0) if self.free else None

    def insert(self, slot: int, prefill_cache, prompt_len: int):
        """Merge a batch-1 prefilled cache into the pool at `slot` — the
        K=1 case of :meth:`insert_many`."""
        self.insert_many([slot], prefill_cache, [prompt_len])

    def insert_many(self, slots: List[int], prefill_cache,
                    prompt_lens: List[int]):
        """Merge a batch-K prefilled cache (leaves (L, K, ...)) into K
        pool slots in one strided scatter per leaf — the admission side
        of the grouped prefill: one device op per leaf regardless of how
        many requests the prefill batched."""
        idx = jnp.asarray(slots, jnp.int32)
        self.cache["layers"] = jax.tree.map(
            lambda pool, many: _insert_slots(pool, many, idx),
            self.cache["layers"], prefill_cache["layers"])
        self.cache["index"] = self.cache["index"].at[idx].set(
            jnp.asarray(prompt_lens, jnp.int32))

    def release(self, slot: int):
        self.cache["index"] = self.cache["index"].at[slot].set(0)
        self.free.append(slot)

    # -- views --------------------------------------------------------------
    @property
    def lengths(self) -> jnp.ndarray:
        return self.cache["index"]

    def active_mask(self, live_slots) -> jnp.ndarray:
        m = jnp.zeros((self.n_slots,), bool)
        if live_slots:
            m = m.at[jnp.asarray(sorted(live_slots))].set(True)
        return m

    @property
    def nbytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(self.cache))


def bucket_length(n: int, buckets=(128, 256, 512, 1024, 2048, 4096)) -> int:
    """Static-shape prompt bucketing (paper §NPU: fixed input shapes; we
    pad prompts up to the nearest bucket instead of recompiling)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]
