"""Serving substrate: slot-based KV cache, continuous batching engine,
sampling — with the paper's TABM hand-off and battery-aware throttling."""
