"""Token sampling: greedy / temperature / top-k / top-p, pure jnp."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("top_k", "top_p"))
def sample(logits: jnp.ndarray, key, *, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 1.0) -> jnp.ndarray:
    """logits (B, V) -> tokens (B,) int32."""
    logits = logits.astype(jnp.float32)
    t = jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-4)
    logits = logits / t
    if top_k:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
