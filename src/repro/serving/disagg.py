"""Disaggregated prefill/decode fleets over a Transport.

The fleet-scale serving topology ("Cost-Efficient Multimodal LLM
Inference via Cross-Tier GPU Heterogeneity", PAPERS.md): vision encode +
batched prefill are compute-bound, decode is memory-bound, so each side
runs its own :class:`~repro.serving.engine.ServingEngine` on its own
hardware pool and they meet only at a serialized
:class:`~repro.core.transport.Transport`:

* :class:`PrefillWorker` — drives the engine's staging + grouped batched
  prefill (``prefill_step``), then exports every newly admitted request
  as a :class:`~repro.core.transport.RemotePrefill` — committed TABM
  slab + the *written* KV blocks + block grant, never a whole
  ``max_len`` lane — and streams it over the wire
  (``transport.send_prefill``).  Its engine never decodes; its slots
  recycle the moment a request ships, so prefill capacity is sized and
  scaled independently of decode.
* :class:`DecodeWorker` — receives frames, admits each prefill straight
  into its own paged pool (``engine.admit_remote``; a full pool decodes
  a step to retire capacity and retries — continuous batching across
  the fleet boundary), cohort-decodes everything to completion, and
  streams per-request results back on the same transport.

Failure semantics (the wire contract, core/transport.py): a frame whose
payload fails its checksum is *recoverable* — the stream stayed aligned
and the rid survived in the frame prefix, so the decode fleet fails
exactly that request (a ``result`` frame with the error) and keeps
serving.  A truncated or header-corrupt stream is fatal: every request
still unresolved fails with the stream error.  Prefill-side staging
failures cross as ``failed`` frames so the decode side can account for
every submitted rid.

Frame kinds on the wire::

    prefill  prefill fleet -> decode fleet   RemotePrefill (slab + KV)
    failed   prefill fleet -> decode fleet   rid + error (staging failed)
    done     either direction                end of stream
    result   decode fleet -> prefill fleet   rid + tokens (+ error)

Decode tokens are bit-identical to the single-process engine: the
decode worker runs the *unmodified* ``step()`` over imported state that
round-tripped the lossless wire codec, with the same first token picked
from the same prefill logits (launch/serve_disagg.py asserts this
against a fresh single-process oracle on every run).  Disaggregated
serving is greedy-only — temperature 0 is enforced at submit, because a
sampled token stream cannot be split across two engines' RNGs.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.transport import RemotePrefill, Transport, TransportError
from repro.serving.engine import Request, ServingEngine


@dataclass
class DisaggResult:
    """One request's outcome as it crossed back over the wire."""

    rid: int
    tokens: List[int] = field(default_factory=list)
    error: Optional[str] = None


@dataclass
class PrefillStats:
    """Wire accounting for the prefill fleet — the evidence the driver
    asserts on: ``kv_wire_bytes`` (paged KV actually shipped) vs
    ``lane_bytes_baseline`` (what whole ``max_len`` lanes would cost)."""

    sent: int = 0
    failed: int = 0
    wire_bytes: int = 0
    kv_wire_bytes: int = 0
    lane_bytes_baseline: int = 0
    # measured wire clock (Transport.send_seconds at run end) + transport
    # name: the CostCalibration.observe_link feedback edge the launcher
    # folds back into schedule_split
    wire_seconds: float = 0.0
    transport: str = ""


class PrefillWorker:
    """The prefill fleet: vision encode -> projector -> grouped batched
    prefill, streamed out as RemotePrefill frames."""

    def __init__(self, cfg, params, transport: Transport, *,
                 max_steps: int = 10_000, **engine_kwargs):
        engine_kwargs.setdefault("async_staging", False)
        self.transport = transport
        self.max_steps = max_steps
        self.engine = ServingEngine(cfg, params, capture_slab=True,
                                    **engine_kwargs)
        self.stats = PrefillStats()
        self._done_seen = 0

    def submit(self, req: Request) -> None:
        if req.temperature != 0.0:
            raise ValueError(
                f"disaggregated serving is greedy-only (request "
                f"{req.rid} has temperature {req.temperature})")
        self.engine.submit(req)

    def _flush_failures(self) -> None:
        """Staging/admission failures land in engine.done; cross them as
        ``failed`` frames so the decode side accounts for every rid."""
        while self._done_seen < len(self.engine.done):
            req = self.engine.done[self._done_seen]
            self._done_seen += 1
            self.stats.failed += 1
            self.stats.wire_bytes += self.transport.send(
                "failed", {"rid": req.rid, "error": repr(req.error)},
                rid=req.rid)

    def run(self) -> PrefillStats:
        """Prefill and ship everything submitted, then send ``done``."""
        eng = self.engine
        self.stats.lane_bytes_baseline = eng.slots.slot_lane_bytes
        steps = 0
        while eng.queue or eng.live:
            if steps >= self.max_steps:
                raise RuntimeError(
                    f"prefill fleet made no progress in "
                    f"{self.max_steps} admission rounds")
            steps += 1
            for req in eng.prefill_step():
                rp = eng.export_remote(req)
                self.stats.sent += 1
                self.stats.kv_wire_bytes += rp.kv_wire_bytes()
                self.stats.wire_bytes += self.transport.send_prefill(rp)
            self._flush_failures()
        self.transport.send("done", {})
        return self.stats

    def collect(self, n: int) -> Dict[int, DisaggResult]:
        """Receive result frames until the decode fleet's ``done`` and
        return them keyed by rid (``n`` is the expected count, for the
        caller's accounting).  Draining to ``done`` is the close
        handshake: it proves the decode side's last write completed, so
        closing our end afterwards can never break the pipe under the
        sender's final frame (returning at the n-th result races
        exactly that)."""
        results: Dict[int, DisaggResult] = {}
        while True:
            kind, meta, arrays, rid = self.transport.recv()
            if kind == "done":
                break
            if kind != "result":
                raise TransportError(
                    f"unexpected frame kind {kind!r} on the result path")
            tokens = [int(t) for t in arrays[0]] if arrays else []
            results[rid] = DisaggResult(rid=rid, tokens=tokens,
                                        error=meta.get("error"))
        return results


class DecodeWorker:
    """The decode fleet: admit RemotePrefill frames into the paged pool,
    cohort-decode to completion, stream results back."""

    def __init__(self, cfg, params, transport: Transport, *,
                 max_steps: int = 100_000, **engine_kwargs):
        engine_kwargs.setdefault("async_staging", False)
        self.transport = transport
        self.max_steps = max_steps
        self.engine = ServingEngine(cfg, params, **engine_kwargs)
        self.results: Dict[int, DisaggResult] = {}

    def _admit(self, rp: RemotePrefill) -> None:
        eng = self.engine
        while not eng.admit_remote(rp):
            # pool full: decode one step so a finishing request retires
            # and frees the slot/blocks this admission needs
            if not eng.live:
                raise RuntimeError(
                    f"request {rp.rid} needs {rp.blocks_granted} blocks "
                    f"but the idle pool cannot grant them (decode fleet "
                    f"sized too small for one request)")
            eng.step()

    def run(self) -> Dict[int, DisaggResult]:
        """Serve the stream to completion.  Recoverable wire errors fail
        only the owning request; a fatal stream error fails everything
        unresolved, then propagates."""
        eng = self.engine
        expected: List[int] = []               # rids in arrival order
        stream_error: Optional[TransportError] = None
        while True:
            try:
                kind, meta, arrays, rid = self.transport.recv()
            except TransportError as e:
                if e.recoverable:
                    # the frame was consumed whole and named its owner:
                    # fail exactly that request, keep receiving
                    if e.rid is not None:
                        expected.append(e.rid)
                        self.results[e.rid] = DisaggResult(
                            rid=e.rid, error=repr(e))
                    continue
                stream_error = e
                break
            if kind == "done":
                break
            if kind == "failed":
                expected.append(rid)
                self.results[rid] = DisaggResult(
                    rid=rid, error=meta.get("error"))
                continue
            if kind != "prefill":
                continue                       # ignore unknown kinds
            try:
                rp = RemotePrefill.from_wire(meta, arrays)
                self._admit(rp)
                expected.append(rp.rid)
            except TransportError as e:
                if e.rid is not None:
                    expected.append(e.rid)
                    self.results[e.rid] = DisaggResult(rid=e.rid,
                                                       error=repr(e))
        steps = 0
        while eng.live and steps < self.max_steps:
            eng.step()
            steps += 1
        for req in eng.done:
            if req.rid in self.results:
                continue
            self.results[req.rid] = DisaggResult(
                rid=req.rid, tokens=list(req.out_tokens),
                error=None if req.error is None else repr(req.error))
        if stream_error is not None:
            for rid in expected:
                if rid not in self.results:
                    self.results[rid] = DisaggResult(
                        rid=rid, error=repr(stream_error))
        for rid in expected:                   # arrival order, duplex back
            r = self.results[rid]
            self.transport.send(
                "result", {"rid": r.rid, "error": r.error},
                # host list -> host array, no device involved
                arrays=[np.asarray(r.tokens, np.int32)],  # replint: disable=host-sync
                rid=r.rid)
        self.transport.send("done", {})
        if stream_error is not None:
            raise stream_error
        return self.results


def serve_disagg_inproc(cfg, params, requests: List[Request], *,
                        prefill_kwargs: Optional[dict] = None,
                        decode_kwargs: Optional[dict] = None,
                        ) -> Tuple[Dict[int, DisaggResult], PrefillStats]:
    """The two-fleet topology in one process: an
    :class:`~repro.core.transport.InProcTransport` pair, the decode
    worker on its own thread — the degenerate single-host case (and the
    README's executable example).  Returns ``(results by rid,
    prefill-side wire stats)``."""
    from repro.core.transport import InProcTransport
    a, b = InProcTransport.pair()
    pre = PrefillWorker(cfg, params, a, **(prefill_kwargs or {}))
    dec = DecodeWorker(cfg, params, b, **(decode_kwargs or {}))
    errs: List[BaseException] = []

    def _decode():
        try:
            dec.run()
        except BaseException as e:            # surfaces after join
            errs.append(e)
            b.close()                         # unblocks the collector

    t = threading.Thread(target=_decode, name="decode-fleet", daemon=True)
    t.start()
    try:
        for req in requests:
            pre.submit(req)
        stats = pre.run()
        try:
            results = pre.collect(len(requests))
        except TransportError:
            if errs:                          # the root cause, not the close
                raise errs[0]
            raise
        stats.wire_seconds = a.send_seconds
        stats.transport = a.name
    finally:
        t.join(timeout=120.0)
        pre.engine.shutdown()
        dec.engine.shutdown()
    if errs:
        raise errs[0]
    return results, stats
