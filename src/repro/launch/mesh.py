"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run forces 512 host devices via XLA_FLAGS *before* jax initializes, while
smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis (DCN between pods, ICI within)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many local devices exist (CPU tests)."""
    n = len(jax.devices())
    n_model = min(n_model, n)
    n_data = min(n_data, n // n_model)
    return jax.make_mesh((n_data, n_model), ("data", "model"))
