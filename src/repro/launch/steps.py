"""Step builders: the jit entry points the launchers, dry-run, and serving
engine all share.

* ``build_train_step(cfg, opt_cfg)``  -> f(params, opt, batch) -> (params, opt, metrics)
* ``build_prefill_step(cfg, cell)``   -> f(params, batch) -> (logits, cache)
* ``build_serve_step(cfg)``           -> f(params, tokens, cache) -> (logits, cache)

plus the abstract (ShapeDtypeStruct, zero-allocation) builders the multi-pod
dry-run lowers against: :func:`abstract_params`, :func:`abstract_opt`,
:func:`abstract_cache`, :func:`input_specs`.

``decode_*`` / ``long_*`` cells lower ``serve_step`` (one token against a
full cache), NOT ``train_step``, per the assignment.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import encdec as ED
from repro.models import model as M
from repro.training.optimizer import OptConfig, adamw_update, init_opt


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, opt_cfg: OptConfig):
    loss_fn = ED.encdec_loss if cfg.encdec else M.lm_loss

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, max_len: int):
    if cfg.encdec:
        def prefill(params, batch):
            return ED.encdec_prefill(params, cfg, batch["src_embeds"],
                                     batch["tgt_tokens"], max_len)
    else:
        def prefill(params, batch):
            return M.lm_prefill(params, cfg, batch["tokens"], max_len,
                                vision_feats=batch.get("vision_feats"))
    return prefill


def build_serve_step(cfg: ModelConfig):
    """One-token decode against an existing cache (the serving hot loop)."""
    if cfg.encdec:
        def serve(params, tokens, cache):
            return ED.encdec_decode_step(params, cfg, tokens, cache)
    else:
        def serve(params, tokens, cache):
            return M.lm_decode_step(params, cfg, tokens, cache)
    return serve


# ---------------------------------------------------------------------------
# concrete initializers (smoke tests / examples)
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    return ED.init_encdec(key, cfg) if cfg.encdec else M.init_lm(key, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.encdec:
        return ED.init_encdec_decode_state(cfg, batch, max_len)
    return M.init_decode_state(cfg, batch, max_len)


# ---------------------------------------------------------------------------
# abstract builders (dry-run: ShapeDtypeStruct only, no allocation)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, quant_policy: Optional[str] = None):
    """quant_policy: name from repro.core.quantize.PROFILES — the paper's
    W4A16 serving configuration lowers with packed-int weights."""
    if quant_policy is None:
        return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0),
                                                  cfg))
    from repro.core.quantize import PROFILES, quantize_tree
    return jax.eval_shape(
        lambda: quantize_tree(init_params(jax.random.PRNGKey(0), cfg),
                              PROFILES[quant_policy]))


def abstract_opt(cfg: ModelConfig, opt_cfg: OptConfig, params_shapes=None):
    params_shapes = params_shapes or abstract_params(cfg)
    return jax.eval_shape(partial(init_opt, cfg=opt_cfg), params_shapes)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    Modality frontends are STUBS per the assignment: VLM cells get
    precomputed patch features; audio cells get precomputed frame
    embeddings."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    bf16 = cfg.compute_dtype
    sds = jax.ShapeDtypeStruct

    if cfg.encdec:
        if cell.kind == "train":
            return {"src_embeds": sds((B, S, cfg.d_model), bf16),
                    "tgt_tokens": sds((B, S), i32)}
        if cell.kind == "prefill":
            return {"src_embeds": sds((B, cfg.enc_seq_len, cfg.d_model), bf16),
                    "tgt_tokens": sds((B, S), i32)}
        return {"tokens": sds((B, 1), i32)}       # decode

    if cell.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), i32)}
        if cfg.vlm:
            batch["vision_feats"] = sds((B, cfg.vision_tokens,
                                         cfg.vision_feat_dim), bf16)
        return batch
    return {"tokens": sds((B, 1), i32)}           # decode
