"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 50 --batch 8 --seq 256 [--reduced] [--ckpt-dir ckpts/] \
        [--grad-accum 2]

``--reduced`` (default on CPU) runs the same-family tiny config; the full
config path is identical and is what the pod launcher runs under pjit.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, list_archs
from repro.data import multimodal_batch_iter
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, fit


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="use the full (pod-scale) config, not the reduced")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    data = multimodal_batch_iter(cfg, args.batch, args.seq)
    opt = OptConfig(lr=args.lr, warmup_steps=max(1, args.steps // 10),
                    total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every,
                       grad_accum=args.grad_accum)
    res = fit(cfg, opt, tcfg, data)
    losses = [m["loss"] for m in res.metrics_history]
    print(f"[train] {args.arch}: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
