"""Fleet-scale battery simulation CLI — part of the no-TPU gate.

Drives :class:`repro.telemetry.fleet.FleetSimulator` over hundreds of
simulated battery devices, each characterized per modality phase (stage /
prefill / decode) from a telemetry :class:`~repro.telemetry.ledger.Ledger`:

* ``--profile modeled`` (default) prices the paper's full edge pipeline
  (decomposed llava-onevision graph incl. the real SigLip-class vision
  encoder) through the scheduler's energy-objective placement and
  ``Ledger.modeled`` — deterministic across machines, which is what lets
  the fleet metrics carry a tight regression gate in ``BENCH_<pr>.json``;
* ``--profile ledger --ledger FILE`` characterizes from a measured
  ledger a bench run saved (``samples > 0`` rows included);
* ``--profile default`` uses the RK3566-class fallback constants.

``--smoke`` is the CI parameterization: a small pack (150 mAh) so 128
devices traverse UNCONSTRAINED -> THROTTLED -> CRITICAL and die inside a
2 h horizon, with the acceptance assertions (>= 100 devices, all three
power states seen, positive fleet J/token, deaths recorded) enforced.

    PYTHONPATH=src python -m repro.launch.fleet_sim --smoke
    PYTHONPATH=src python -m repro.launch.fleet_sim --devices 512 \
        --hours 12 [--out fleet.csv] [--bench-json BENCH_8.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

# fig8's event shape: SigLip-so400m patches per frame, a short prompt,
# a short voice answer
VISION_TOKENS = 729
SIGLIP_PARAMS = 400e6
PREFILL_TOKENS = 64
SMOKE = dict(devices=128, hours=2.0, dt=10.0, battery_mah=150.0)


def _paper_pipeline(arch: str = "llava-onevision-0.5b"):
    """The full edge pipeline with the REAL vision-encoder brick swapped
    in for the stub frontend and analytic param_bytes filled (fig8's
    idiom) — so the modeled ledger prices what the paper deploys."""
    from repro.configs import get_config
    from repro.core.bricks import Brick, Port, decompose

    g = decompose(get_config(arch))
    enc = Brick("vision_encoder", "encoder", (),
                lambda p, c, ctx: ctx["vision_feats"],
                in_ports=(Port("vision_feats"),), out_port=Port("patches"),
                static_shape=True, quant_label="fp16",
                flops_per_token=2 * SIGLIP_PARAMS,
                param_bytes=int(SIGLIP_PARAMS * 2))
    g.bricks = [enc if b.name == "vision_frontend" else b for b in g.bricks]
    g.bricks = [b if b.param_bytes else dataclasses.replace(
        b, param_bytes=int(b.flops_per_token / 2 * 0.56))
        for b in g.bricks]
    return g


def modeled_profile():
    """ModalityProfile from the compile-time cost model: the scheduler's
    energy-objective placement priced per phase via ``Ledger.modeled``."""
    from repro.core.scheduler import edge_accelerators, schedule
    from repro.telemetry.fleet import ModalityProfile
    from repro.telemetry.ledger import Ledger

    g = _paper_pipeline()
    accels = edge_accelerators()
    by_name = {a.name: a for a in accels}
    pl = schedule(g, accels, n_tokens=PREFILL_TOKENS, objective="energy")
    accel_for = {b: by_name[a] for b, a in pl.assignment.items()}
    led = Ledger.modeled(g, accel_for, phase_tokens={
        "stage": VISION_TOKENS, "prefill": PREFILL_TOKENS, "decode": 1})
    return ModalityProfile.from_ledger(led), led


def main(argv=None) -> int:
    from repro.core.power import PowerState
    from repro.telemetry.fleet import FleetSimulator, ModalityProfile

    ap = argparse.ArgumentParser(
        description="fleet-scale battery simulation over the telemetry "
                    "ledger's per-modality energy profile")
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--hours", type=float, default=12.0)
    ap.add_argument("--dt", type=float, default=30.0,
                    help="simulated seconds per tick")
    ap.add_argument("--battery-mah", type=float, default=2000.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile", choices=("modeled", "ledger", "default"),
                    default="modeled")
    ap.add_argument("--ledger", default=None,
                    help="telemetry ledger JSON to characterize from "
                         "(with --profile ledger)")
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI mode: {SMOKE['devices']} devices on a "
                         f"{SMOKE['battery_mah']:.0f} mAh pack so all "
                         f"three power states and device death happen "
                         f"inside a {SMOKE['hours']:.0f} h horizon; "
                         f"enforces the acceptance assertions")
    ap.add_argument("--out", default=None,
                    help="also write the summary rows to this CSV "
                         "(CI artifact)")
    ap.add_argument("--bench-json", default=None,
                    help="fold rows/gated metrics/modeled ledger into "
                         "this versioned BENCH_<pr>.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.devices = max(args.devices, SMOKE["devices"])
        args.hours, args.dt = SMOKE["hours"], SMOKE["dt"]
        args.battery_mah = SMOKE["battery_mah"]

    led = None
    if args.profile == "modeled":
        profile, led = modeled_profile()
    elif args.profile == "ledger":
        if not args.ledger:
            ap.error("--profile ledger needs --ledger FILE")
        from repro.telemetry.ledger import Ledger
        led = Ledger.load(args.ledger)
        profile = ModalityProfile.from_ledger(led)
    else:
        profile = ModalityProfile.default_edge()
    print(f"profile ({args.profile}): "
          f"J/token={dict(profile.j_per_token)} "
          f"tokens/s={dict(profile.tokens_per_s)}")

    sim = FleetSimulator(args.devices, profile, seed=args.seed,
                         battery_mah=args.battery_mah, dt_s=args.dt)
    rep = sim.run(args.hours)
    print(rep.summary())

    rows = [
        ("fleet/tokens_per_s", 0.0, f"{rep.tokens_per_s:.2f}"),
        ("fleet/j_per_token", 0.0, f"{rep.j_per_token:.5f}"),
        ("fleet/survival_p50_h", 0.0, f"{rep.survival_hours_p50:.3f}"),
        ("fleet/dead", 0.0, f"{rep.dead}/{rep.n_devices}"),
        ("fleet/states", 0.0, " ".join(sorted(rep.states_seen))),
        ("fleet/shed_tokens", 0.0, f"{rep.shed_tokens:.0f}"),
    ]
    if args.out or args.bench_json:
        from repro.telemetry import writer
        if args.out:
            writer.write_csv(args.out, rows)
        if args.bench_json:
            # simulated time over a modeled energy integral: these are
            # machine-independent, so they carry the 10% regression gate
            writer.merge_section(
                args.bench_json, "fleet", rows=rows,
                metrics={
                    "fleet_tokens_per_s": writer.metric(
                        rep.tokens_per_s, better="higher", gate=True),
                    "fleet_j_per_token": writer.metric(
                        rep.j_per_token, better="lower", gate=True),
                    "survival_hours_p50": writer.metric(
                        rep.survival_hours_p50, better="higher",
                        gate=True)},
                ledger=led)

    if args.smoke:
        all_states = {s.value for s in PowerState}
        assert rep.n_devices >= 100, rep.n_devices
        assert rep.states_seen == all_states, (
            f"fleet never traversed all power states: saw "
            f"{sorted(rep.states_seen)}, want {sorted(all_states)}")
        assert rep.j_per_token > 0, "no energy accounted"
        assert rep.dead > 0, "no device exhausted its pack in the smoke"
        print(f"OK: fleet smoke passed ({rep.n_devices} devices, "
              f"{sorted(rep.states_seen)}, {rep.dead} dead, "
              f"p50 {rep.survival_hours_p50:.2f} h)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
