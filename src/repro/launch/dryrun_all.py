"""Run the full dry-run matrix: every (arch x shape) on both meshes.

Each cell runs in a SUBPROCESS (fresh XLA state; a pathological cell cannot
poison the sweep).  Results land in experiments/dryrun/*.json; the summary
table prints at the end and feeds EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.dryrun_all [--multipod] \
        [--archs a,b] [--shapes s1,s2] [--timeout 900]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import SHAPES, cell_applicable, get_config, list_archs

OUT = "experiments/dryrun"


def run_one(arch: str, shape: str, multipod: bool, timeout: int) -> dict:
    mesh = "2x16x16" if multipod else "16x16"
    fn = os.path.join(OUT, f"{arch}__{shape}__{mesh}.json")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", OUT]
    if multipod:
        cmd.append("--multipod")
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        ok = proc.returncode == 0
    except subprocess.TimeoutExpired:
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "status": "timeout", "wall_s": time.time() - t0}
    if os.path.exists(fn):
        with open(fn) as f:
            rec = json.load(f)
        rec["wall_s"] = time.time() - t0
        return rec
    return {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
            "stderr": proc.stderr[-1500:], "wall_s": time.time() - t0}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=",".join(
        a for a in list_archs() if a != "llava-onevision-0.5b"))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--multipod", action="store_true",
                    help="run the 2x16x16 mesh instead of 16x16")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args(argv)

    meshes = [False, True] if args.both else [args.multipod]
    rows = []
    for arch in args.archs.split(","):
        cfg = get_config(arch)
        for shape in args.shapes.split(","):
            for mp in meshes:
                mesh = "2x16x16" if mp else "16x16"
                fn = os.path.join(OUT, f"{arch}__{shape}__{mesh}.json")
                if args.skip_done and os.path.exists(fn):
                    with open(fn) as f:
                        rows.append(json.load(f))
                    print(f"[skip] {arch} {shape} {mesh}")
                    continue
                ok, why = cell_applicable(cfg, SHAPES[shape])
                if not ok:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "skipped", "reason": why}
                    os.makedirs(OUT, exist_ok=True)
                    with open(fn, "w") as f:
                        json.dump(rec, f)
                    rows.append(rec)
                    print(f"[skip-rule] {arch} {shape} {mesh}")
                    continue
                print(f"[run ] {arch} {shape} {mesh} ...", flush=True)
                rec = run_one(arch, shape, mp, args.timeout)
                rows.append(rec)
                print(f"       -> {rec.get('status')} "
                      f"({rec.get('wall_s', 0):.0f}s)", flush=True)

    n_ok = sum(r.get("status") == "ok" for r in rows)
    n_skip = sum(r.get("status") == "skipped" for r in rows)
    bad = [r for r in rows if r.get("status") not in ("ok", "skipped")]
    print(f"\n=== dry-run matrix: {n_ok} ok, {n_skip} skipped, "
          f"{len(bad)} failed ===")
    for r in bad:
        print(f"  FAIL {r['arch']} {r['shape']} {r['mesh']}: "
              f"{r.get('status')} {r.get('error', '')[:200]}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
