"""Mixed-class TABM engine smoke — part of the no-TPU gate (make check).

Drives one high-resolution and one thumbnail request through a reduced
``ServingEngine`` on placeholder devices, so the class-partitioned slot
pool path (core/slot_classes + core/tabm.SlotClassPool) is exercised by
CI: classification at submit, per-class staging threads, class-sized
ring commits, per-class release/drain.  Exits non-zero on any violation.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.smoke_classes
"""
from __future__ import annotations

import sys

import numpy as np


def main() -> int:
    import jax
    from repro.configs import get_config
    from repro.core.slot_classes import resolution_buckets
    from repro.launch.steps import init_params
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    buckets = resolution_buckets(cfg)
    thumb_tokens, full_tokens = buckets[0], buckets[-1]
    rng = np.random.default_rng(0)

    def feats(n_tokens):
        return rng.standard_normal(
            (1, n_tokens, cfg.vision_feat_dim)).astype(np.float32) * 0.02

    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng:
        print("slot classes (rings materialize lazily on first use):")
        for name, c in eng.tabm.classes.items():
            print(f"  {name:>12}: {c.n_images} img x {c.tokens_per_image} "
                  f"tok -> slab {c.max_tokens} tok, {c.n_slots} slots, "
                  f"{eng.tabm.class_nbytes(name)} B")
        assert not eng.tabm.rings              # nothing allocated yet
        hi = Request(rid=0, tokens=np.arange(8) + 3, max_new_tokens=4,
                     vision_feats=feats(full_tokens))
        thumb = Request(rid=1, tokens=np.arange(6) + 3, max_new_tokens=4,
                        vision_feats=feats(thumb_tokens))
        eng.submit(hi)
        eng.submit(thumb)
        done = eng.run()

        assert len(done) == 2, f"expected 2 finished requests, got {done}"
        for r in done:
            assert r.error is None, f"request {r.rid} failed: {r.error!r}"
            assert len(r.out_tokens) >= 4, f"request {r.rid} undergenerated"
        assert hi.slot_class != thumb.slot_class, (
            f"hi-res and thumbnail landed in one class "
            f"({hi.slot_class}) — partitioning is broken")
        hi_ring = eng.tabm.ring(hi.slot_class)
        th_ring = eng.tabm.ring(thumb.slot_class)
        assert hi_ring.max_tokens >= full_tokens > th_ring.max_tokens, (
            "thumbnail slab is not smaller than the full-resolution slab")
        assert hi_ring.stats["writes"] == th_ring.stats["writes"] == 1, (
            f"each class ring should carry exactly its own request: "
            f"hi={hi_ring.stats} thumb={th_ring.stats}")
        assert set(eng.tabm.rings) == {hi.slot_class, thumb.slot_class}, (
            f"only the classes traffic touched should have allocated "
            f"pools, got {list(eng.tabm.rings)}")
        print(f"classes used: hi-res={hi.slot_class} "
              f"thumbnail={thumb.slot_class}")
        print(f"per-class stats: hi={hi_ring.stats} thumb={th_ring.stats}")
        print(f"tokens: hi={hi.out_tokens} thumb={thumb.out_tokens}")
    print("OK: mixed-class engine smoke passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
