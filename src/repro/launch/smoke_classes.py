"""Mixed-class TABM engine smoke — part of the no-TPU gate (make check).

Default mode drives one high-resolution and one thumbnail request through
a reduced ``ServingEngine`` on placeholder devices, so the
class-partitioned slot pool path (core/slot_classes +
core/tabm.SlotClassPool) is exercised by CI: classification at submit,
per-class staging threads, class-sized ring commits, per-class
release/drain.  Exits non-zero on any violation.

``--stage-batch K`` (K > 1) runs the *batched staging* smoke instead:
eight queued same-class requests through the microbatching pipeline, and
asserts the acceptance evidence — at least one multi-request strided slab
commit (``slab_commit`` trace event + ring ``slab_commits`` stat) and at
least one batch>1 grouped prefill (``prefill_batch``), with greedy tokens
identical to the synchronous one-by-one oracle.

``--decode-cohort`` runs the *continuous-batching decode* smoke: five
mixed-class requests against a 2-slot paged KV pool, so the engine must
retire and admit mid-flight while the survivors keep decoding in the
same batched cohort step.  Asserts the acceptance evidence — a
``decode_cohort`` trace of size > 1, at least one retirement before a
later admission, >= 2 slot classes — and that every request's greedy
tokens equal the request decoded alone in its own engine.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.smoke_classes [--stage-batch 4 \
                                             | --decode-cohort]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _mixed_class_smoke(cfg, params) -> int:
    from repro.core.slot_classes import resolution_buckets
    from repro.serving.engine import Request, ServingEngine

    buckets = resolution_buckets(cfg)
    thumb_tokens, full_tokens = buckets[0], buckets[-1]
    rng = np.random.default_rng(0)

    def feats(n_tokens):
        return rng.standard_normal(
            (1, n_tokens, cfg.vision_feat_dim)).astype(np.float32) * 0.02

    with ServingEngine(cfg, params, n_slots=2, max_len=128) as eng:
        print("slot classes (rings materialize lazily on first use):")
        for name, c in eng.tabm.classes.items():
            print(f"  {name:>12}: {c.n_images} img x {c.tokens_per_image} "
                  f"tok -> slab {c.max_tokens} tok, {c.n_slots} slots, "
                  f"{eng.tabm.class_nbytes(name)} B")
        assert not eng.tabm.rings              # nothing allocated yet
        hi = Request(rid=0, tokens=np.arange(8) + 3, max_new_tokens=4,
                     vision_feats=feats(full_tokens))
        thumb = Request(rid=1, tokens=np.arange(6) + 3, max_new_tokens=4,
                        vision_feats=feats(thumb_tokens))
        eng.submit(hi)
        eng.submit(thumb)
        done = eng.run()

        assert len(done) == 2, f"expected 2 finished requests, got {done}"
        for r in done:
            assert r.error is None, f"request {r.rid} failed: {r.error!r}"
            assert len(r.out_tokens) >= 4, f"request {r.rid} undergenerated"
        assert hi.slot_class != thumb.slot_class, (
            f"hi-res and thumbnail landed in one class "
            f"({hi.slot_class}) — partitioning is broken")
        hi_ring = eng.tabm.ring(hi.slot_class)
        th_ring = eng.tabm.ring(thumb.slot_class)
        assert hi_ring.max_tokens >= full_tokens > th_ring.max_tokens, (
            "thumbnail slab is not smaller than the full-resolution slab")
        assert hi_ring.stats["writes"] == th_ring.stats["writes"] == 1, (
            f"each class ring should carry exactly its own request: "
            f"hi={hi_ring.stats} thumb={th_ring.stats}")
        assert set(eng.tabm.rings) == {hi.slot_class, thumb.slot_class}, (
            f"only the classes traffic touched should have allocated "
            f"pools, got {list(eng.tabm.rings)}")
        print(f"classes used: hi-res={hi.slot_class} "
              f"thumbnail={thumb.slot_class}")
        print(f"per-class stats: hi={hi_ring.stats} thumb={th_ring.stats}")
        print(f"tokens: hi={hi.out_tokens} thumb={thumb.out_tokens}")
    print("OK: mixed-class engine smoke passed")
    return 0


def _batched_staging_smoke(cfg, params, stage_batch: int) -> int:
    from repro.serving.engine import Request, ServingEngine

    n_reqs = 8

    def reqs():
        rng = np.random.default_rng(1)         # same feats in both runs
        return [Request(rid=i, tokens=np.arange(8) + 3, max_new_tokens=4,
                        vision_feats=rng.standard_normal(
                            (1, cfg.vision_tokens, cfg.vision_feat_dim)
                        ).astype(np.float32) * 0.02)
                for i in range(n_reqs)]

    batch = reqs()
    with ServingEngine(cfg, params, n_slots=4, max_len=128,
                       stage_batch=stage_batch) as eng:
        for r in batch:
            eng.submit(r)
        done = eng.run()
        assert len(done) == n_reqs and all(r.error is None for r in done)
        classes = {r.slot_class for r in batch}
        assert len(classes) == 1, f"expected one class, got {classes}"
        events = [(e, k) for e, k, _ in eng.trace]
        slabs = [k for e, k in events if e == "slab_commit"]
        prefills = [k for e, k in events if e == "prefill_batch"]
        ring = eng.tabm.ring(batch[0].slot_class)
        assert slabs and max(slabs) > 1, (
            f"no multi-request slab commit in the trace: {events}")
        assert ring.stats["slab_commits"] >= 1, ring.stats
        assert prefills and max(prefills) > 1, (
            f"no batch>1 prefill call in the trace: {events}")
        print(f"slab commits (K): {slabs}  grouped prefills (B): {prefills}")
        print(f"ring stats: {ring.stats}")
        batched_tokens = {r.rid: r.out_tokens for r in done}

    # the one-by-one oracle: sync staging (K=1) + batch-1 prefill groups
    oracle = reqs()
    with ServingEngine(cfg, params, n_slots=4, max_len=128,
                       async_staging=False, stage_batch=1) as eng:
        eng.executor.policy.full_batch = 1     # one admission per step
        for r in oracle:
            eng.submit(r)
        done = eng.run()
        assert all(r.error is None for r in done)
        oracle_tokens = {r.rid: r.out_tokens for r in done}
    assert batched_tokens == oracle_tokens, (
        f"batched staging changed greedy tokens:\n"
        f"  batched: {batched_tokens}\n  oracle:  {oracle_tokens}")
    print("OK: batched staging smoke passed (tokens == one-by-one oracle)")
    return 0


def _decode_cohort_smoke(cfg, params) -> int:
    from repro.serving.engine import Request, ServingEngine

    def reqs():
        out = []
        for rid, (n_tok, n_img, n_new, plen) in enumerate(
                [(8, 1, 6, 7), (2, 1, 3, 6), (32, 4, 5, 9),
                 (2, 1, 4, 8), (8, 1, 3, 6)]):
            rng = np.random.default_rng(rid)
            out.append(Request(
                rid=rid, tokens=(np.arange(plen) % 50 + 3).astype(np.int32),
                n_images=n_img, max_new_tokens=n_new,
                vision_feats=rng.standard_normal(
                    (1, n_tok, cfg.vision_feat_dim)
                ).astype(np.float32) * 0.02))
        return out

    batch = reqs()
    with ServingEngine(cfg, params, n_slots=2, max_len=128,
                       block_size=32) as eng:
        for r in batch:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 5, f"expected 5 finished, got {len(done)}"
        for r in done:
            assert r.error is None, f"request {r.rid} failed: {r.error!r}"
        classes = {r.slot_class for r in batch}
        assert len(classes) >= 2, f"expected >=2 classes, got {classes}"
        events = [(e, k) for e, k, _ in eng.trace]
        cohorts = [k for e, k in events if e == "decode_cohort"]
        assert max(cohorts) > 1, f"never decoded a cohort >1: {cohorts}"
        first_finish = next(i for i, (e, _) in enumerate(events)
                            if e == "finish")
        assert any(e == "prefill" and i > first_finish
                   for i, (e, _) in enumerate(events)), (
            "no mid-flight admission after the first retirement")
        eng.slots.check_block_invariants()
        cohort_tokens = {r.rid: r.out_tokens for r in done}
        print(f"classes: {sorted(classes)}  cohort sizes: {sorted(set(cohorts))}")
        print(f"paged pool: {eng.slots.n_blocks} blocks x "
              f"{eng.slots.block_size} tok, all free again")

    for ref in reqs():                         # the per-request oracle
        with ServingEngine(cfg, params, n_slots=2, max_len=128,
                           block_size=32) as eng:
            eng.submit(ref)
            eng.run()
            assert ref.error is None
            assert cohort_tokens[ref.rid] == ref.out_tokens, (
                f"request {ref.rid}: cohort decode changed greedy tokens\n"
                f"  cohort: {cohort_tokens[ref.rid]}\n"
                f"  alone:  {ref.out_tokens}")
    print("OK: decode-cohort smoke passed (tokens == per-request oracle, "
          "mid-flight admit/retire observed)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="class-partitioned TABM smoke")
    ap.add_argument("--stage-batch", type=int, default=1,
                    help="staging microbatch; >1 runs the batched-staging "
                         "smoke (strided slab commit + grouped prefill)")
    ap.add_argument("--decode-cohort", action="store_true",
                    help="run the continuous-batching decode smoke "
                         "(paged KV, mid-flight admit/retire, per-request "
                         "oracle equivalence)")
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_config
    from repro.launch.steps import init_params

    cfg = get_config("llava-onevision-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.decode_cohort:
        return _decode_cohort_smoke(cfg, params)
    if args.stage_batch > 1:
        return _batched_staging_smoke(cfg, params, args.stage_batch)
    return _mixed_class_smoke(cfg, params)


if __name__ == "__main__":
    sys.exit(main())
