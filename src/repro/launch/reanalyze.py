"""Rebuild dry-run JSON records from cached HLO dumps — no recompilation.

The perf-iteration loop edits the cost model / analysis far more often than
the programs themselves; this re-derives every experiments/dryrun/*.json
from experiments/hlo/*.hlo.gz in seconds.

    PYTHONPATH=src python -m repro.launch.reanalyze
"""
from __future__ import annotations

import glob
import gzip
import json
import os

from repro.analysis import hlo_cost, roofline as rl
from repro.configs import SHAPES, get_config

HLO_DIR = "experiments/hlo"
OUT_DIR = "experiments/dryrun"


def reanalyze_one(hlo_path: str) -> dict:
    base = os.path.basename(hlo_path)[: -len(".hlo.gz")]
    arch, shape, mesh = base.split("__")
    n_dev = 512 if mesh == "2x16x16" else 256
    cfg = get_config(arch)
    cell = SHAPES[shape]
    with gzip.open(hlo_path, "rt") as f:
        hlo = f.read()
    rep = hlo_cost.analyze(hlo, n_dev)
    stats = rl.CollectiveStats(
        raw_bytes={k: int(v) for k, v in rep.coll_raw.items()},
        transfer_bytes={k: int(v) for k, v in rep.coll_transfer.items()},
        count={k: int(v) for k, v in rep.coll_count.items()})
    fn = os.path.join(OUT_DIR, f"{base}.json")
    old = {}
    if os.path.exists(fn):
        with open(fn) as f:
            old = json.load(f)
    roof = rl.Roofline(
        arch=arch, shape=shape, mesh=mesh, n_devices=n_dev,
        flops_per_device=rep.flops, bytes_per_device=rep.traffic_bytes,
        collective=stats, model_flops=rl.model_flops_for(cfg, cell),
        attn_flops=rl.attn_flops_for(cfg, cell),
        ideal_bytes=rl.ideal_serve_bytes(cfg, cell),
        n_params=cfg.n_params(), n_params_active=cfg.n_active_params(),
        memory_per_device=old.get("memory_per_device"))
    rec = dict(old)
    rec.update(roof.to_dict())
    rec.update(status="ok",
               traffic_bytes_raw=rep.traffic_bytes_raw,
               top_collectives=rep.top_collectives[:12],
               top_dots=rep.top_dots[:8],
               top_traffic=rep.top_traffic[:12])
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    paths = sorted(glob.glob(os.path.join(HLO_DIR, "*.hlo.gz")))
    for p in paths:
        rec = reanalyze_one(p)
        print(f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
              f"t_comp={rec['t_compute_s']*1e3:9.1f}ms "
              f"t_mem={rec['t_memory_s']*1e3:9.1f}ms "
              f"t_coll={rec['t_collective_s']*1e3:9.1f}ms "
              f"{rec['bottleneck']:10s} "
              f"useful={rec['useful_flops_ratio']:7.1%} "
              f"roofline={rec['roofline_fraction']:7.2%}")


if __name__ == "__main__":
    main()
