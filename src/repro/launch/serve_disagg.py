import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Disaggregated pod serving — the paper's NPU/GPU split at mesh scale.

The pod's "model" axis is sliced into two profile-heterogeneous submeshes
(core/scheduler.make_virtual_accelerators): the encoder slice runs the
static-shape vision brick (≙ the paper's NPU), the decoder slice runs the
W4A16 language model (≙ the GPU).  The hand-off is the TABM edge:

    encoder submesh --(SubmeshPipe: sharding-preserving device_put,
                       pure ICI, no host round trip)--> ring slot
                    --(zero-copy bind)--> decoder prefill

Runs on 8 placeholder devices in-container; the identical code drives a
256-chip pod.

    PYTHONPATH=src python -m repro.launch.serve_disagg
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.scheduler import SubmeshPipe, make_virtual_accelerators
from repro.core.tabm import RingBuffer
from repro.launch.steps import init_params
from repro.models import model as M


def main():
    cfg = get_config("llava-onevision-0.5b").reduced()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    enc_acc, dec_acc = make_virtual_accelerators(mesh, fractions=(0.25, 0.75))
    print(f"pod mesh {mesh.devices.shape}; encoder submesh "
          f"{enc_acc.mesh.devices.shape}, decoder submesh "
          f"{dec_acc.mesh.devices.shape}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    # encoder brick weights live on the encoder submesh; decoder weights on
    # the decoder submesh — module-level placement, the paper's core move
    enc_params = jax.device_put(
        params["vis_proj"], NamedSharding(enc_acc.mesh, P()))
    dec_params = jax.device_put(
        {k: v for k, v in params.items() if k != "vis_proj"},
        NamedSharding(dec_acc.mesh, P()))

    @jax.jit
    def encode(vp, feats):
        v = jax.nn.gelu(jnp.einsum("bnf,fd->bnd",
                                   feats.astype(cfg.compute_dtype),
                                   vp["w1"]))
        return jnp.einsum("bnd,de->bne", v, vp["w2"])

    def prefill(p, tokens, vision_embeds):
        x = p["embed"][tokens]
        x = jnp.concatenate([vision_embeds.astype(x.dtype),
                             x[:, vision_embeds.shape[1]:]], axis=1)
        from repro.models.common import default_positions
        from repro.models import decoder as dec
        rope_fn = M.make_rope_fn(cfg, default_positions(*tokens.shape),
                                 None)
        x, caches, _ = dec.stack_forward(p["layers"], cfg, x, rope_fn,
                                         causal=True, want_cache=True,
                                         decode_len=96, remat=False)
        return M._head(p, cfg, x[:, -1:])[:, 0], \
            {"layers": caches, "index": jnp.asarray(tokens.shape[1],
                                                    jnp.int32)}

    prefill = jax.jit(prefill)
    decode = jax.jit(lambda p, t, c: M.lm_decode_step(p, cfg, t, c),
                     donate_argnums=(2,))

    # TABM pool lives decoder-side; the pipe moves encoder output over ICI
    pipe = SubmeshPipe(enc_acc, dec_acc, P())
    ring = RingBuffer(n_slots=2, max_tokens=cfg.vision_tokens,
                      dim=cfg.d_model,
                      sharding=NamedSharding(dec_acc.mesh, P()))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for event in range(3):
        feats = jnp.asarray(rng.standard_normal(
            (1, cfg.vision_tokens, cfg.vision_feat_dim)) * 0.02,
            jnp.float32)
        # 1. encoder brick on the "NPU" submesh
        emb = encode(enc_params, jax.device_put(
            feats, NamedSharding(enc_acc.mesh, P())))
        # 2. ICI hand-off + TABM slot (zero-copy via donation)
        emb_dec = pipe.transfer(emb)
        slot = ring.acquire_write()
        ring.commit_write(slot, emb_dec[0])
        got = ring.acquire_read()
        s, view, n = got
        # 3. decoder prefill binds the slot; then a few decode steps
        tokens = jnp.asarray(rng.integers(3, 200, (1, 16)), jnp.int32)
        logits, cache = prefill(dec_params, tokens, view[None, :n])
        out = [int(jnp.argmax(logits[0]))]
        for _ in range(5):
            lg, cache = decode(dec_params,
                               jnp.asarray([[out[-1]]], jnp.int32), cache)
            out.append(int(jnp.argmax(lg[0])))
        ring.release(s)
        print(f"event {event}: encoder@{enc_acc.mesh.devices.shape} -> "
              f"tabm slot {s} -> decoder@{dec_acc.mesh.devices.shape}: "
              f"{out}")
    print(f"3 events in {time.time()-t0:.1f}s; tabm stats {ring.stats}")
    assert ring.stats["writes"] == ring.stats["reads"] == 3
    print("OK: disaggregated encoder/decoder submesh pipeline")


if __name__ == "__main__":
    main()
