import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Disaggregated two-fleet serving: prefill fleet -> Transport -> decode
fleet.

The fleet-scale topology ("Cost-Efficient Multimodal LLM Inference via
Cross-Tier GPU Heterogeneity", PAPERS.md): a
:class:`~repro.serving.disagg.PrefillWorker` stages vision encode ->
projector -> grouped batched prefill on a compute-rich fleet and streams
each request — committed TABM slab + the *written* KV blocks + block
grant, never a whole ``max_len`` lane — over a serialized
:class:`~repro.core.transport.Transport` to a
:class:`~repro.serving.disagg.DecodeWorker` that admits straight into
its own paged pool and cohort-decodes.  Both fleets are ordinary
``ServingEngine`` instances on per-ordinal device backends
(``device:0`` / ``device:1`` — ``core/backends.device_backend``), so a
multi-device box is the degenerate single-host case; the scheduler's
split pricing (``core/scheduler.schedule_split``) is printed for the
chosen transport.

Every run asserts the acceptance bar:

* greedy decode tokens are **bit-identical** to a fresh single-process
  ``ServingEngine`` oracle, per request, across >= 2 slot classes;
* the paged KV bytes that crossed the wire are **less** than shipping
  whole ``max_len`` lanes (``PagedKVCache.slot_lane_bytes``).

    PYTHONPATH=src python -m repro.launch.serve_disagg \
        --transport {inproc,pipe,socket} --requests 4

``--transport pipe`` / ``socket`` spawn the decode fleet as a real
subprocess (``--role decode`` plus fd / port plumbing below) that
re-initializes identical params from the same seed — nothing but frames
crosses the boundary.
"""
import argparse
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.bricks import decompose
from repro.core.scheduler import populate_brick_bytes, schedule_split
from repro.core.transport import PipeTransport, SocketTransport
from repro.launch.steps import init_params
from repro.serving.disagg import DecodeWorker, PrefillWorker, \
    serve_disagg_inproc
from repro.serving.engine import Request, ServingEngine

ENGINE_KW = dict(n_slots=4, max_len=256, block_size=32)


def make_requests(cfg, n: int, max_new: int):
    """>= 2 slot classes: thumbnails (1 image) interleaved with 4-image
    full-resolution requests, varying prompt lengths."""
    reqs = []
    for i in range(n):
        rng = np.random.default_rng(i)
        hi = i % 2 == 1
        plen = 6 + (i % 3)
        reqs.append(Request(
            rid=i, tokens=(np.arange(plen) % 50 + 3).astype(np.int32),
            n_images=4 if hi else 1,
            max_new_tokens=max_new + (i % 2),
            vision_feats=rng.standard_normal(
                (1, 32 if hi else 8, cfg.vision_feat_dim)
            ).astype(np.float32) * 0.02))
    return reqs


def oracle_tokens(cfg, params, reqs):
    """The single-process baseline: same engine geometry, no wire."""
    with ServingEngine(cfg, params, **ENGINE_KW) as eng:
        for r in reqs:
            eng.submit(r)
        done = eng.run()
    assert all(r.error is None for r in done), \
        [(r.rid, r.error) for r in done]
    return {r.rid: list(r.out_tokens) for r in done}


def run_decode_fleet(args):
    """The decode-fleet subprocess (``--role decode``): identical params
    re-initialized from the shared seed; only frames cross the wire."""
    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.transport == "pipe":
        tr = PipeTransport(args.recv_fd, args.send_fd)
    elif args.transport == "socket":
        tr = SocketTransport.connect("127.0.0.1", args.port)
    else:
        raise SystemExit("--role decode needs --transport pipe|socket")
    worker = DecodeWorker(cfg, params, tr, **ENGINE_KW)
    results = worker.run()
    ok = sum(1 for r in results.values() if r.error is None)
    print(f"[decode-fleet] served {ok}/{len(results)} requests, "
          f"{worker.engine.stats.decoded_tokens} decode tokens")
    tr.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llava-onevision-0.5b",
                    choices=list_archs())
    ap.add_argument("--transport", default="inproc",
                    choices=["inproc", "pipe", "socket"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=4)
    # decode-fleet subprocess plumbing (not for direct use)
    ap.add_argument("--role", default="prefill",
                    choices=["prefill", "decode"], help=argparse.SUPPRESS)
    ap.add_argument("--recv-fd", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--send-fd", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--port", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.role == "decode":
        run_decode_fleet(args)
        return

    if args.requests < 3:
        raise SystemExit("--requests must be >= 3 (the smoke's floor)")
    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)

    # the scheduler's split pricing for this wire: the same chain DP as
    # any placement, over the two fleet rows priced at the transport's
    # link_bw — a fast wire pulls the static bricks onto the prefill
    # fleet, a slow one keeps them co-located
    graph = decompose(cfg)
    populate_brick_bytes(graph, params)
    split = schedule_split(graph, args.transport,
                           n_tokens=cfg.vision_tokens)
    print(f"[schedule_split @ {args.transport}] {split}")

    reqs = make_requests(cfg, args.requests, args.max_new)
    oracle = oracle_tokens(cfg, params, make_requests(
        cfg, args.requests, args.max_new))

    t0 = time.time()
    child = None
    if args.transport == "inproc":
        # degenerate single-host case: each fleet's engine on its OWN
        # device ordinal (device:0 / device:1 — per-accelerator streams)
        results, stats = serve_disagg_inproc(
            cfg, params, reqs,
            prefill_kwargs=dict(backend="device:0", **ENGINE_KW),
            decode_kwargs=dict(backend="device:1", **ENGINE_KW))
    else:
        base_cmd = [sys.executable, "-m", "repro.launch.serve_disagg",
                    "--role", "decode", "--transport", args.transport,
                    "--arch", args.arch]
        if args.transport == "pipe":
            a2b_r, a2b_w = os.pipe()
            b2a_r, b2a_w = os.pipe()
            child = subprocess.Popen(
                base_cmd + ["--recv-fd", str(a2b_r),
                            "--send-fd", str(b2a_w)],
                pass_fds=(a2b_r, b2a_w))
            os.close(a2b_r)
            os.close(b2a_w)
            tr = PipeTransport(b2a_r, a2b_w)
        else:
            srv, port = SocketTransport.listen()
            child = subprocess.Popen(base_cmd + ["--port", str(port)])
            tr = SocketTransport.accept(srv, timeout=120.0)
            srv.close()
        pre = PrefillWorker(cfg, params, tr, **ENGINE_KW)
        for r in reqs:
            pre.submit(r)
        stats = pre.run()
        results = pre.collect(len(reqs))
        stats.wire_seconds = tr.send_seconds
        stats.transport = tr.name
        pre.engine.shutdown()
        tr.close()
    wall = time.time() - t0
    if child is not None:
        assert child.wait(timeout=300) == 0, "decode fleet exited nonzero"

    # acceptance: bit-identical greedy tokens, across >= 2 slot classes
    classes = {r.slot_class for r in reqs}
    assert len(classes) >= 2, f"need >= 2 slot classes, got {classes}"
    for r in reqs:
        got = results.get(r.rid)
        assert got is not None and got.error is None, \
            f"request {r.rid} failed: {got and got.error}"
        assert got.tokens == oracle[r.rid], (
            f"request {r.rid} tokens diverged over {args.transport}: "
            f"{got.tokens} != oracle {oracle[r.rid]}")
    # acceptance: only granted/written blocks crossed, never whole lanes
    lane_total = stats.sent * stats.lane_bytes_baseline
    assert stats.kv_wire_bytes < lane_total, (
        f"wire shipped {stats.kv_wire_bytes}B of KV, whole lanes would "
        f"be {lane_total}B — paged export is not saving bytes")
    print(f"[prefill-fleet] {stats.sent} prefills shipped, "
          f"{stats.wire_bytes}B on the wire "
          f"({stats.kv_wire_bytes}B paged KV vs {lane_total}B whole-lane "
          f"baseline), {len(classes)} slot classes, {wall:.1f}s")
    # feedback edge: reprice the split from what the frames actually
    # clocked (measured bytes/s over the static transport class row)
    if stats.wire_seconds > 0 and stats.wire_bytes > 0:
        from repro.telemetry.calibration import CostCalibration
        cal = CostCalibration()
        cal.observe_link(stats.transport, stats.wire_bytes,
                         stats.wire_seconds, n=max(1, stats.sent))
        mbw = stats.wire_bytes / stats.wire_seconds
        split2 = schedule_split(graph, args.transport,
                                n_tokens=cfg.vision_tokens,
                                calibration=cal)
        print(f"[schedule_split recalibrated @ {mbw / 1e6:.0f} MB/s "
              f"measured] {split2}")
    print(f"OK: disaggregated prefill/decode fleets over "
          f"{args.transport}: {len(reqs)} requests bit-identical to the "
          f"single-process oracle")


if __name__ == "__main__":
    main()
