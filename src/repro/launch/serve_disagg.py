import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Disaggregated pod serving — the paper's NPU/GPU split at mesh scale.

The pod's "model" axis is sliced into two profile-heterogeneous submeshes
(core/scheduler.make_virtual_accelerators): the encoder slice runs the
static-shape vision bricks (≙ the paper's NPU), the decoder slice runs the
W4A16 language model (≙ the GPU).  The placement is no longer only
cost-modeled: it compiles to an ExecutionPlan through the SubmeshBackend
(the accelerators' ``backend="submesh"`` profile — core/backends.py) whose
brick weights are device_put onto their submesh and whose cross-submesh
edges are SubmeshPipes, so the hand-off really moves over ICI:

    encoder submesh --(SubmeshPipe: sharding-preserving device_put,
                       pure ICI, no host round trip)--> ring slot
                    --(zero-copy bind)--> decoder prefill

Runs on 8 placeholder devices in-container; the identical code drives a
256-chip pod.

    PYTHONPATH=src python -m repro.launch.serve_disagg
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.bricks import decompose
from repro.core.plan import compile_plan
from repro.core.scheduler import (make_virtual_accelerators,
                                  populate_brick_bytes, schedule)
from repro.core.tabm import RingBuffer
from repro.launch.steps import init_params
from repro.models import model as M


def main():
    cfg = get_config("llava-onevision-0.5b").reduced()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    accels = make_virtual_accelerators(mesh, fractions=(0.25, 0.75))
    enc_acc, dec_acc = accels
    print(f"pod mesh {mesh.devices.shape}; encoder submesh "
          f"{enc_acc.mesh.devices.shape}, decoder submesh "
          f"{dec_acc.mesh.devices.shape}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    graph = decompose(cfg)
    populate_brick_bytes(graph, params)
    # the cost model's own pick, for reference
    print("scheduler:", schedule(graph, accels,
                                 n_tokens=cfg.vision_tokens))
    # module-level placement, the paper's core move: static-shape vision
    # bricks on the encoder submesh, the language model decoder-side
    assignment = {b.name: (enc_acc.name if b.static_shape else dec_acc.name)
                  for b in graph.bricks}

    # TABM pool lives decoder-side; the plan's SubmeshPipe moves encoder
    # output over ICI into the ring
    ring = RingBuffer(n_slots=2, max_tokens=cfg.vision_tokens,
                      dim=cfg.d_model,
                      sharding=NamedSharding(dec_acc.mesh, P()))
    plan = compile_plan(graph, params, placement=assignment, accels=accels,
                        tabm=ring)
    print("plan:", plan.describe())

    # decoder-side weights come from the plan's placement binding (already
    # device_put onto the decoder submesh) — prefill/decode keep their own
    # cache-building compiled fns over those bound params
    dec_params = {}
    for name in ("embedding", "decoder", "head"):
        dec_params.update(plan.brick_params(name))

    def prefill(p, tokens, vision_embeds):
        x = p["embed"][tokens]
        x = jnp.concatenate([vision_embeds.astype(x.dtype),
                             x[:, vision_embeds.shape[1]:]], axis=1)
        from repro.models.common import default_positions
        from repro.models import decoder as dec
        rope_fn = M.make_rope_fn(cfg, default_positions(*tokens.shape),
                                 None)
        x, caches, _ = dec.stack_forward(p["layers"], cfg, x, rope_fn,
                                         causal=True, want_cache=True,
                                         decode_len=96, remat=False)
        return M._head(p, cfg, x[:, -1:])[:, 0], \
            {"layers": caches, "index": jnp.asarray(tokens.shape[1],
                                                    jnp.int32)}

    prefill = jax.jit(prefill)
    decode = jax.jit(lambda p, t, c: M.lm_decode_step(p, cfg, t, c),
                     donate_argnums=(2,))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for event in range(3):
        feats = jnp.asarray(rng.standard_normal(
            (1, cfg.vision_tokens, cfg.vision_feat_dim)) * 0.02,
            jnp.float32)
        # 1+2. producer half: frontend + projector bricks on the "NPU"
        # submesh, ICI hand-off, TABM commit (zero-copy via donation)
        slot = plan.produce({"vision_feats": feats})
        assert slot is not None
        # 3. consumer half: decoder prefill binds the slot; then decode
        s, view, n = plan.consume()
        tokens = jnp.asarray(rng.integers(3, 200, (1, 16)), jnp.int32)
        logits, cache = prefill(dec_params, tokens, view[None, :n])
        out = [int(jnp.argmax(logits[0]))]
        for _ in range(5):
            lg, cache = decode(dec_params,
                               jnp.asarray([[out[-1]]], jnp.int32), cache)
            out.append(int(jnp.argmax(lg[0])))
        plan.release(s)
        print(f"event {event}: encoder@{enc_acc.mesh.devices.shape} -> "
              f"tabm slot {s} -> decoder@{dec_acc.mesh.devices.shape}: "
              f"{out}")
    print(f"3 events in {time.time()-t0:.1f}s; tabm stats {ring.stats}")
    assert ring.stats["writes"] == ring.stats["reads"] == 3
    print("OK: disaggregated encoder/decoder submesh pipeline")


if __name__ == "__main__":
    main()
