"""Backend lowering matrix — the no-TPU gate for core/backends.

Lowers one reduced vlm BrickGraph through each requested backend
(HostBackend, DeviceBackend, and — given >= 2 placeholder devices — the
SubmeshBackend over a real submesh split), runs one forward per lowering,
and cross-checks the logits agree.  Wired into scripts/check.sh so no
backend path can rot without TPU hardware.

    PYTHONPATH=src python -m repro.launch.dryrun_backends \
        --arch llava-onevision-0.5b --backends host,device,submesh
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# ^ must run before any jax import — jax locks the device count at init

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.bricks import decompose
from repro.core.plan import compile_plan
from repro.core.scheduler import make_virtual_accelerators
from repro.launch.steps import init_params


def lower_and_run(cfg, graph, params, inputs, name: str):
    """Compile the graph under one backend lowering; return its logits."""
    if name == "submesh":
        mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
        accels = make_virtual_accelerators(mesh, fractions=(0.25, 0.75))
        enc, dec = accels
        assignment = {b.name: (enc.name if b.static_shape else dec.name)
                      for b in graph.bricks}
        plan = compile_plan(graph, params, placement=assignment,
                            accels=accels)
    else:
        plan = compile_plan(graph, params, backend=name)
    got = {s.backend.name for s in plan.steps}
    assert got == {name if name != "submesh" else "submesh"}, got
    out, _ = plan.run(inputs)
    print(f"  {name:8s} OK  logits{tuple(out.shape)}  "
          f"[{plan.describe()[:72]}...]")
    return np.asarray(out, np.float32)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llava-onevision-0.5b",
                    choices=list_archs())
    ap.add_argument("--backends", default="host,device",
                    help="comma list of host|device|submesh")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    if not cfg.vlm:
        raise SystemExit("dryrun_backends exercises the vlm chain "
                         "(vision -> projector -> decoder)")
    params = init_params(jax.random.PRNGKey(0), cfg)
    graph = decompose(cfg)
    rng = np.random.default_rng(0)
    inputs = {
        "tokens": jnp.asarray(rng.integers(3, 200, (1, 24)), jnp.int32),
        "vision_feats": jnp.asarray(
            rng.standard_normal(
                (1, cfg.vision_tokens, cfg.vision_feat_dim)) * 0.02,
            jnp.float32)}

    names = [b.strip() for b in args.backends.split(",") if b.strip()]
    if "submesh" in names and jax.device_count() < 2:
        print("  submesh  SKIP (needs >= 2 devices)")
        names.remove("submesh")
    print(f"backend matrix for {args.arch} on "
          f"{jax.device_count()} {jax.default_backend()} device(s): {names}")
    outs = {n: lower_and_run(cfg, graph, params, inputs, n) for n in names}

    ref_name, ref = next(iter(outs.items()))
    for n, out in outs.items():
        np.testing.assert_allclose(out, ref, rtol=2e-2, atol=2e-2,
                                   err_msg=f"{n} vs {ref_name}")
    print(f"OK: {len(outs)} backend lowerings agree ({', '.join(outs)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
