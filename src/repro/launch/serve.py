"""Serving launcher: continuous-batching engine + battery-aware policy.

    PYTHONPATH=src python -m repro.launch.serve --arch llava-onevision-0.5b \
        --requests 16 --battery 0.9

Submits synthetic prompts (+ stub vision features for vlm archs), runs the
engine to completion, prints the paper's metrics (tokens/s, end-to-end
latency, memory, modeled watts/hours).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.analysis.energy import EDGE_GPU, hours_on_battery, watts
from repro.configs import get_config, list_archs
from repro.core.power import BatteryAwareExecutor, PMU
from repro.launch.steps import init_params
from repro.serving.engine import Request, ServingEngine
from repro.telemetry.calibration import CostCalibration


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llava-onevision-0.5b",
                    choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--battery", type=float, default=1.0)
    ap.add_argument("--quantize", default=None,
                    choices=[None, "nanomind-default", "all-q4", "dec-q2"])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="persist wall-clock cost calibration across "
                         "restarts: load PATH if it exists, feed it to "
                         "the engine's energy governor, and atomically "
                         "re-save the measured table on shutdown")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if cfg.encdec:
        raise SystemExit("serve: decoder-only archs (enc-dec via examples/)")
    if not args.full:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.quantize:
        from repro.core.quantize import PROFILES, quantize_tree, \
            dequantize_tree
        params = dequantize_tree(quantize_tree(params,
                                               PROFILES[args.quantize]))

    executor = BatteryAwareExecutor(PMU())
    executor.pmu.level = args.battery
    calibration = None
    if args.calibration and os.path.exists(args.calibration):
        calibration = CostCalibration.load(args.calibration)
        print(f"[serve] loaded calibration from {args.calibration} "
              f"({len(calibration)} entries)")
    eng = ServingEngine(cfg, params, n_slots=args.slots,
                        max_len=args.max_len, executor=executor,
                        calibration=calibration)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        n = int(rng.integers(8, 64))
        req = Request(rid=i, tokens=rng.integers(
            3, cfg.vocab_size - 1, n).astype(np.int32),
            max_new_tokens=args.max_new)
        if cfg.vlm:
            req.vision_feats = rng.standard_normal(
                (1, cfg.vision_tokens, cfg.vision_feat_dim)
            ).astype(np.float32) * 0.02
        eng.submit(req)

    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    lat = [r.e2e_latency for r in done if r.e2e_latency]
    mem = eng.memory_bytes()
    state, knobs, objective = executor.current()
    print(f"[serve] {args.arch} battery={args.battery:.0%} state={state.value}"
          f" objective={objective}")
    print(f"  finished={len(done)}/{args.requests} wall={wall:.1f}s "
          f"throughput={eng.stats.decoded_tokens / wall:.1f} tok/s")
    if lat:
        print(f"  e2e latency: mean={np.mean(lat):.2f}s p95="
              f"{np.percentile(lat, 95):.2f}s")
    print(f"  memory: weights={mem['weights']/1e6:.1f}MB "
          f"kv={mem['kv_pool']/1e6:.1f}MB tabm={mem['tabm']/1e6:.2f}MB")
    if eng.tabm is not None:
        # every vision hand-off really went through the ring: writes ==
        # reads == served vlm requests, stalls = producer backpressure
        print(f"  tabm ring: {eng.tabm.stats}")
    if args.calibration:
        # fold this run's wall-clock probes on top of whatever table we
        # loaded, so the file converges across restarts (save is atomic:
        # tmp + os.replace)
        table = eng.measured_calibration()
        if calibration is not None:
            for key, s in table.to_dict()["table"].items():
                brick, _, prof = key.rpartition("@")
                calibration.observe(brick, prof or None, s["seconds"],
                                    s["tokens"], s["joules"], n=s["n"])
            table = calibration
        table.save(args.calibration)
        print(f"  calibration: saved {len(table)} entries to "
              f"{args.calibration}")


if __name__ == "__main__":
    main()
