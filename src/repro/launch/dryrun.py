import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512"
                               ).strip()
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The lines above MUST run before any jax import — jax locks the device
count at first init.  512 placeholder host devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh; unrelated pre-set
XLA_FLAGS are preserved, and a pre-set device count wins so ``--reduced``
CI runs can use 8 devices — see scripts/check.sh.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
        --shape train_4k [--multipod] [--out experiments/dryrun]

Succeeding here proves the distribution config is coherent: the sharding
rules satisfy the partitioner for every cell, and memory_analysis() shows it
fits.  cost_analysis() + the HLO collective parse feed §Roofline.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.analysis import roofline as rl
from repro.configs import SHAPES, cell_applicable, get_config, list_archs
from repro.distributed import sharding as sh
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.training.optimizer import OptConfig

from jax.sharding import NamedSharding, PartitionSpec as P


def opt_config_for(cfg) -> OptConfig:
    """bf16 adam moments for the >=100B archs (fits jamba-398B on one pod —
    DESIGN.md §6); fp32 otherwise."""
    big = cfg.n_params() > 60e9
    return OptConfig(state_dtype="bfloat16" if big else "float32")


def lower_cell(cfg, cell, mesh, *, verbose=True, quant=None):
    """Returns (lowered, compiled, aux) for one cell on one mesh.

    quant: quantization profile name (e.g. "nanomind-default") — the
    paper's W4A16 serving path: packed-int weights lower as model inputs
    and dequantize in-register inside the layer scan."""
    batch_sds = st.input_specs(cfg, cell)
    bspecs = sh.tree_batch_specs(mesh, batch_sds)
    batch_in = sh.with_specs(batch_sds, bspecs, mesh)

    params_sds = st.abstract_params(cfg, quant_policy=quant)
    pspecs = sh.tree_param_specs(mesh, params_sds)
    params_in = sh.with_specs(params_sds, pspecs, mesh)
    pshard = sh.tree_shardings(mesh, pspecs)

    if cell.kind == "train":
        opt_cfg = opt_config_for(cfg)
        opt_sds = st.abstract_opt(cfg, opt_cfg, params_sds)
        ospecs = sh.tree_param_specs(mesh, opt_sds)
        opt_in = sh.with_specs(opt_sds, ospecs, mesh)
        oshard = sh.tree_shardings(mesh, ospecs)
        fn = st.build_train_step(cfg, opt_cfg)
        jitted = jax.jit(fn, donate_argnums=(0, 1),
                         out_shardings=(pshard, oshard, None))
        lowered = jitted.lower(params_in, opt_in, batch_in)
    elif cell.kind == "prefill":
        cache_sds = st.abstract_cache(cfg, cell.global_batch, cell.seq_len)
        cspecs = sh.tree_cache_specs(mesh, cache_sds)
        cshard = sh.tree_shardings(mesh, cspecs)
        logits_shard = NamedSharding(
            mesh, sh.batch_spec(mesh, "logits",
                                (cell.global_batch, cfg.padded_vocab)))
        fn = st.build_prefill_step(cfg, cell.seq_len)
        jitted = jax.jit(fn, out_shardings=(logits_shard, cshard))
        lowered = jitted.lower(params_in, batch_in)
    else:  # decode / serve
        cache_sds = st.abstract_cache(cfg, cell.global_batch, cell.seq_len)
        cspecs = sh.tree_cache_specs(mesh, cache_sds)
        cache_in = sh.with_specs(cache_sds, cspecs, mesh)
        cshard = sh.tree_shardings(mesh, cspecs)
        logits_shard = NamedSharding(
            mesh, sh.batch_spec(mesh, "logits",
                                (cell.global_batch, cfg.padded_vocab)))
        fn = st.build_serve_step(cfg)
        jitted = jax.jit(fn, donate_argnums=(2,),
                         out_shardings=(logits_shard, cshard))
        lowered = jitted.lower(params_in, batch_in["tokens"], cache_in)
    compiled = lowered.compile()
    return lowered, compiled


def mem_per_device(compiled, n_devices):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None, None
    if ma is None:
        return None, None
    fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            fields[f] = int(v)
    total = (fields.get("argument_size_in_bytes", 0)
             + fields.get("temp_size_in_bytes", 0)
             + fields.get("output_size_in_bytes", 0)
             - fields.get("alias_size_in_bytes", 0))
    return total, fields


def pick_mode(cfg, cell, requested: str = "auto") -> str:
    """Sharding mode per cell (see distributed/sharding.py).

    auto: "serve" for decode cells when the model-parallel-only weights fit
    (<12 GB/dev) — replicating over "data" kills the per-token FSDP
    regather; "tp" otherwise (the paper-faithful baseline layout)."""
    if requested != "auto":
        return requested
    if cell.kind == "decode" and cfg.n_params() * 2 / 16 < 12e9:
        return "serve"
    return "tp"


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir=None,
             verbose=True, mode: str = "tp", overrides=None, quant=None,
             reduced=False):
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if reduced:
        # CI-sized cell: same lower+compile+roofline path, 8 host devices
        cfg = cfg.reduced()
        cell = dataclasses.replace(cell, name=cell.name + "-reduced",
                                   seq_len=min(cell.seq_len, 256),
                                   global_batch=min(cell.global_batch, 8))
        mesh_name = "2x4"
    ok, why = cell_applicable(cfg, cell)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "mode": mode,
           "quant": quant}
    sh.set_mode(mode)
    if not ok:
        rec.update(status="skipped", reason=why)
        if verbose:
            print(f"[{arch} x {shape} x {mesh_name}] SKIP: {why}")
        return rec

    if reduced:
        mesh = jax.make_mesh((2, 4), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    try:
        with mesh:
            lowered, compiled = lower_cell(cfg, cell, mesh, verbose=verbose,
                                           quant=quant)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{arch} x {shape} x {mesh_name}] FAIL {type(e).__name__}: {e}")
        return rec
    t_compile = time.time() - t0

    mem_total, mem_fields = mem_per_device(compiled, n_dev)
    extra = {}
    roof = rl.build(arch, shape, mesh_name, n_dev, compiled, cfg, cell,
                    mem_per_device=mem_total, extra=extra)
    rec.update(status="ok", compile_s=round(t_compile, 1),
               memory_fields=mem_fields, **roof.to_dict(), **extra)
    if out_dir:
        # cache the partitioned HLO so analysis iterations skip recompiles
        import gzip
        hlo_dir = os.path.join(os.path.dirname(out_dir.rstrip("/")), "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        with gzip.open(os.path.join(
                hlo_dir, f"{arch}__{shape}__{mesh_name}.hlo.gz"), "wt") as f:
            f.write(compiled.as_text())
    if verbose:
        print(f"compile={t_compile:.0f}s mem/dev="
              f"{(mem_total or 0)/1e9:.2f}GB " + roof.summary())
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mode", default="tp",
                    choices=["tp", "fsdp", "serve", "auto"])
    ap.add_argument("--override", default=None,
                    help="comma-separated cfg overrides, e.g. n_heads=32")
    ap.add_argument("--quant", default=None,
                    help="quant profile for serving cells, e.g. "
                         "nanomind-default (the paper's W4A16)")
    ap.add_argument("--print-hlo", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="CI mode: reduced config + shrunken cell on a 2x4 "
                         "mesh (set XLA_FLAGS device_count=8 in the env)")
    args = ap.parse_args(argv)
    mode = pick_mode(get_config(args.arch), SHAPES[args.shape], args.mode)
    overrides = {}
    if args.override:
        import dataclasses as _dc
        for kv in args.override.split(","):
            k, v = kv.split("=")
            overrides[k] = type(getattr(get_config(args.arch), k))(v) \
                if not isinstance(getattr(get_config(args.arch), k), bool) \
                else v.lower() == "true"
    rec = run_cell(args.arch, args.shape, args.multipod, args.out, mode=mode,
                   overrides=overrides, quant=args.quant,
                   reduced=args.reduced)
    if rec.get("status") == "error":
        print(rec.get("traceback", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
