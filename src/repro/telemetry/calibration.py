"""Measured per-brick cost tables the scheduler consults.

The feedback edge of the telemetry subsystem: wall-time (and, when the
fleet simulator supplies them, energy) observations keyed by
``(brick, energy-profile)`` that ``core/scheduler.brick_cost`` blends
with its modeled roofline numbers — measured overrides modeled as the
sample count grows:

    w = n / (n + prior)          # 0 samples -> pure model,
    cost = (1-w)*modeled + w*measured    # n >> prior -> pure measurement

Lookup falls back from the exact ``(brick, profile)`` key to
``(brick, None)``: a probe that cannot attribute an accelerator (the
engine's default single-substrate plan) still calibrates every
candidate placement of that brick.

Only stdlib imports here — ``core/scheduler`` imports this module at
top level, and the reverse (static ledger population) goes through a
function-local import in :meth:`repro.telemetry.ledger.Ledger.modeled`.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Tuple


@dataclass(frozen=True)
class CalSample:
    """Aggregated observations for one (brick, profile) key."""

    seconds: float = 0.0
    joules: float = 0.0
    tokens: float = 0.0
    n: int = 0                  # observation count (blending weight input)

    @property
    def seconds_per_token(self) -> float:
        return self.seconds / self.tokens if self.tokens else 0.0

    @property
    def joules_per_token(self) -> float:
        return self.joules / self.tokens if self.tokens else 0.0


class CostCalibration:
    """(brick, profile-or-None) -> :class:`CalSample` table.

    ``prior`` is the pseudo-count of trust in the model: at ``n ==
    prior`` measured and modeled weigh equally; the default (4) lets a
    handful of bench iterations already dominate hand-written
    constants."""

    def __init__(self, prior: int = 4):
        self.prior = max(1, int(prior))
        self._table: Dict[Tuple[str, Optional[str]], CalSample] = {}

    # -- population ---------------------------------------------------------
    def observe(self, brick: str, profile: Optional[str], seconds: float,
                tokens: float, joules: float = 0.0, n: int = 1) -> CalSample:
        key = (brick, profile)
        cur = self._table.get(key, CalSample())
        out = CalSample(cur.seconds + seconds, cur.joules + joules,
                        cur.tokens + tokens, cur.n + max(1, int(n)))
        self._table[key] = out
        return out

    @classmethod
    def from_ledger(cls, ledger, profile: Optional[str] = None,
                    prior: int = 4) -> "CostCalibration":
        """Fold a ledger's *measured* rows (``samples > 0``) into a
        table; modeled rows are skipped by definition — the whole point
        is that the scheduler already has the model."""
        cal = cls(prior=prior)
        for brick, _phase, rec in ledger.items():
            if rec.samples > 0 and rec.tokens > 0:
                cal.observe(brick, profile, rec.seconds, rec.tokens,
                            rec.joules, n=rec.samples)
        return cal

    # -- lookup -------------------------------------------------------------
    def sample(self, brick: str, profile: Optional[str] = None
               ) -> Optional[CalSample]:
        s = self._table.get((brick, profile))
        if s is None and profile is not None:
            s = self._table.get((brick, None))
        return s

    def weight(self, n: int) -> float:
        """Sample-count blending weight in [0, 1)."""
        return n / (n + self.prior)

    # -- wire links ---------------------------------------------------------
    # Measured transport bandwidth rides the same table under a reserved
    # brick key: ``(LINK_KEY, transport-name)`` with bytes in the tokens
    # column.  ``core/scheduler.fleet_accelerators`` blends the result
    # over the static per-class ``link_bw`` row exactly like brick costs
    # blend measured seconds over the roofline model.

    LINK_KEY = "__link__"

    def observe_link(self, transport_name: Optional[str],
                     bytes_moved: float, seconds: float,
                     n: int = 1) -> CalSample:
        """Record measured wire crossings for one transport
        (``Transport.sent_bytes`` over ``Transport.send_seconds``)."""
        return self.observe(self.LINK_KEY, transport_name, seconds,
                            bytes_moved, n=n)

    def link_bw(self, transport_name: Optional[str],
                modeled_bw: float) -> float:
        """Blend measured wire bandwidth over the modeled ``link_bw``:
        no observation -> the static row, a well-observed wire -> what
        the frames actually clocked."""
        s = self.sample(self.LINK_KEY, transport_name)
        if s is None or s.tokens <= 0 or s.seconds <= 0:
            return modeled_bw
        w = self.weight(s.n)
        return (1.0 - w) * modeled_bw + w * (s.tokens / s.seconds)

    def energy_pressure(self, brick: str, profile: Optional[str],
                        modeled_j_per_token: float) -> float:
        """Measured-over-modeled decode energy ratio (>= 0); 1.0 when no
        energy observation exists.  The engine feeds this into
        ``kv_block_budgets`` so hotter-than-modeled decode sheds hi-res
        KV grants earlier."""
        s = self.sample(brick, profile)
        if s is None or s.joules <= 0 or modeled_j_per_token <= 0:
            return 1.0
        return s.joules_per_token / modeled_j_per_token

    def __len__(self) -> int:
        return len(self._table)

    def __bool__(self) -> bool:
        return bool(self._table)

    # -- persistence --------------------------------------------------------
    def to_dict(self) -> Dict:
        return {"schema": 1, "prior": self.prior,
                "table": {f"{b}@{p or ''}": {
                    "seconds": s.seconds, "joules": s.joules,
                    "tokens": s.tokens, "n": s.n}
                    for (b, p), s in sorted(
                        self._table.items(),
                        key=lambda kv: (kv[0][0], kv[0][1] or ""))}}

    @classmethod
    def from_dict(cls, d: Mapping) -> "CostCalibration":
        cal = cls(prior=int(d.get("prior", 4)))
        for key, s in d.get("table", {}).items():
            brick, _, prof = key.rpartition("@")
            cal.observe(brick, prof or None, s["seconds"], s["tokens"],
                        s.get("joules", 0.0), n=s.get("n", 1))
        return cal

    def save(self, path: str) -> str:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "CostCalibration":
        with open(path) as f:
            return cls.from_dict(json.load(f))
