"""Telemetry: measured-not-modeled feedback for the scheduler + benches.

Three coupled layers (ISSUE 8 / ROADMAP "measured energy/roofline
ledger"):

* :mod:`repro.telemetry.ledger` — a ``FlopCount``-style accumulating
  record of flops / bytes / link-bytes / tokens / joules / seconds per
  brick per phase (stage | prefill | decode), JSON-persisted; populated
  statically from the roofline+energy cost model at compile time and
  dynamically from wall-time probes.
* :mod:`repro.telemetry.probes` — timestamped per-brick wall-time
  samples recorded by ``ExecutionPlan`` / ``ServingEngine`` outside jit
  regions (host clocks only, replint-clean).
* :mod:`repro.telemetry.calibration` — measured per-brick
  seconds/joules tables that ``core/scheduler.brick_cost`` consults, so
  placement prices come from observation when samples exist.
* :mod:`repro.telemetry.fleet` — a RAPS-``FLOPSManager``-style
  simulator stepping hundreds of battery devices (own PMU/PowerPolicy
  each) through request traces, reporting fleet tokens/s, J/token and
  survival-hours histograms.
* :mod:`repro.telemetry.writer` — the ONE benchmark emitter: CSV
  side-emit plus the versioned ``BENCH_<pr>.json`` ledger that
  ``scripts/bench_gate.py`` regression-gates in CI.
"""
from repro.telemetry.calibration import CostCalibration
from repro.telemetry.ledger import Ledger, PhaseRecord
from repro.telemetry.probes import WallProbe

__all__ = ["CostCalibration", "Ledger", "PhaseRecord", "WallProbe"]
